from repro.configs.base import (
    ArchConfig, MoEConfig, SSMConfig, MLAConfig, ShapeConfig, PlanConfig,
    SHAPES, SHAPES_BY_NAME, shape_applicable,
)
from repro.configs.registry import ARCHS, get_arch, all_cells

__all__ = [
    "ArchConfig", "MoEConfig", "SSMConfig", "MLAConfig", "ShapeConfig",
    "PlanConfig", "SHAPES", "SHAPES_BY_NAME", "shape_applicable",
    "ARCHS", "get_arch", "all_cells",
]
