"""Architecture / run / plan configuration dataclasses.

``ArchConfig`` describes a model architecture exactly as assigned (full-size
production config).  ``smoke()`` derives a reduced config of the same family
for CPU tests.  ``ShapeConfig`` describes one input-shape cell (train/prefill/
decode/long-context-decode).  ``PlanConfig`` is a *tensor plan* — the
polystore "engine" choice for a compiled step: sharding regime, remat policy,
accumulation, attention implementation.  Plans are enumerated/selected by
``repro.core.tensorplan`` using the BigDAWG planner/monitor protocol.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared_experts: int = 0
    d_ff_expert: int = 0            # ff dim of each routed expert
    d_ff_shared: int = 0            # ff dim of the shared-expert path (total)
    capacity_factor: float = 1.25
    first_dense_layers: int = 0     # leading layers that use a dense MLP
    d_ff_dense: int = 0             # ff dim of those dense layers
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int                  # N
    head_dim: int = 64              # P
    expand: int = 2
    conv_width: int = 4
    n_groups: int = 1
    chunk: int = 128                # SSD chunk length


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0            # 0 = no q compression (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    # hybrid (zamba2-style): a shared attention block every `attn_period`
    # backbone layers, with per-invocation LoRA deltas of rank `shared_lora_rank`.
    attn_period: int = 0
    shared_lora_rank: int = 0
    # encoder-decoder
    encoder_layers: int = 0
    # modality frontend stub: None | "vision" | "audio"
    frontend: Optional[str] = None
    num_frontend_tokens: int = 0    # patches / audio frames folded into the seq
    # bookkeeping
    source: str = ""
    notes: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived ------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic context handling: SSM and hybrid families only."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (seamless is enc-dec)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D model-flops)."""
        from repro.models.api import count_params  # local import, no cycle at module load
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.api import count_params
        return count_params(self, active_only=True)

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 4 if self.family != "hybrid" else 7),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            d_ff=256,
            vocab_size=512,
            head_dim=32,
            encoder_layers=2 if self.encoder_layers else 0,
            num_frontend_tokens=8 if self.frontend else 0,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=2, d_ff_expert=64,
                d_ff_shared=(64 if self.moe.num_shared_experts else 0),
                first_dense_layers=min(self.moe.first_dense_layers, 1),
                d_ff_dense=(128 if self.moe.first_dense_layers else 0))
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, state_dim=16, head_dim=16, chunk=8)
        if self.mla is not None:
            kw["mla"] = dataclasses.replace(
                self.mla, kv_lora_rank=32, qk_nope_head_dim=32,
                qk_rope_head_dim=16, v_head_dim=32)
        if self.attn_period:
            kw["attn_period"] = 3
            kw["shared_lora_rank"] = 8
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------
# Input-shape cells (assigned shape set for the LM family)
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    mode: str                       # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4096, 256),
    ShapeConfig("prefill_32k", "prefill", 32768, 32),
    ShapeConfig("decode_32k", "decode", 32768, 128),
    ShapeConfig("long_500k", "decode", 524288, 1),
)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs, and why not if skipped."""
    if shape.name == "long_500k" and not arch.supports_long_context:
        return False, ("skip: pure full-attention family is quadratic at 500k "
                       "context (assignment: run long_500k only for SSM/hybrid)")
    return True, ""


# --------------------------------------------------------------------------
# Tensor plans — the polystore "engine" for a compiled step
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class PlanConfig:
    name: str = "baseline"
    fsdp: bool = True               # shard params' d_model dim over DP axes
    tp: bool = True                 # Megatron TP over the "model" axis
    sp_boundary: bool = True        # shard remat-boundary activations on seq over "model"
    sp_residual: bool = False       # Megatron sequence-parallelism: constrain
                                    # BOTH residual sums to seq-sharded, so TP
                                    # all-reduces lower as reduce-scatter +
                                    # all-gather (half the ring bytes)
    accum: int = 1                  # gradient-accumulation microbatch count
    remat: str = "block"            # none | block
    attn_chunk: int = 1024          # query-chunked attention block size
    loss_chunk: int = 1024          # seq chunk for the vocab-sharded loss
    moe_ep: bool = True             # shard experts over "model" when divisible
    moe_group_size: int = 4096      # sequence-chunked MoE dispatch (0 = off):
                                    # dispatch buffers (E*C tokens ~ 2.5x
                                    # activations) live one chunk at a time
    cache_seq_shard: bool = True    # shard decode KV cache on seq over "model"
    decode_cp: bool = False         # context-parallel decode attention via
                                    # shard_map + log-sum-exp combine: ~(B,H)
                                    # partials instead of all-gathering the
                                    # seq-sharded cache (2.2 GB/layer measured)
    compute_dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    master_dtype: str = "float32"
    moment_dtype: str = "float32"
    grad_compression: str = "none"  # none | int8_ef
    pipeline_stages: int = 1        # >1: GPipe over the "pod" axis (multi-pod)
    # dry-run cost accounting: cost_analysis counts a lax.scan body ONCE and
    # does NOT scale by trip count, so cost-probe compiles unroll every inner
    # loop (attention chunks, loss chunks, grad accumulation, SSD chunks) AND
    # the layer stacks into python loops at reduced probe depths (L1=1, L2=2),
    # then extrapolate linearly in depth.  Production programs keep lax.scan.
    unroll_inner: bool = False
    unroll_layers: bool = False

    def with_(self, **kw) -> "PlanConfig":
        return dataclasses.replace(self, **kw)
