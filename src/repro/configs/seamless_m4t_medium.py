"""seamless-m4t-medium — encoder-decoder multimodal (audio frontend STUB) [arXiv:2308.11596].

12L encoder + 12L decoder, d_model 1024, 16H (kv=16), ff 4096, vocab 256206.
The speech frontend is a stub: ``input_specs()`` provides precomputed frame
embeddings (batch, seq, d_model) for the encoder; the decoder is a standard
self+cross-attention transformer over text tokens.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,              # decoder layers
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    frontend="audio",
    num_frontend_tokens=0,      # encoder consumes the full frame sequence
    source="arXiv:2308.11596; hf:facebook/seamless-m4t-medium",
)
