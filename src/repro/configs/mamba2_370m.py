"""mamba2-370m — attention-free SSM with state-space duality (SSD) [arXiv:2405.21060]."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=1,            # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,                 # no MLP: mamba2 blocks only
    vocab_size=50280,
    head_dim=64,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  n_groups=1, chunk=128),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
