"""grok-1-314b — MoE, 8 experts top-2 [hf:xai-org/grok-1].

64L, d_model 6144, 48H GQA kv=8, expert ff 32768, vocab 131072.  With 8
experts and a 16-wide model axis, experts are TP-sharded on d_ff rather than
EP-sharded (8 ∤ 16) — see DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    moe=MoEConfig(num_experts=8, top_k=2, num_shared_experts=0,
                  d_ff_expert=32768, capacity_factor=1.25),
    source="hf:xai-org/grok-1",
)
