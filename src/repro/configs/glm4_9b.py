"""glm4-9b — dense GQA LM with tiny KV (kv=2), RoPE [hf:THUDM/glm-4-9b].

GLM-4 uses partial-rotary attention and post-norm quirks in the reference
implementation; we keep the standard pre-norm RoPE decoder here and note the
simplification (attention/KV geometry — the part that matters for sharding and
roofline — matches the assignment exactly).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    head_dim=128,
    qkv_bias=False,
    rope_theta=1e6,
    source="hf:THUDM/glm-4-9b",
    notes="partial-rotary + ffn gating simplified to standard pre-norm SwiGLU",
)
