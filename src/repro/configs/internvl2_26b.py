"""internvl2-26b — VLM: InternViT frontend (STUB) + InternLM2-20B backbone [arXiv:2404.16821].

Per the assignment, the modality frontend is a stub: ``input_specs()`` provides
precomputed patch embeddings of shape (batch, num_frontend_tokens, d_model)
which are concatenated ahead of the text tokens.  Only the transformer backbone
is modeled (48L / 6144 / 48H GQA kv=8 / ff 16384 / vocab 92553).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    rope_theta=1e6,
    frontend="vision",
    num_frontend_tokens=256,
    source="arXiv:2404.16821; hf:OpenGVLab/InternVL2-26B",
)
