"""zamba2-7b — hybrid Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

81 Mamba2 layers; a SHARED transformer block (full MHA kv=32 + SwiGLU ff=14336)
is applied every ``attn_period`` backbone layers with per-invocation LoRA
deltas, following the Zamba2 parameter-sharing scheme.  head_dim = 3584/32 = 112.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                  n_groups=1, chunk=128),
    attn_period=6,
    shared_lora_rank=64,
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-7B",
)
