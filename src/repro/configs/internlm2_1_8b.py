"""internlm2-1.8b — dense GQA LM [arXiv:2403.17297; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-1.8b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92544,
    head_dim=128,
    qkv_bias=False,
    rope_theta=1e6,
    source="arXiv:2403.17297; hf:internlm/internlm2-1_8b",
)
