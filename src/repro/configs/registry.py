"""Registry of the 10 assigned architectures (+ smoke variants)."""
from __future__ import annotations

from repro.configs.base import ArchConfig, SHAPES, SHAPES_BY_NAME, shape_applicable

from repro.configs.internlm2_1_8b import CONFIG as INTERNLM2_1_8B
from repro.configs.codeqwen1_5_7b import CONFIG as CODEQWEN1_5_7B
from repro.configs.qwen2_72b import CONFIG as QWEN2_72B
from repro.configs.glm4_9b import CONFIG as GLM4_9B
from repro.configs.mamba2_370m import CONFIG as MAMBA2_370M
from repro.configs.internvl2_26b import CONFIG as INTERNVL2_26B
from repro.configs.zamba2_7b import CONFIG as ZAMBA2_7B
from repro.configs.seamless_m4t_medium import CONFIG as SEAMLESS_M4T_MEDIUM
from repro.configs.deepseek_v2_lite_16b import CONFIG as DEEPSEEK_V2_LITE_16B
from repro.configs.grok1_314b import CONFIG as GROK1_314B

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        INTERNLM2_1_8B,
        CODEQWEN1_5_7B,
        QWEN2_72B,
        GLM4_9B,
        MAMBA2_370M,
        INTERNVL2_26B,
        ZAMBA2_7B,
        SEAMLESS_M4T_MEDIUM,
        DEEPSEEK_V2_LITE_16B,
        GROK1_314B,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return ARCHS[name[: -len("-smoke")]].smoke()
    return ARCHS[name]


def all_cells():
    """Yield every (arch, shape, applicable, why) cell — 40 total."""
    for arch in ARCHS.values():
        for shape in SHAPES:
            ok, why = shape_applicable(arch, shape)
            yield arch, shape, ok, why


__all__ = [
    "ARCHS", "get_arch", "all_cells", "SHAPES", "SHAPES_BY_NAME",
]
