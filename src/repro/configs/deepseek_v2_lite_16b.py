"""deepseek-v2-lite-16b — MoE with multi-head latent attention (MLA) [arXiv:2405.04434].

27L, d_model 2048, 16H MLA (kv_lora 512, rope 64, nope 128, v 128), vocab
102400.  MoE: 64 routed experts top-6 + 2 shared experts, expert ff 1408,
first layer dense (ff 10944).  NOTE: the assignment bracket "2 shared+160
routed" contradicts its own headline "MoE 64e top-6"; we follow 64 routed
top-6, which matches the published V2-Lite config.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,            # MLA: latent cache, no separate KV heads
    d_ff=1408,
    vocab_size=102400,
    head_dim=128,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, num_shared_experts=2,
                  d_ff_expert=1408, d_ff_shared=2 * 1408,
                  capacity_factor=1.25, first_dense_layers=1, d_ff_dense=10944),
    source="arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2-Lite",
)
