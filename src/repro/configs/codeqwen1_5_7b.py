"""codeqwen1.5-7b — dense LM, qwen1.5 architecture (QKV bias, kv=heads) [hf:Qwen/CodeQwen1.5-7B]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    source="hf:Qwen/CodeQwen1.5-7B",
)
