"""Pallas TPU kernel: Mamba2 SSD intra-chunk compute.

The quadratic (attention-like) intra-chunk term dominates SSD FLOPs; the
inter-chunk recurrence is a cheap sequential scan left in jnp.  Grid:
(batch, head-block).  Head blocks live inside a single B/C group (g_blk = 1),
so the decay matrix L = exp(segsum(da)) is materialized per head block only:
(block_h, Q, Q) f32 at Q=128, block_h=32 is 2 MiB of VMEM.  Each grid cell
produces the chunk output, the end-of-chunk state contribution, and the chunk
decay in one VMEM residency.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, da_ref, b_ref, c_ref, y_ref, st_ref, cd_ref, *, q: int):
    x = x_ref[0].astype(jnp.float32)      # (Q, bh, p)  (pre-multiplied by dt)
    da = da_ref[0].astype(jnp.float32)    # (Q, bh)
    B = b_ref[0, :, 0].astype(jnp.float32)   # (Q, n) — this block's group
    C = c_ref[0, :, 0].astype(jnp.float32)

    daT = da.T                            # (bh, Q)
    cs = jnp.cumsum(daT, axis=-1)
    diff = cs[:, :, None] - cs[:, None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    Lm = jnp.where(mask[None], jnp.exp(jnp.where(mask[None], diff, 0.0)), 0.0)

    G = jnp.einsum("qn,kn->qk", C, B)     # (Q, Q)
    M = G[None] * Lm                      # (bh, Q, Q)
    y = jnp.einsum("hqk,khp->qhp", M, x)
    y_ref[0] = y.astype(y_ref.dtype)

    decay_states = jnp.exp(cs[:, -1:] - cs)               # (bh, Q)
    states = jnp.einsum("kn,hk,khp->hnp", B, decay_states, x)
    st_ref[0] = states.astype(st_ref.dtype)
    cd_ref[0] = jnp.exp(cs[:, -1]).astype(cd_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_h", "interpret"))
def ssd_intra_pallas(x, da, B, C, block_h: int = 32, interpret: bool = False):
    """Intra-chunk SSD for one chunk, batched.

    x: (b, Q, h, p) pre-multiplied by dt; da: (b, Q, h); B, C: (b, Q, g, n).
    Returns (y (b,Q,h,p), states (b,h,n,p), chunk_decay (b,h)).
    """
    b, q, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    bh = min(block_h, hg)
    while hg % bh:                        # largest divisor of hg <= block_h
        bh -= 1
    grid = (b, h // bh)

    y, st, cd = pl.pallas_call(
        functools.partial(_ssd_kernel, q=q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, bh, p), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, q, bh), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, q, 1, n), lambda i, j: (i, 0, (j * bh) // hg, 0)),
            pl.BlockSpec((1, q, 1, n), lambda i, j: (i, 0, (j * bh) // hg, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, bh, p), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, bh, n, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bh), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, q, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        interpret=interpret,
    )(x, da, B, C)
    return y, st, cd
