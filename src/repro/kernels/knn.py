"""Pallas TPU kernel: kNN score matrix (test @ trainᵀ) as a tiled MXU matmul.

Tiling: (block_b × block_v) @ (block_v × block_n) with the contraction as the
innermost grid axis and an f32 VMEM accumulator block; all matmul dims are
kept at multiples of 128 to map onto the 128×128 MXU.  Top-k runs outside the
kernel (it is O(B·N) and bandwidth-trivial next to the GEMM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(t_ref, x_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
    o_ref[...] += jnp.dot(t_ref[...], x_ref[...].T,
                          preferred_element_type=jnp.float32)


def _pick(block, dim):
    b = min(block, dim)
    while dim % b:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit,
                   static_argnames=("block_b", "block_n", "block_v", "interpret"))
def knn_scores_pallas(train: jnp.ndarray, test: jnp.ndarray,
                      block_b: int = 128, block_n: int = 256,
                      block_v: int = 512, interpret: bool = False):
    """train: (N, V); test: (B, V) -> scores (B, N) f32."""
    n, v = train.shape
    b = test.shape[0]
    bb, bn, bv = _pick(block_b, b), _pick(block_n, n), _pick(block_v, v)
    grid = (b // bb, n // bn, v // bv)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bv), lambda i, j, k: (i, k)),   # test block
            pl.BlockSpec((bn, bv), lambda i, j, k: (j, k)),   # train block
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(test, train)


def knn_pallas(train, test, k, interpret: bool = False):
    scores = knn_scores_pallas(train, test, interpret=interpret)
    s, idx = jax.lax.top_k(scores, k)
    return idx, s
