"""Public jit'd wrappers for the Pallas kernels.

Dispatch policy: on TPU backends the Pallas kernels run compiled; elsewhere
(this CPU container) the pure-jnp references execute, and the kernels
themselves are validated against those references in interpret mode by
tests/test_kernels.py.  Set REPRO_FORCE_PALLAS=interpret to route these
wrappers through the interpret-mode kernels (slow; used by the kernel tests).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.haar import haar_pallas
from repro.kernels.knn import knn_pallas, knn_scores_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssd_scan import ssd_intra_pallas


def _mode() -> str:
    forced = os.environ.get("REPRO_FORCE_PALLAS", "")
    if forced:
        return forced                     # "interpret" | "compiled" | "ref"
    return "compiled" if jax.default_backend() == "tpu" else "ref"


def haar(x: jnp.ndarray, levels: int) -> jnp.ndarray:
    m = _mode()
    if m == "ref":
        return ref.haar_ref(x, levels)
    return haar_pallas(x, levels, interpret=(m == "interpret"))


def knn(train: jnp.ndarray, test: jnp.ndarray, k: int):
    m = _mode()
    if m == "ref":
        return ref.knn_ref(train, test, k)
    return knn_pallas(train, test, k, interpret=(m == "interpret"))


def knn_scores(train, test):
    m = _mode()
    if m == "ref":
        return ref.knn_scores_ref(train, test)
    return knn_scores_pallas(train, test, interpret=(m == "interpret"))


def flash_attention(q, k, v, *, causal: bool = True):
    """q, k, v: (BH, S, d)."""
    m = _mode()
    if m == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return flash_attention_pallas(q, k, v, causal=causal,
                                  interpret=(m == "interpret"))


def ssd_intra(x, da, B, C):
    m = _mode()
    if m == "ref":
        return ref.ssd_intra_ref(x, da, B, C)
    return ssd_intra_pallas(x, da, B, C, interpret=(m == "interpret"))
