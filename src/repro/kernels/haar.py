"""Pallas TPU kernel: multi-level Haar DWT over rows of a (N, T) array.

Tiling: rows are blocked by ``block_rows`` (VPU lane-friendly multiples of 8),
the full T samples of a row block live in VMEM (T ≤ 8192 f32 = 32 KiB/row —
a (128, 4096) block is 2 MiB, well inside the ~16 MiB VMEM budget).  Each
grid step transforms its block fully in registers/VMEM — the transform is
memory-bound, so one HBM round-trip per element is the roofline.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _haar_kernel(x_ref, o_ref, *, levels: int):
    x = x_ref[...]
    inv = jnp.asarray(1.0 / math.sqrt(2.0), x.dtype)
    details = []
    a = x
    for _ in range(levels):                 # static unroll; T halves each time
        e, o = a[..., 0::2], a[..., 1::2]
        details.append((e - o) * inv)
        a = (e + o) * inv
    o_ref[...] = jnp.concatenate([a] + details[::-1], axis=-1)


@functools.partial(jax.jit, static_argnames=("levels", "block_rows", "interpret"))
def haar_pallas(x: jnp.ndarray, levels: int, block_rows: int = 128,
                interpret: bool = False) -> jnp.ndarray:
    n, t = x.shape
    assert t % (1 << levels) == 0, "T must be divisible by 2^levels"
    br = min(block_rows, n)
    if n % br:
        br = n                               # degenerate small input: one block
    grid = (n // br,)
    return pl.pallas_call(
        functools.partial(_haar_kernel, levels=levels),
        grid=grid,
        in_specs=[pl.BlockSpec((br, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, t), x.dtype),
        interpret=interpret,
    )(x)
