"""Pallas TPU kernel: causal flash attention (forward).

Grid: (batch·heads, q-blocks, kv-blocks) with the kv axis innermost.  Running
max / denominator / unnormalized accumulator live in VMEM scratch; the output
block is written once, on the final kv step for its q block.  Causal blocks
strictly above the diagonal are skipped via ``pl.when`` (no wasted MXU work —
this is the advantage over the jnp chunked path used for dry-runs, which
computes then masks).

Block shapes default to (128, head_dim) q-blocks × (512, head_dim) kv-blocks:
q, k, v, acc blocks together stay under ~1 MiB for head_dim 128 — far inside
VMEM — while keeping the MXU contraction dim ≥ 128.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, block_q: int, block_k: int, causal: bool,
               kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: skip blocks fully above the diagonal
    run = True
    if causal:
        run = ki * block_k <= qi * block_q + block_q - 1

    @pl.when(run if causal else True)
    def _step():
        q = q_ref[0]                                   # (bq, d)
        k = k_ref[0]                                   # (bk, d)
        v = v_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                           (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                           (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, block_q: int = 128,
                           block_k: int = 512, interpret: bool = False):
    """q, k, v: (BH, S, d) — KV already expanded to query heads."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    bq = min(block_q, sq)
    while sq % bq:
        bq //= 2
    bk = min(block_k, sk)
    while sk % bk:
        bk //= 2
    grid = (bh, sq // bq, sk // bk)
    scale = 1.0 / math.sqrt(d)
    return pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, block_q=bq, block_k=bk,
                          causal=causal, kv_blocks=sk // bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denominator
            pltpu.VMEM((bq, d), jnp.float32),   # unnormalized accumulator
        ],
        interpret=interpret,
    )(q, k, v)
