"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernels are validated against (interpret=True
on CPU) and the execution path used off-TPU by ``kernels.ops``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def haar_ref(x: jnp.ndarray, levels: int) -> jnp.ndarray:
    """Multi-level Haar DWT over the last axis: [a_L, d_L, ..., d_1]."""
    inv = 1.0 / math.sqrt(2.0)
    details = []
    a = x
    for _ in range(levels):
        e, o = a[..., 0::2], a[..., 1::2]
        details.append((e - o) * inv)
        a = (e + o) * inv
    return jnp.concatenate([a] + details[::-1], axis=-1)


def knn_scores_ref(train: jnp.ndarray, test: jnp.ndarray) -> jnp.ndarray:
    """Dot-product scores.  train: (N, V); test: (B, V) -> (B, N)."""
    return jnp.einsum("bv,nv->bn", test.astype(jnp.float32),
                      train.astype(jnp.float32))


def knn_ref(train, test, k):
    scores = knn_scores_ref(train, test)
    s, idx = jax.lax.top_k(scores, k)
    return idx, s


def flash_attention_ref(q, k, v, *, causal=True):
    """q,k,v: (BH, S, d) -> (BH, S, d), f32 softmax."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) / math.sqrt(d)
    if causal:
        S = q.shape[1]
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def ssd_intra_ref(x, da, B, C):
    """Intra-chunk SSD (one chunk, batched): the quadratic dual form.

    x: (b, Q, h, p) pre-multiplied by dt; da: (b, Q, h); B, C: (b, Q, g, n).
    Returns (y (b,Q,h,p), states (b,h,n,p), chunk_decay (b,h)) where states is
    this chunk's contribution decayed to the chunk end and chunk_decay is
    exp(sum da).
    """
    b, Q, h, p = x.shape
    g = B.shape[2]
    hg = h // g
    daT = da.transpose(0, 2, 1).astype(jnp.float32)       # (b, h, Q)
    cs = jnp.cumsum(daT, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    Lm = jnp.exp(jnp.where(mask, diff, -jnp.inf))         # (b, h, Q, Q)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    xf = x.astype(jnp.float32).reshape(b, Q, g, hg, p)
    G = jnp.einsum("bqgn,bkgn->bgqk", Cf, Bf)
    M = G.reshape(b, g, 1, Q, Q) * Lm.reshape(b, g, hg, Q, Q)
    y = jnp.einsum("bghqk,bkghp->bqghp", M, xf).reshape(b, Q, h, p)
    decay_states = jnp.exp(cs[..., -1:] - cs)             # (b, h, Q)
    dsg = decay_states.reshape(b, g, hg, Q)
    states = jnp.einsum("bkgn,bghk,bkghp->bghnp", Bf, dsg, xf)
    states = states.reshape(b, h, B.shape[-1], p)
    chunk_decay = jnp.exp(cs[..., -1])                    # (b, h)
    return y.astype(x.dtype), states, chunk_decay
