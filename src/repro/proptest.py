"""A minimal property-based testing shim with the hypothesis surface.

``requirements.txt`` pins real hypothesis and CI installs it, but the test
suite must run — not skip — in a bare environment where ``pip install`` is
unavailable.  This module implements the exact decorator surface the tests
use (``given`` / ``settings`` / ``strategies as st``) over a deterministic
seeded RNG, so::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from repro.proptest import given, settings, strategies as st

keeps every property test collecting AND executing either way.  Differences
from real hypothesis, deliberately accepted:

* no shrinking — a failure reports the raw falsifying example;
* no example database — the seed is derived from the test's qualified name,
  so runs are reproducible but do not remember past failures;
* draws are independent per example (no swarm testing / coverage guidance).

Supported strategies: ``integers``, ``floats``, ``booleans``,
``sampled_from``, ``just``, ``one_of``, ``lists``, ``tuples``, plus
``.map``/``.filter`` combinators and the ``@st.composite`` builder.
``settings`` honors ``max_examples`` and ignores the rest (``deadline``,
``database``...), matching how the suite calls it.
"""
from __future__ import annotations

import random
import zlib
from functools import wraps
from typing import Any, Callable, Iterable, Sequence

__all__ = ["given", "settings", "strategies", "st"]

_DEFAULT_MAX_EXAMPLES = 100
_MAX_FILTER_TRIES = 1000


class SearchStrategy:
    """Base strategy: ``example(rng)`` draws one value."""

    def example(self, rng: random.Random) -> Any:
        raise NotImplementedError

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return _Mapped(self, fn)

    def filter(self, pred: Callable[[Any], bool]) -> "SearchStrategy":
        return _Filtered(self, pred)


class _Mapped(SearchStrategy):
    def __init__(self, base, fn):
        self.base, self.fn = base, fn

    def example(self, rng):
        return self.fn(self.base.example(rng))


class _Filtered(SearchStrategy):
    def __init__(self, base, pred):
        self.base, self.pred = base, pred

    def example(self, rng):
        for _ in range(_MAX_FILTER_TRIES):
            v = self.base.example(rng)
            if self.pred(v):
                return v
        raise RuntimeError("filter predicate rejected every candidate")


class _Integers(SearchStrategy):
    def __init__(self, min_value=None, max_value=None):
        self.lo = -(2 ** 31) if min_value is None else int(min_value)
        self.hi = 2 ** 31 if max_value is None else int(max_value)

    def example(self, rng):
        # bias toward the boundaries — that is where off-by-ones live, and
        # without shrinking the boundary cases must be drawn directly
        r = rng.random()
        if r < 0.08:
            return self.lo
        if r < 0.16:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _Floats(SearchStrategy):
    def __init__(self, min_value=None, max_value=None, *, allow_nan=False,
                 allow_infinity=False, width=64):
        self.lo = -1e9 if min_value is None else float(min_value)
        self.hi = 1e9 if max_value is None else float(max_value)

    def example(self, rng):
        r = rng.random()
        if r < 0.05:
            return self.lo
        if r < 0.10:
            return self.hi
        if r < 0.15 and self.lo <= 0.0 <= self.hi:
            return 0.0
        return rng.uniform(self.lo, self.hi)


class _Booleans(SearchStrategy):
    def example(self, rng):
        return rng.random() < 0.5


class _SampledFrom(SearchStrategy):
    def __init__(self, elements: Sequence):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from requires a non-empty sequence")

    def example(self, rng):
        return rng.choice(self.elements)


class _Just(SearchStrategy):
    def __init__(self, value):
        self.value = value

    def example(self, rng):
        return self.value


class _OneOf(SearchStrategy):
    def __init__(self, strats: Iterable[SearchStrategy]):
        self.strats = list(strats)

    def example(self, rng):
        return rng.choice(self.strats).example(rng)


class _Lists(SearchStrategy):
    def __init__(self, elements: SearchStrategy, *, min_size=0, max_size=10):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10

    def example(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return [self.elements.example(rng) for _ in range(n)]


class _Tuples(SearchStrategy):
    def __init__(self, *strats: SearchStrategy):
        self.strats = strats

    def example(self, rng):
        return tuple(s.example(rng) for s in self.strats)


class _CompositeStrategy(SearchStrategy):
    def __init__(self, fn, args, kwargs):
        self.fn, self.args, self.kwargs = fn, args, kwargs

    def example(self, rng):
        return self.fn(lambda strat: strat.example(rng),
                       *self.args, **self.kwargs)


def _composite(fn):
    @wraps(fn)
    def builder(*args, **kwargs):
        return _CompositeStrategy(fn, args, kwargs)
    return builder


class _Strategies:
    """The ``hypothesis.strategies`` namespace subset the suite imports."""
    integers = staticmethod(_Integers)
    floats = staticmethod(_Floats)
    booleans = staticmethod(_Booleans)
    sampled_from = staticmethod(_SampledFrom)
    just = staticmethod(_Just)
    lists = staticmethod(_Lists)
    composite = staticmethod(_composite)

    @staticmethod
    def one_of(*strats):
        return _OneOf(strats)

    @staticmethod
    def tuples(*strats):
        return _Tuples(*strats)


strategies = _Strategies()
st = strategies


class settings:                              # noqa: N801 — hypothesis surface
    """Decorator carrying ``max_examples`` to the ``given`` runner.  Works
    in either stacking order (settings-outside-given is what the suite
    uses); unknown knobs (``deadline=None``...) are accepted and ignored."""

    def __init__(self, max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._proptest_max_examples = self.max_examples
        return fn


def given(*strats: SearchStrategy):
    """Run the wrapped test once per drawn example.  The RNG seed derives
    from the test's qualified name, so a run is reproducible and a failure
    message names the falsifying example explicitly."""

    def deco(fn):
        @wraps(fn)
        def runner(*args, **kwargs):         # signature intentionally empty:
            # pytest must not mistake the property's drawn params for
            # fixtures (``__wrapped__`` is deleted below for the same
            # reason — it would expose fn's signature through inspect)
            n = getattr(runner, "_proptest_max_examples",
                        getattr(fn, "_proptest_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                drawn = tuple(s.example(rng) for s in strats)
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception as exc:
                    raise AssertionError(
                        f"falsifying example (run {i + 1}/{n}) for "
                        f"{fn.__qualname__}: args={drawn!r}") from exc
        del runner.__wrapped__
        # pytest unwraps property tests through fn.hypothesis.inner_test
        # (the real library's handle shape) — mirror it exactly
        runner.hypothesis = type("inner", (), {"inner_test": fn})()
        return runner

    return deco
