"""Perf hillclimbing driver — BigDAWG's training phase applied to tensor
plans (DESIGN.md §7).

For one (arch × shape) cell, evaluates a list of plan variants (each a
dry-run subprocess), records roofline terms into the tensorplan monitor DB,
and prints the comparison.  The hypothesis → change → measure → validate log
lives in EXPERIMENTS.md §Perf.

Usage:
  python -m repro.launch.hillclimb --arch qwen2-72b --shape train_4k \
      --variant baseline --variant accum16:accum=16 \
      --variant nosp:sp_boundary=false
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from repro.core.monitor import Monitor
from repro.core.tensorplan import cell_signature
from repro.configs import get_arch, SHAPES_BY_NAME

OUTDIR = "benchmarks/artifacts/hillclimb"
DBPATH = os.path.join(OUTDIR, "tensorplan_monitor.json")


def run_variant(arch, shape, name, overrides, timeout=3000):
    out = os.path.join(OUTDIR, f"{arch}.{shape}.{name}.json")
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--plan-name", name, "--out", out]
    if overrides:
        cmd += ["--set"] + overrides
    env = dict(os.environ, PYTHONPATH="src")
    p = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                       env=env)
    if p.returncode != 0:
        return None, (p.stdout + p.stderr)[-1500:]
    return json.load(open(out)), None


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", action="append", default=[],
                    help="name[:k=v,k=v...]")
    args = ap.parse_args(argv)
    os.makedirs(OUTDIR, exist_ok=True)
    monitor = Monitor(DBPATH)
    sig = cell_signature(get_arch(args.arch), SHAPES_BY_NAME[args.shape],
                         "pod_16x16")

    print(f"{'variant':18s} {'t_comp':>8s} {'t_mem':>8s} {'t_coll':>8s} "
          f"{'dominant':>10s} {'domval':>8s} {'rooffrac':>8s} {'hbm':>7s}")
    for v in args.variant:
        if ":" in v:
            name, ov = v.split(":", 1)
            overrides = ov.split(",")
        else:
            name, overrides = v, []
        rec, err = run_variant(args.arch, args.shape, name, overrides)
        if rec is None or "roofline" not in rec:
            print(f"{name:18s} FAILED: {(err or 'no roofline')[:90]}")
            continue
        rf = rec["roofline"]
        dom = max(rf["t_compute"], rf["t_memory"], rf["t_collective"])
        monitor.record(sig, name, dom, extra={
            "t_compute": rf["t_compute"], "t_memory": rf["t_memory"],
            "t_collective": rf["t_collective"],
            "roofline_fraction": rf["roofline_fraction"],
            "hbm_gb": rec["hbm_bytes_per_device"] / 1e9})
        print(f"{name:18s} {rf['t_compute']:8.3f} {rf['t_memory']:8.3f} "
              f"{rf['t_collective']:8.3f} {rf['dominant']:>10s} {dom:8.3f} "
              f"{rf['roofline_fraction']:8.4f} "
              f"{rec['hbm_bytes_per_device']/1e9:6.1f}G")
    monitor.save()
    key, stats, _ = monitor.best(sig)
    print(f"\nproduction pick for {sig}: {key} "
          f"(dominant {stats.mean_seconds:.3f}s)")


if __name__ == "__main__":
    main()
