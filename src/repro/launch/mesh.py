"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (dry-runs set XLA_FLAGS before first jax init; smoke tests
see 1 device).
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` across JAX versions.

    Newer JAX exposes ``jax.sharding.AxisType`` and lets ``make_mesh`` take
    ``axis_types``; older releases (e.g. 0.4.x) have neither, and their
    default behavior is exactly ``AxisType.Auto`` on every axis.  Request
    Auto explicitly where the API exists, plain ``make_mesh`` where it
    doesn't — same semantics either way.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def mesh_devices(*, multi_pod: bool = False) -> int:
    return 512 if multi_pod else 256
