"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (dry-runs set XLA_FLAGS before first jax init; smoke tests
see 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_devices(*, multi_pod: bool = False) -> int:
    return 512 if multi_pod else 256
