"""Dry-run sweep driver: one subprocess per (arch × shape × mesh) cell.

Each cell needs a fresh process (jax locks the host-device count at first
init) and subprocess isolation makes the sweep resumable — existing artifacts
are skipped.  Failures are recorded to <cell>.err and the sweep continues.

Usage: python -m repro.launch.sweep [--mesh pod|multipod|both] [--force]
           [--arch A ...] [--shape S ...] [--outdir benchmarks/artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import ARCHS, SHAPES, shape_applicable

# ascending size: fail fast on the cheap ones
ORDER = ["mamba2-370m", "seamless-m4t-medium", "internlm2-1.8b",
         "codeqwen1.5-7b", "glm4-9b", "zamba2-7b", "deepseek-v2-lite-16b",
         "internvl2-26b", "qwen2-72b", "grok-1-314b"]


def cell_path(outdir, arch, shape, mesh):
    return os.path.join(outdir, f"{arch}.{shape}.{mesh}.json")


def run_cell(arch, shape, mesh, outdir, timeout=3000):
    out = cell_path(outdir, arch, shape, mesh)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", out]
    if mesh == "multipod":
        cmd.append("--multi-pod")
    env = dict(os.environ, PYTHONPATH="src")
    t0 = time.time()
    try:
        p = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=timeout, env=env)
        ok = p.returncode == 0
        tail = (p.stdout + p.stderr)[-4000:]
    except subprocess.TimeoutExpired as e:
        ok, tail = False, f"TIMEOUT after {timeout}s"
    dt = time.time() - t0
    if not ok:
        with open(out.replace(".json", ".err"), "w") as f:
            f.write(tail)
    return ok, dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--outdir", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--arch", nargs="*", default=None)
    ap.add_argument("--shape", nargs="*", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)
    os.makedirs(args.outdir, exist_ok=True)

    archs = args.arch or [a for a in ORDER if a in ARCHS]
    shapes = args.shape or [s.name for s in SHAPES]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    total = ok_n = skip_n = 0
    for mesh in meshes:
        for arch in archs:
            for shape in shapes:
                cfg = ARCHS[arch]
                sh = next(s for s in SHAPES if s.name == shape)
                applicable, why = shape_applicable(cfg, sh)
                out = cell_path(args.outdir, arch, shape, mesh)
                if not applicable:
                    with open(out, "w") as f:
                        json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                                   "applicable": False, "skip_reason": why},
                                  f, indent=1)
                    print(f"SKIP {arch} x {shape} x {mesh}: {why}", flush=True)
                    skip_n += 1
                    continue
                if os.path.exists(out) and not args.force:
                    try:
                        rec = json.load(open(out))
                        if "memory" in rec:
                            print(f"HAVE {arch} x {shape} x {mesh}", flush=True)
                            continue
                    except Exception:
                        pass
                total += 1
                ok, dt = run_cell(arch, shape, mesh, args.outdir)
                ok_n += ok
                print(f"{'OK  ' if ok else 'FAIL'} {arch} x {shape} x {mesh} "
                      f"({dt:.0f}s)", flush=True)
    print(f"\nsweep done: {ok_n}/{total} ran ok, {skip_n} skipped by design",
          flush=True)


if __name__ == "__main__":
    main()
