import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
# Placeholder host devices exist ONLY for the dry-run (smoke tests and
# benchmarks run in their own processes and see 1 device).

"""Multi-pod dry-run: prove every (architecture × input-shape × mesh) cell
lowers, compiles, fits, and expose its roofline terms — without hardware.

Per cell:
  memory compile  full-depth program with lax.scan layer stacks (accurate CPU
                  scheduling) -> memory_analysis() is the fits-proof.
  cost probes     python-unrolled programs at depths L1/L2 (single-pod only);
                  totals extrapolate linearly in scan depth.  Needed because
                  cost_analysis() counts a while body once (DESIGN.md §5).
                  Training cells probe grad-only steps at the true microbatch
                  and scale by the accumulation count; the optimizer update is
                  compiled separately at FULL size (exact, no extrapolation).
  collectives     parsed from compiled HLO text (post-SPMD, per-device shapes)
                  with a ring-model multiplier for all-reduce.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k \
      [--multi-pod] [--plan-name baseline] [--set accum=4 sp_boundary=false]
      [--out artifacts/...json] [--skip-cost]
"""
import argparse
import dataclasses
import json
import math
import re
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import (ARCHS, PlanConfig, SHAPES_BY_NAME, get_arch,
                           shape_applicable)
from repro.core.tensorplan import default_plan
from repro.launch.mesh import make_production_mesh, mesh_devices
from repro.models import api
from repro.models.partition import plan_scope
from repro.optim import AdamW

# v5e hardware constants (roofline)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
                "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
                "u64": 8, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"= (?P<shapes>.+?) (?P<op>all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def parse_collective_bytes(hlo_text: str):
    """Per-device collective bytes from post-SPMD HLO.  all-reduce counts 2x
    (ring reduce-scatter + all-gather); others count their result size."""
    total = 0.0
    breakdown = {}
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group("op")
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(m.group("shapes")):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        factor = 2.0 if op == "all-reduce" else 1.0
        total += nbytes * factor
        rec = breakdown.setdefault(op, [0, 0.0])
        rec[0] += 1
        rec[1] += nbytes * factor
    return total, {k: {"count": v[0], "bytes": v[1]}
                   for k, v in breakdown.items()}


def _probe_cfg(cfg, depth_units: int):
    """Reduced-depth config with `depth_units` scan iterations."""
    if cfg.family == "hybrid":
        nl = cfg.attn_period * depth_units + \
            (cfg.num_layers % cfg.attn_period)
        return dataclasses.replace(cfg, num_layers=nl)
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, num_layers=depth_units,
                                   encoder_layers=depth_units)
    prefix = cfg.moe.first_dense_layers if cfg.moe else 0
    return dataclasses.replace(cfg, num_layers=prefix + depth_units)


def scan_depth(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_period
    prefix = cfg.moe.first_dense_layers if cfg.moe else 0
    return cfg.num_layers - prefix


def _shardings(mesh, spec_tree):
    return api.to_shardings(mesh, spec_tree)


def _compile_stats(compiled):
    m = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    coll, breakdown = parse_collective_bytes(txt)
    return {
        "arg_bytes": m.argument_size_in_bytes,
        "out_bytes": m.output_size_in_bytes,
        "temp_bytes": m.temp_size_in_bytes,
        "alias_bytes": m.alias_size_in_bytes,
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": coll,
        "coll_breakdown": breakdown,
        "hlo_chars": len(txt),
    }


def _lower_train(cfg, shape, plan, mesh, *, micro_only=False, grad_only=False):
    """Returns compiled stats for the train step (or grad-only probe)."""
    opt = AdamW(learning_rate=1e-4, moment_dtype=plan.moment_dtype)
    with plan_scope(mesh, plan):
        batch = api.example_batch(cfg, shape, plan)
        if micro_only:
            A = plan.accum
            batch = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((s.shape[0] // A,) + s.shape[1:],
                                               s.dtype), batch)
            plan = plan.with_(accum=1)
        state_sds = jax.eval_shape(
            lambda k: api.init_train_state(cfg, plan, k, opt),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        sspec = api.train_state_specs(cfg, plan, state_sds)
        bspec = api.batch_specs(cfg, plan, batch)
        sshard = _shardings(mesh, sspec)
        bshard = _shardings(mesh, bspec)

        if grad_only:
            loss_fn = api.get_loss_fn(cfg, plan)
            cdt = jnp.dtype(plan.compute_dtype)

            def grad_step(master, b):
                return jax.value_and_grad(
                    lambda m, bb: loss_fn(api.cast_params(m, cdt), bb))(master, b)

            fn = jax.jit(grad_step, in_shardings=(sshard["master"], bshard),
                         out_shardings=(None, sshard["master"]))
            lowered = fn.lower(state_sds["master"], batch)
        else:
            step = api.make_train_step(cfg, plan, opt)
            fn = jax.jit(step, in_shardings=(sshard, bshard),
                         out_shardings=(sshard, None), donate_argnums=(0,))
            lowered = fn.lower(state_sds, batch)
        t0 = time.time()
        compiled = lowered.compile()
        stats = _compile_stats(compiled)
        stats["compile_s"] = time.time() - t0
        return stats


def _lower_opt_update(cfg, plan, mesh):
    """Full-size optimizer update probe (elementwise; exact at full depth)."""
    opt = AdamW(learning_rate=1e-4, moment_dtype=plan.moment_dtype)
    with plan_scope(mesh, plan):
        master_sds = jax.eval_shape(
            lambda k: api.init_params(
                cfg, k, plan.with_(param_dtype=plan.master_dtype)),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        opt_sds = jax.eval_shape(opt.init, master_sds)
        pspec = api.param_specs(cfg, plan, master_sds)
        pshard = _shardings(mesh, pspec)
        oshard = {"m": pshard, "v": pshard,
                  "count": _shardings(mesh, jax.sharding.PartitionSpec())}
        gshard = pshard
        fn = jax.jit(opt.update,
                     in_shardings=(gshard, oshard, pshard),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(1, 2))
        lowered = fn.lower(master_sds, opt_sds, master_sds)
        t0 = time.time()
        compiled = lowered.compile()
        stats = _compile_stats(compiled)
        stats["compile_s"] = time.time() - t0
        return stats


def _lower_serve(cfg, shape, plan, mesh):
    with plan_scope(mesh, plan):
        if shape.mode == "decode":
            cache_sds = api.example_cache(cfg, shape, plan)
            batch = api.example_batch(cfg, shape, plan)
            cspec = api.cache_specs(cfg, plan, cache_sds)
            bspec = api.batch_specs(cfg, plan, batch)
            pspec_sds = jax.eval_shape(
                lambda k: api.init_params(cfg, k, plan),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
            pspec = api.param_specs(cfg, plan, pspec_sds)
            step = api.make_decode_step(cfg, shape, plan)
            fn = jax.jit(step,
                         in_shardings=(_shardings(mesh, pspec),
                                       _shardings(mesh, cspec),
                                       _shardings(mesh, bspec["tokens"]),
                                       _shardings(mesh, bspec["pos"])),
                         donate_argnums=(1,))
            lowered = fn.lower(pspec_sds, cache_sds, batch["tokens"],
                               batch["pos"])
        else:                                        # prefill
            batch = api.example_batch(cfg, shape, plan)
            pspec_sds = jax.eval_shape(
                lambda k: api.init_params(cfg, k, plan),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
            pspec = api.param_specs(cfg, plan, pspec_sds)
            bspec = api.batch_specs(cfg, plan, batch)
            fn = jax.jit(api.make_prefill(cfg, shape, plan),
                         in_shardings=(_shardings(mesh, pspec),
                                       _shardings(mesh, bspec)))
            lowered = fn.lower(pspec_sds, batch)
        t0 = time.time()
        compiled = lowered.compile()
        stats = _compile_stats(compiled)
        stats["compile_s"] = time.time() - t0
        return stats


def _combine(base, delta, n):
    """base + n * delta for the cost keys."""
    out = {}
    for k in ("flops", "bytes", "coll_bytes"):
        out[k] = base[k] + n * delta[k]
    return out


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
             plan: PlanConfig, skip_cost: bool = False) -> dict:
    cfg = get_arch(arch_name)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh_kind = "multipod_2x16x16" if multi_pod else "pod_16x16"
    record = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
        "plan": dataclasses.asdict(plan), "applicable": ok, "skip_reason": why,
        "params": api.count_params(cfg),
        "active_params": api.count_params(cfg, active_only=True),
    }
    if not ok:
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = mesh_devices(multi_pod=multi_pod)
    if shape.mode == "train":
        # microbatches must still shard over the DP axes
        dp = 32 if multi_pod else 16
        max_accum = max(shape.global_batch // dp, 1)
        if plan.accum > max_accum:
            plan = plan.with_(accum=max_accum)
            record["plan"] = dataclasses.asdict(plan)

    # ---- memory compile (the fits-proof) --------------------------------
    if shape.mode == "train":
        mem = _lower_train(cfg, shape, plan, mesh)
    else:
        mem = _lower_serve(cfg, shape, plan, mesh)
    record["memory"] = mem
    hbm = (mem["arg_bytes"] + mem["temp_bytes"] + mem["out_bytes"]
           - mem["alias_bytes"])
    record["hbm_bytes_per_device"] = hbm
    record["fits_16g"] = bool(hbm < 16e9)

    if skip_cost or multi_pod:
        return record

    # ---- cost probes (single-pod roofline) -------------------------------
    probe_plan = plan.with_(unroll_inner=True, unroll_layers=True)
    L = scan_depth(cfg)
    c1 = _probe_cfg(cfg, 1)
    c2 = _probe_cfg(cfg, 2)
    if shape.mode == "train":
        g1 = _lower_train(c1, shape, probe_plan, mesh, micro_only=True,
                          grad_only=True)
        g2 = _lower_train(c2, shape, probe_plan, mesh, micro_only=True,
                          grad_only=True)
        opt_cost = _lower_opt_update(cfg, plan, mesh)
        delta = {k: g2[k] - g1[k] for k in ("flops", "bytes", "coll_bytes")}
        per_micro = _combine(g1, delta, L - 1)
        cost = {k: plan.accum * per_micro[k] + opt_cost[k]
                for k in ("flops", "bytes", "coll_bytes")}
        record["probes"] = {"g1": g1, "g2": g2, "opt": opt_cost}
    else:
        s1 = _lower_serve(c1, shape, probe_plan, mesh)
        s2 = _lower_serve(c2, shape, probe_plan, mesh)
        delta = {k: s2[k] - s1[k] for k in ("flops", "bytes", "coll_bytes")}
        cost = _combine(s1, delta, L - 1)
        record["probes"] = {"s1": s1, "s2": s2}
    record["cost"] = cost

    # ---- roofline terms ---------------------------------------------------
    n_act = record["active_params"]
    if shape.mode == "train":
        model_flops = 6.0 * n_act * shape.tokens
    elif shape.mode == "prefill":
        model_flops = 2.0 * n_act * shape.tokens
    else:
        model_flops = 2.0 * n_act * shape.global_batch
    mf_dev = model_flops / ndev
    t_compute = cost["flops"] / PEAK_FLOPS
    t_memory = cost["bytes"] / HBM_BW
    t_collective = cost["coll_bytes"] / ICI_BW
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_collective, "collective"))
    record["roofline"] = {
        "t_compute": t_compute, "t_memory": t_memory,
        "t_collective": t_collective,
        "dominant": dominant[1],
        "model_flops_per_device": mf_dev,
        "useful_flops_ratio": mf_dev / max(cost["flops"], 1.0),
        "roofline_fraction": (mf_dev / PEAK_FLOPS) / max(
            t_compute, t_memory, t_collective),
    }
    return record


def _parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        if v.lower() in ("true", "false"):
            out[k] = v.lower() == "true"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                out[k] = v
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES_BY_NAME))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-cost", action="store_true")
    ap.add_argument("--plan-name", default=None)
    ap.add_argument("--set", nargs="*", default=[],
                    help="plan overrides: accum=4 sp_boundary=false ...")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    shape = SHAPES_BY_NAME[args.shape]
    plan = default_plan(cfg, shape)
    ov = _parse_overrides(args.set)
    if args.plan_name:
        ov["name"] = args.plan_name
    if ov:
        plan = plan.with_(**ov)

    t0 = time.time()
    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod, plan=plan,
                   skip_cost=args.skip_cost)
    rec["wall_s"] = time.time() - t0

    blob = json.dumps(rec, indent=1, default=float)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(blob)
    print(blob)
    if rec.get("applicable") and "memory" in rec:
        print(f"\nOK {args.arch} x {args.shape} x "
              f"{'multipod' if args.multi_pod else 'singlepod'}: "
              f"hbm/dev={rec['hbm_bytes_per_device']/1e9:.2f} GB "
              f"fits16G={rec['fits_16g']}", file=sys.stderr)


if __name__ == "__main__":
    main()
