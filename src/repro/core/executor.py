"""Executor (paper §III-C / [19]): runs a plan tree — resolves refs against
the catalog, migrates inputs to each node's engine via the migrator, invokes
the shim (engine op), and collects wall time + cast statistics for the
monitor.

Two dispatch modes:

  sequential (default) — blocks after every node, yielding honest per-node
      timings; these feed the calibrated cost model (training phase).
  concurrent — groups the DAG into topological levels and submits every node
      in a level (including its multi-hop input casts) to a shared host
      ``ThreadPoolExecutor``.  Numpy-eager engine work — columnar joins, COO
      conversions, cast hops — releases the GIL on real arrays, so host work
      genuinely overlaps across workers, on top of JAX async dispatch
      overlapping the device work.  One barrier per level (futures are
      drained before the next level starts).  Used by the production phase,
      where per-node attribution is not needed.  In auto mode
      (``host_workers=None``) a level is threaded only when at least two of
      its tasks are predicted heavy enough to overlap: with a ``cost_model``
      at hand the gate compares each task's *predicted seconds* (op seconds
      from learned throughputs + cast seconds for inputs homed on another
      data model) against ``HOST_TASK_GATE_FACTOR x`` the model's learned
      per-host thread-dispatch overhead (measured once per process as real
      submit->result round trips on the live pool, persisted with the
      calibration file); without a model it falls back to the static
      ``HOST_TASK_MIN_BYTES`` byte threshold.  Tiny XLA-bound levels stay
      inline, where single-threaded async dispatch is already optimal;
      ``host_workers<=1`` falls back to inline single-threaded level
      dispatch (the pre-PR-3 behavior), and a single-node level always runs
      inline (no pool round-trip).

Both modes report each node's *actual* logical output size (``size_obs``)
and dense-equivalent output shape (``shape_obs``), keyed by post-order
position, so the monitor can feed real intermediate sizes AND shapes back
into the planner's estimates — the other half of the §III-C feedback loop
(a measured select size overrides the bytes rule; a measured shape feeds
downstream matmul output estimates).  When a ``cost_model`` is supplied, the
migrator routes casts along the model's cheapest (possibly multi-hop) path,
each hop sized from its intermediate format, instead of always taking the
direct pair.

The host pool is process-wide and lazily built (``host_pool``): plans are
short-lived but frequent on the serving path, and thread churn per plan
would dominate the win.  ``execute_plan`` is safe to call from many request
threads at once — each call keeps its own value/timing dicts, the shared
Migrator accounting is lock-guarded, and pool workers never submit to the
pool themselves.  Do not call ``execute_plan(concurrent=True)`` with
``host_workers>1`` from *inside* a pool worker — a saturated pool could
deadlock on the level barrier; background tasks that must execute a plan
from a worker (the middleware's off-path exploration) pass
``host_workers=1`` so their level dispatch stays inline.
"""
from __future__ import annotations

import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.core.costmodel import (CostModel, container_elems, observed_nbytes,
                                  observed_shape)
from repro.core.engines import ENGINES
from repro.core.errors import EngineDown, is_engine_failure
from repro.core.islands import ISLAND_KIND, island_kind
from repro.core.migrator import Migrator
from repro.core.ops import SCOPE_OP, PolyOp, Ref
from repro.core.planner import Plan

# (ISLAND_KIND — the data model a query's result is delivered in, i.e. its
# root island's model — is re-exported from islands.py, its canonical home
# since island boundaries became first-class IR nodes)

# default size of the shared host pool; override per call via host_workers=
# or process-wide via REPRO_HOST_WORKERS
DEFAULT_HOST_WORKERS = min(8, os.cpu_count() or 1)

# auto-mode FALLBACK gate (no cost model): threads a level only when at
# least two of its nodes each move this many input bytes.  Small-payload
# levels are XLA-dispatch-bound, and multi-threaded dispatch of many tiny
# ops pays lock contention for zero overlap (measured ~0.6x on
# fig_host_parallel's pipeline family).  An explicit host_workers forces
# threading regardless.  With a cost model, the predicted-seconds gate below
# replaces this static threshold.
HOST_TASK_MIN_BYTES = 1e6

# predicted-seconds gate: a task is worth a pool round trip only when its
# predicted seconds dwarf the measured dispatch overhead by this factor
HOST_TASK_GATE_FACTOR = 4.0

_POOL: Optional[ThreadPoolExecutor] = None
_POOL_SIZE = 0
_POOL_LOCK = threading.Lock()

# one measurement of the pool round-trip cost per worker count per process
# (see _dispatch_overhead); cached so later cost models inherit it without
# re-measuring on the serve path
DISPATCH_PROBE_WORKERS = (1, 2, 4)
_DISPATCH_MEASURED: Dict[int, float] = {}
_DISPATCH_LOCK = threading.Lock()


def host_pool(max_workers: Optional[int] = None) -> ThreadPoolExecutor:
    """The process-wide host-task pool for concurrent dispatch (lazily
    created; rebuilt only if a larger size is requested)."""
    global _POOL, _POOL_SIZE
    want = max_workers or int(os.environ.get("REPRO_HOST_WORKERS", 0)) \
        or DEFAULT_HOST_WORKERS
    with _POOL_LOCK:
        if _POOL is None or _POOL_SIZE < want:
            # a superseded pool is NOT shut down: another plan may still
            # hold a reference and submit to it (shutdown would raise
            # RuntimeError mid-plan).  Its idle threads simply park until
            # process exit; pool growth happens at most a handful of times.
            _POOL = ThreadPoolExecutor(max_workers=want,
                                       thread_name_prefix="bigdawg-host")
            _POOL_SIZE = want
        return _POOL


@dataclass
class ExecutionResult:
    value: Any
    seconds: float
    cast_bytes: float
    n_casts: int
    plan: Plan
    per_node_seconds: Dict[int, float] = field(default_factory=dict)
    # measured (engine, op, input_elems, seconds) per node — sequential only
    node_obs: List[Tuple[str, str, float, float]] = field(default_factory=list)
    # measured (src_kind, dst_kind, bytes, seconds) per cast
    cast_obs: List[Tuple[str, str, float, float]] = field(default_factory=list)
    levels: int = 0                     # topological depth actually dispatched
    # post-order position -> measured logical output bytes (both modes) —
    # the monitor stores these per signature for size-estimate feedback
    size_obs: Dict[int, float] = field(default_factory=dict)
    # post-order position -> measured dense-equivalent output shape (both
    # modes, where the format carries one) — shape feedback for downstream
    # matmul/transpose output estimates
    shape_obs: Dict[int, Tuple[int, ...]] = field(default_factory=dict)
    # position groups that executed as single compiled segments this run
    # (empty when fusion was off, nothing was fusable, or every segment
    # fell back)
    fused_segments: Tuple[Tuple[int, ...], ...] = ()
    # fused segments that failed to trace/compile/run THIS run and were
    # re-executed node-by-node (each also marks its key sticky-broken)
    fusion_fallbacks: int = 0
    # fused segments whose compiled callable paid trace+compile THIS run
    # (first serve of a segment signature at these shapes) — the middleware
    # keeps such a serve's wall time out of the plan's measured mean so a
    # one-off compile spike can never trigger a divergence re-plan
    fusion_cold_compiles: int = 0


def _block(x):
    """Block on all device buffers in a container (honest timing)."""
    for leaf in jax.tree.leaves(getattr(x, "__dict__", x)):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return x


def topo_levels(query: PolyOp) -> List[List[PolyOp]]:
    """Nodes grouped by topological depth; everything within a level is
    mutually independent and can be dispatched together."""
    depth: Dict[int, int] = {}
    levels: List[List[PolyOp]] = []
    for node in query.nodes():              # post-order: inputs first
        if node.uid in depth:               # shared subtree: already placed
            continue
        d = 0
        for inp in node.inputs:
            if isinstance(inp, PolyOp):
                d = max(d, depth[inp.uid] + 1)
        depth[node.uid] = d
        while len(levels) <= d:
            levels.append([])
        levels[d].append(node)
    return levels


def _node_input_nbytes(node: PolyOp, catalog, values) -> float:
    """Physical bytes this node's inputs occupy right now — the cheap proxy
    the FALLBACK auto-threading gate uses when no cost model is at hand."""
    total = 0.0
    for inp in node.inputs:
        if isinstance(inp, Ref):
            if catalog is not None and inp.name in catalog:
                total += float(getattr(catalog[inp.name].obj, "nbytes", 0.0))
        else:
            total += float(getattr(values.get(inp.uid), "nbytes", 0.0) or 0.0)
    return total


def _dispatch_overhead(cost_model, workers: Optional[int] = None,
                       reps: int = 5) -> float:
    """The learned per-host thread-dispatch overhead, in seconds, for a
    level dispatched over ``workers`` pool threads.

    Measured once per process at each of ``DISPATCH_PROBE_WORKERS`` (1/2/4
    host workers): the median over ``reps`` rounds of (submit ``w`` no-op
    tasks, await all, divide by ``w``) — per-task amortized overhead, which
    FALLS with worker count as submissions overlap result waits.  The table
    is folded into the cost model (``observe_dispatch(s, workers=w)``) so
    it persists beside the calibration and later processes start from real
    numbers; the auto-threading gate then interpolates at the level's
    actual worker count instead of assuming the single-point cost.  A model
    that already carries measurements (restored from disk) is trusted
    without re-measuring.

    The round trips run on PRIVATE probe pools, not the live host pool:
    the quantity of interest is pure submit->result overhead, and on the
    shared pool a queued background exploration trial would be timed as
    'overhead', poisoning the persisted value (seconds-scale floor => the
    gate never threads again)."""
    if cost_model.dispatch_overhead.n or cost_model.dispatch_table:
        return cost_model.dispatch_overhead_s(workers)
    with _DISPATCH_LOCK:
        if not _DISPATCH_MEASURED:
            for w in DISPATCH_PROBE_WORKERS:
                with ThreadPoolExecutor(max_workers=w) as probe:
                    # concurrent sleeps force all w threads to spin up
                    # before the timed rounds
                    for f in [probe.submit(time.sleep, 0.001)
                              for _ in range(w)]:
                        f.result()
                    samples = []
                    for _ in range(reps):
                        t0 = time.perf_counter()
                        futs = [probe.submit(lambda: None)
                                for _ in range(w)]
                        for f in futs:
                            f.result()
                        samples.append((time.perf_counter() - t0) / w)
                samples.sort()
                _DISPATCH_MEASURED[w] = samples[len(samples) // 2]
    for w, s in _DISPATCH_MEASURED.items():
        cost_model.observe_dispatch(s, workers=w)
    return cost_model.dispatch_overhead_s(workers)


def _task_pred_seconds(node: PolyOp, engine_name: str, catalog, values,
                       cost_model) -> float:
    """Predicted seconds of one host task (engine op + any input casts onto
    the op's data model) — what the auto-threading gate weighs against the
    dispatch overhead.  Sized from the inputs' CURRENT containers, so the
    estimate sharpens level by level as real intermediates materialize."""
    eng = ENGINES[engine_name]
    elems = 0.0
    secs = 0.0
    for inp in node.inputs:
        if isinstance(inp, Ref):
            obj = catalog[inp.name].obj if (catalog is not None
                                            and inp.name in catalog) else None
        else:
            obj = values.get(inp.uid)
        if obj is None:
            continue
        elems += container_elems(obj)
        kind = getattr(obj, "kind", eng.kind)
        if kind != eng.kind:
            # flat nbytes (no per-hop kind sizing): the gate must stay cheap
            # — container_kind_nbytes scans columnar validity masks
            secs += cost_model.cast_seconds(kind, eng.kind,
                                            float(getattr(obj, "nbytes", 0.0)))
    return secs + cost_model.op_seconds(engine_name, node.op, elems)


def _gather_args(node: PolyOp, eng, catalog, values, migrator):
    args = []
    for inp in node.inputs:
        if isinstance(inp, Ref):
            obj = catalog[inp.name].obj
        else:
            obj = values[inp.uid]
        args.append(migrator.to_engine(obj, eng.name))
    return args


def _deliver(query: PolyOp, result):
    """Deliver in the root island's data model (location transparency: the
    caller sees the island model regardless of which engine produced it).

    A 0-d dense result — an aggregate scalar (``count``) — is delivered
    as-is on every island: a scalar has no data-model home, and every
    engine's aggregate already emits the same shape, so the scatter–gather
    ``sum`` merge sees one uniform container regardless of root island."""
    if getattr(result, "kind", None) == "dense" \
            and getattr(result.data, "ndim", None) == 0:
        return result
    want = island_kind(query.island)
    if getattr(result, "kind", want) != want:
        from repro.core import cast as castmod
        result = castmod.cast(result, want)
        _block(result)
    return result


def execute_plan(query: PolyOp, plan: Plan, catalog,
                 concurrent: bool = False,
                 cost_model: Optional[CostModel] = None,
                 host_workers: Optional[int] = None,
                 health=None, fused=None, trace=None) -> ExecutionResult:
    """``health`` (a ``core.health.EngineHealth``) opts the run into the
    resilience path: the registry's ``before_op`` hook fires ahead of every
    engine op (the fault-injection seam), and any *engine* failure — an
    exception ``errors.is_engine_failure`` classifies as infrastructure, in
    the op itself or in an input cast onto the op's engine — feeds the
    engine's circuit breaker and re-raises as ``EngineDown`` so the
    middleware can fail over.  Query errors (bad column names, shape
    mismatches) propagate unchanged: they would fail identically on every
    engine, so retrying them elsewhere is never correct.

    ``fused`` (a ``core.fuseplan.FusedPlan`` for this plan) opts concurrent
    dispatch into fused execution: each segment runs as ONE host task — the
    migrator casts its external inputs onto the segment engine (cast-in),
    the single jitted callable evaluates the whole chain with intermediates
    on device, and the segment's measured seconds are attributed back to
    member nodes pro-rata by predicted cost (``per_node_seconds`` keeps its
    meaning for the monitor, drift re-planning and the straggler
    detectors).  ``health.before_op`` still fires per member op, so
    fault-injection and breakers see fused serves exactly like unfused
    ones.  Any fused-call failure falls back to node-by-node execution of
    the members inside the same task (sticky per segment signature — see
    ``fuseplan.mark_broken``), so fusion can never turn a servable query
    into an error.  Sequential (training) mode ignores ``fused``: per-node
    calibration timings must stay pure.

    ``trace`` (a ``core.tracing.Span``, or None) attaches already-measured
    ``engine_op`` / ``fused_segment`` / ``cast`` child spans under the
    caller's span — no extra clock reads beyond the timings this function
    takes anyway, and zero work when None."""
    amap = plan.engine_map(query)
    migrator = Migrator(cost_model=cost_model, trace=trace)
    values: Dict[int, Any] = {}
    per_node: Dict[int, float] = {}
    node_obs: List[Tuple[str, str, float, float]] = []
    size_obs: Dict[int, float] = {}
    shape_obs: Dict[int, Tuple[int, ...]] = {}
    t0 = time.perf_counter()
    n_levels = 0

    def run_node(node: PolyOp):
        """One host task: migrate inputs (possibly multi-hop casts) and run
        the engine op — the numpy-eager parts release the GIL, so tasks of
        one level overlap on the pool.  Deliberately does NOT block on the
        result: XLA-backed ops stay async (dispatch returns immediately;
        blocking here would serialize the device pipeline behind each
        worker), and the level boundary blocks everything once.

        An island-boundary (scope) node IS its input migration: the cast
        onto the boundary engine's data model happens in ``_gather_args``
        (migrator-routed, byte-accounted), and the node itself is the
        identity."""
        eng = ENGINES[amap[node.uid]]
        tn = time.perf_counter()
        try:
            if health is not None:
                health.before_op(eng.name, node.op)
            args = _gather_args(node, eng, catalog, values, migrator)
            out = args[0] if node.op == SCOPE_OP \
                else eng.run(node.op, node.attrs, *args)
        except Exception as exc:
            _engine_fail(exc, eng.name, node.op)
            raise
        dt = time.perf_counter() - tn
        per_node[node.uid] = dt
        if trace is not None:
            trace.static_child("engine_op", dt, op=node.op, engine=eng.name)
        return node.uid, out

    def _engine_fail(exc: BaseException, engine: str, op: str):
        """Failure attribution: infrastructure-shaped exceptions feed the
        breaker and become EngineDown; anything else falls through to the
        caller's bare re-raise (a query error, not an engine one)."""
        if health is not None and is_engine_failure(exc):
            health.record_failure(engine)
            raise EngineDown(engine, op, exc) from exc

    fused_ran: List[Tuple[int, ...]] = []
    fallbacks = [0]
    cold_compiles = [0]

    if concurrent and fused is not None and getattr(fused, "segments", ()):
        from repro.core import fuseplan
        nodes = query.nodes()
        node_at = {pos: n for pos, n in enumerate(nodes)}
        uid_at = {pos: n.uid for pos, n in enumerate(nodes)}

        def run_segment(seg):
            """One host task for a whole fused segment: cast-in the external
            inputs, invoke the compiled callable (intermediates stay on
            device), attribute the measured seconds pro-rata.  A broken (or
            just-failed) segment executes its members node-by-node inside
            the SAME task — identical results, one sticky mark per key."""
            eng = ENGINES[seg.engine]
            tn = time.perf_counter()
            try:
                if health is not None:
                    for op in seg.ops:       # breakers/injectors see every
                        health.before_op(eng.name, op)   # member op
                out = None
                if not fuseplan.is_broken(seg.key):
                    try:
                        if fused.injector is not None:
                            fused.injector.on_fuse(seg.key)
                        ext_objs = [
                            migrator.to_engine(
                                catalog[src].obj if kind == "ref"
                                else values[uid_at[src]], eng.name)
                            for kind, src in seg.ext_sources]
                        out, was_cold = fuseplan.run_fused_segment(
                            seg, ext_objs)
                        fused_ran.append(seg.positions)
                        if was_cold:
                            cold_compiles[0] += 1
                    except Exception as exc:
                        # trace/compile/run failure: never an error for the
                        # caller — mark sticky, count, re-run unfused below
                        fuseplan.mark_broken(seg.key, repr(exc))
                        fallbacks[0] += 1
                        out = None
                if out is None:
                    out = _segment_unfused(seg, eng)
            except Exception as exc:
                _engine_fail(exc, eng.name, seg.ops[-1])
                raise
            dt = time.perf_counter() - tn
            for p, w in zip(seg.positions, seg.weights):
                per_node[uid_at[p]] = dt * w
            if trace is not None:
                # one fused_segment span, with per-member engine_op children
                # carrying the same pro-rata attribution per_node got
                sid = trace.static_child("fused_segment", dt,
                                         engine=seg.engine,
                                         positions=list(seg.positions))
                for p, w in zip(seg.positions, seg.weights):
                    trace.trace.static("engine_op", sid, dt * w,
                                       op=node_at[p].op, engine=seg.engine)
            return uid_at[seg.root_pos], out

        def _segment_unfused(seg, eng):
            """Node-by-node fallback, inline in the segment's task.  Member
            intermediates land in ``values`` so size/shape feedback is as
            complete as an unfused serve's."""
            out = None
            for p in seg.positions:
                node = node_at[p]
                args = _gather_args(node, eng, catalog, values, migrator)
                out = eng.run(node.op, node.attrs, *args)
                values[node.uid] = out
            return out

        # collapse the DAG to units (fused segments + leftover plain nodes)
        # level them by longest path, like topo_levels over the unit graph.
        # Post-order guarantees a unit's depth is final before any outside
        # consumer reads it (a segment's members all precede its consumer).
        seg_at: Dict[int, int] = {}      # position -> segment index
        for si, seg in enumerate(fused.segments):
            for p in seg.positions:
                seg_at[p] = si

        def unit_of(pos: int):
            si = seg_at.get(pos)
            return ("s", si) if si is not None else ("n", pos)

        pos_of = {n.uid: p for p, n in enumerate(nodes)}
        depth: Dict[Tuple[str, int], int] = {}
        for pos, node in enumerate(nodes):
            u = unit_of(pos)
            d = depth.get(u, 0)
            for inp in node.inputs:
                if isinstance(inp, PolyOp):
                    iu = unit_of(pos_of[inp.uid])
                    if iu != u:
                        d = max(d, depth[iu] + 1)
            depth[u] = d
        unit_levels: List[List[Tuple[str, int]]] = []
        for u, d in depth.items():
            while len(unit_levels) <= d:
                unit_levels.append([])
            unit_levels[d].append(u)
        n_levels = len(unit_levels)

        def run_unit(u):
            kind, x = u
            return run_segment(fused.segments[x]) if kind == "s" \
                else run_node(node_at[x])

        workers = host_workers if host_workers is not None else \
            int(os.environ.get("REPRO_HOST_WORKERS", 0)) or \
            DEFAULT_HOST_WORKERS
        pool = host_pool(workers) if workers > 1 else None
        for level in unit_levels:
            outs = []
            use_pool = pool is not None and len(level) > 1
            if use_pool and host_workers is None and cost_model is not None:
                # same predicted-seconds gate as the unfused path; a
                # segment's task prediction sums its members'
                floor_s = HOST_TASK_GATE_FACTOR * _dispatch_overhead(
                    cost_model, workers)

                def _unit_pred(u):
                    kind, x = u
                    ps = [x] if kind == "n" else \
                        list(fused.segments[x].positions)
                    return sum(_task_pred_seconds(
                        node_at[p], amap[uid_at[p]], catalog, values,
                        cost_model) for p in ps)
                use_pool = sum(1 for u in level
                               if _unit_pred(u) >= floor_s) >= 2
            if not use_pool:
                for u in level:
                    uid, out = run_unit(u)
                    values[uid] = out
                    outs.append(out)
            else:
                futs = [pool.submit(run_unit, u) for u in level]
                for fut in futs:
                    uid, out = fut.result()
                    values[uid] = out
                    outs.append(out)
            for out in outs:
                _block(out)
    elif concurrent:
        lvls = topo_levels(query)
        n_levels = len(lvls)
        workers = host_workers if host_workers is not None else \
            int(os.environ.get("REPRO_HOST_WORKERS", 0)) or \
            DEFAULT_HOST_WORKERS
        pool = host_pool(workers) if workers > 1 else None
        for level in lvls:
            outs = []
            use_pool = pool is not None and len(level) > 1
            if use_pool and host_workers is None:
                # auto mode: thread only when >= 2 tasks are heavy enough to
                # overlap.  With a cost model: predicted task seconds vs the
                # learned dispatch overhead; without: the static byte gate.
                if cost_model is not None:
                    floor_s = HOST_TASK_GATE_FACTOR * \
                        _dispatch_overhead(cost_model, workers)
                    heavy = sum(1 for n in level
                                if _task_pred_seconds(n, amap[n.uid], catalog,
                                                      values, cost_model)
                                >= floor_s)
                else:
                    heavy = sum(1 for n in level
                                if _node_input_nbytes(n, catalog, values)
                                >= HOST_TASK_MIN_BYTES)
                use_pool = heavy >= 2
            if not use_pool:
                for node in level:           # inline fallback / trivial level
                    uid, out = run_node(node)
                    values[uid] = out
                    outs.append(out)
            else:
                # one future per node; .result() re-raises the first worker
                # exception in submission order — a failing node fails the
                # plan, it does not vanish into the pool
                futs = [pool.submit(run_node, node) for node in level]
                for fut in futs:
                    uid, out = fut.result()
                    values[uid] = out
                    outs.append(out)
            for out in outs:                 # one block per level boundary
                _block(out)
    else:
        for node in query.nodes():          # post-order
            eng = ENGINES[amap[node.uid]]
            # per_node covers migration + op (same meaning as concurrent
            # mode's run_node timing); node_obs — what calibrates op rates —
            # starts after the gather, so learned throughputs stay pure op
            tg = time.perf_counter()
            try:
                if health is not None:
                    health.before_op(eng.name, node.op)
                args = _gather_args(node, eng, catalog, values, migrator)
                elems = sum(container_elems(a) for a in args)
                tn = time.perf_counter()
                if node.op == SCOPE_OP:
                    # island boundary: the migration above WAS the work
                    # (timed per hop by the migrator); the node is the
                    # identity, so no op observation — a ~0s "scope" rate
                    # would poison the engine-level mean the cost model
                    # falls back to
                    out = args[0]
                else:
                    out = eng.run(node.op, node.attrs, *args)
                    _block(out)
                    node_obs.append((eng.name, node.op, elems,
                                     time.perf_counter() - tn))
            except Exception as exc:
                _engine_fail(exc, eng.name, node.op)
                raise
            dt = time.perf_counter() - tg
            per_node[node.uid] = dt
            if trace is not None:
                trace.static_child("engine_op", dt, op=node.op,
                                   engine=eng.name)
            values[node.uid] = out

    result = _deliver(query, values[query.uid])
    total = time.perf_counter() - t0
    # size/shape measurement happens OUTSIDE the timed window: observed_nbytes
    # can touch host memory (columnar validity sum) and must not inflate the
    # seconds the monitor records and the replan comparison consumes
    for pos, node in enumerate(query.nodes()):
        obj = values.get(node.uid)
        if obj is None:
            # fused-segment interior: stayed on device inside the compiled
            # callable, so there is nothing to measure (the monitor keeps
            # whatever it learned from unfused serves of this signature)
            continue
        size_obs[pos] = observed_nbytes(obj)
        shp = observed_shape(obj)
        if shp is not None:
            shape_obs[pos] = shp
    return ExecutionResult(result, total, migrator.bytes_moved,
                           migrator.n_casts, plan, per_node, node_obs,
                           list(migrator.events), n_levels, size_obs,
                           shape_obs, tuple(fused_ran), fallbacks[0],
                           cold_compiles[0])


def merge_shard_results(merge: str, parts, by: Optional[str] = None):
    """Gather step of partitioned (scatter–gather) execution: reassemble the
    per-shard fragment results the workers returned.  Returns ``(container,
    merge_seconds)``.

    Deliberately numpy-only (the ``tables`` merge primitives): the gather
    runs in the procpool MASTER, which must never initialize the XLA backend
    — the workers own the device.  ``merge`` is one of ``"concat"`` (row-wise
    ops), ``"sum"`` (decomposable aggregates), or ``"kmerge"`` (k-way ordered
    merge on sort column ``by``); see ``core.shardplan`` for which ops map to
    which."""
    from repro.core import tables
    t0 = time.perf_counter()
    if merge == "concat":
        out = tables.concat_shards(parts)
    elif merge == "sum":
        out = tables.sum_shards(parts)
    elif merge == "kmerge":
        out = tables.kmerge_shards(parts, by)
    else:
        raise ValueError(f"unknown merge kind {merge!r}")
    return out, time.perf_counter() - t0
