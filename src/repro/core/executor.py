"""Executor (paper §III-C / [19]): runs a plan tree — resolves refs against
the catalog, migrates inputs to each node's engine via the migrator, invokes
the shim (engine op), and collects wall time + cast statistics for the
monitor.

Two dispatch modes:

  sequential (default) — blocks after every node, yielding honest per-node
      timings; these feed the calibrated cost model (training phase).
  concurrent — groups the DAG into topological levels and dispatches every
      node in a level without blocking (JAX async dispatch overlaps their
      device work), with a single block at each level boundary.  Used by the
      production phase, where per-node attribution is not needed.

Both modes report each node's *actual* logical output size (``size_obs``,
keyed by post-order position) so the monitor can feed real intermediate
sizes back into the planner's estimates — the other half of the §III-C
feedback loop.  When a ``cost_model`` is supplied, the migrator routes casts
along the model's cheapest (possibly multi-hop) path instead of always
taking the direct pair.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.core.costmodel import CostModel, container_elems, observed_nbytes
from repro.core.engines import ENGINES
from repro.core.migrator import Migrator
from repro.core.ops import PolyOp, Ref
from repro.core.planner import Plan

# the data model a query's result is delivered in = its root island's model
ISLAND_KIND = {"array": "dense", "relational": "columnar", "text": "coo",
               "stream": "stream"}


@dataclass
class ExecutionResult:
    value: Any
    seconds: float
    cast_bytes: float
    n_casts: int
    plan: Plan
    per_node_seconds: Dict[int, float] = field(default_factory=dict)
    # measured (engine, op, input_elems, seconds) per node — sequential only
    node_obs: List[Tuple[str, str, float, float]] = field(default_factory=list)
    # measured (src_kind, dst_kind, bytes, seconds) per cast
    cast_obs: List[Tuple[str, str, float, float]] = field(default_factory=list)
    levels: int = 0                     # topological depth actually dispatched
    # post-order position -> measured logical output bytes (both modes) —
    # the monitor stores these per signature for size-estimate feedback
    size_obs: Dict[int, float] = field(default_factory=dict)


def _block(x):
    """Block on all device buffers in a container (honest timing)."""
    for leaf in jax.tree.leaves(getattr(x, "__dict__", x)):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return x


def topo_levels(query: PolyOp) -> List[List[PolyOp]]:
    """Nodes grouped by topological depth; everything within a level is
    mutually independent and can be dispatched together."""
    depth: Dict[int, int] = {}
    levels: List[List[PolyOp]] = []
    for node in query.nodes():              # post-order: inputs first
        if node.uid in depth:               # shared subtree: already placed
            continue
        d = 0
        for inp in node.inputs:
            if isinstance(inp, PolyOp):
                d = max(d, depth[inp.uid] + 1)
        depth[node.uid] = d
        while len(levels) <= d:
            levels.append([])
        levels[d].append(node)
    return levels


def _gather_args(node: PolyOp, eng, catalog, values, migrator):
    args = []
    for inp in node.inputs:
        if isinstance(inp, Ref):
            obj = catalog[inp.name].obj
        else:
            obj = values[inp.uid]
        args.append(migrator.to_engine(obj, eng.name))
    return args


def _deliver(query: PolyOp, result):
    """Deliver in the root island's data model (location transparency: the
    caller sees the island model regardless of which engine produced it)."""
    if query.island in ISLAND_KIND:
        want = ISLAND_KIND[query.island]
    else:                                    # degenerate:<engine>
        want = ENGINES[query.island.split(":", 1)[1]].kind
    if getattr(result, "kind", want) != want:
        from repro.core import cast as castmod
        result = castmod.cast(result, want)
        _block(result)
    return result


def execute_plan(query: PolyOp, plan: Plan, catalog,
                 concurrent: bool = False,
                 cost_model: Optional[CostModel] = None) -> ExecutionResult:
    amap = plan.engine_map(query)
    migrator = Migrator(cost_model=cost_model)
    values: Dict[int, Any] = {}
    per_node: Dict[int, float] = {}
    node_obs: List[Tuple[str, str, float, float]] = []
    size_obs: Dict[int, float] = {}
    t0 = time.perf_counter()
    n_levels = 0

    if concurrent:
        lvls = topo_levels(query)
        n_levels = len(lvls)
        for level in lvls:
            outs = []
            for node in level:              # dispatch whole level, no blocking
                eng = ENGINES[amap[node.uid]]
                args = _gather_args(node, eng, catalog, values, migrator)
                out = eng.run(node.op, node.attrs, *args)
                values[node.uid] = out
                outs.append(out)
            for out in outs:                # one block at the level boundary
                _block(out)
    else:
        for node in query.nodes():          # post-order
            eng = ENGINES[amap[node.uid]]
            args = _gather_args(node, eng, catalog, values, migrator)
            elems = sum(container_elems(a) for a in args)
            tn = time.perf_counter()
            out = eng.run(node.op, node.attrs, *args)
            _block(out)
            dt = time.perf_counter() - tn
            per_node[node.uid] = dt
            node_obs.append((eng.name, node.op, elems, dt))
            values[node.uid] = out

    result = _deliver(query, values[query.uid])
    total = time.perf_counter() - t0
    # size measurement happens OUTSIDE the timed window: observed_nbytes can
    # touch host memory (columnar validity sum) and must not inflate the
    # seconds the monitor records and the replan comparison consumes
    for pos, node in enumerate(query.nodes()):
        size_obs[pos] = observed_nbytes(values[node.uid])
    return ExecutionResult(result, total, migrator.bytes_moved,
                           migrator.n_casts, plan, per_node, node_obs,
                           list(migrator.events), n_levels, size_obs)
