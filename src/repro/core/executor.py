"""Executor (paper §III-C / [19]): runs a plan tree — resolves refs against
the catalog, migrates inputs to each node's engine via the migrator, invokes
the shim (engine op), and collects wall time + cast statistics for the
monitor.

Two dispatch modes:

  sequential (default) — blocks after every node, yielding honest per-node
      timings; these feed the calibrated cost model (training phase).
  concurrent — groups the DAG into topological levels and submits every node
      in a level (including its multi-hop input casts) to a shared host
      ``ThreadPoolExecutor``.  Numpy-eager engine work — columnar joins, COO
      conversions, cast hops — releases the GIL on real arrays, so host work
      genuinely overlaps across workers, on top of JAX async dispatch
      overlapping the device work.  One barrier per level (futures are
      drained before the next level starts).  Used by the production phase,
      where per-node attribution is not needed.  In auto mode
      (``host_workers=None``) a level is threaded only when at least two of
      its tasks each move ``HOST_TASK_MIN_BYTES`` of input — tiny XLA-bound
      levels stay inline, where single-threaded async dispatch is already
      optimal; ``host_workers<=1`` falls back to inline single-threaded
      level dispatch (the pre-PR-3 behavior), and a single-node level always
      runs inline (no pool round-trip).

Both modes report each node's *actual* logical output size (``size_obs``)
and dense-equivalent output shape (``shape_obs``), keyed by post-order
position, so the monitor can feed real intermediate sizes AND shapes back
into the planner's estimates — the other half of the §III-C feedback loop
(a measured select size overrides the bytes rule; a measured shape feeds
downstream matmul output estimates).  When a ``cost_model`` is supplied, the
migrator routes casts along the model's cheapest (possibly multi-hop) path,
each hop sized from its intermediate format, instead of always taking the
direct pair.

The host pool is process-wide and lazily built (``host_pool``): plans are
short-lived but frequent on the serving path, and thread churn per plan
would dominate the win.  Do not call ``execute_plan`` from inside a pool
worker — a saturated pool could deadlock on the level barrier.
"""
from __future__ import annotations

import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.core.costmodel import (CostModel, container_elems, observed_nbytes,
                                  observed_shape)
from repro.core.engines import ENGINES
from repro.core.migrator import Migrator
from repro.core.ops import PolyOp, Ref
from repro.core.planner import Plan

# the data model a query's result is delivered in = its root island's model
ISLAND_KIND = {"array": "dense", "relational": "columnar", "text": "coo",
               "stream": "stream"}

# default size of the shared host pool; override per call via host_workers=
# or process-wide via REPRO_HOST_WORKERS
DEFAULT_HOST_WORKERS = min(8, os.cpu_count() or 1)

# auto mode (host_workers=None) threads a level only when at least two of
# its nodes each move this many input bytes: small-payload levels are
# XLA-dispatch-bound, and multi-threaded dispatch of many tiny ops pays lock
# contention for zero overlap (measured ~0.6x on fig_host_parallel's
# pipeline family).  An explicit host_workers forces threading regardless.
HOST_TASK_MIN_BYTES = 1e6

_POOL: Optional[ThreadPoolExecutor] = None
_POOL_SIZE = 0
_POOL_LOCK = threading.Lock()


def host_pool(max_workers: Optional[int] = None) -> ThreadPoolExecutor:
    """The process-wide host-task pool for concurrent dispatch (lazily
    created; rebuilt only if a larger size is requested)."""
    global _POOL, _POOL_SIZE
    want = max_workers or int(os.environ.get("REPRO_HOST_WORKERS", 0)) \
        or DEFAULT_HOST_WORKERS
    with _POOL_LOCK:
        if _POOL is None or _POOL_SIZE < want:
            # a superseded pool is NOT shut down: another plan may still
            # hold a reference and submit to it (shutdown would raise
            # RuntimeError mid-plan).  Its idle threads simply park until
            # process exit; pool growth happens at most a handful of times.
            _POOL = ThreadPoolExecutor(max_workers=want,
                                       thread_name_prefix="bigdawg-host")
            _POOL_SIZE = want
        return _POOL


@dataclass
class ExecutionResult:
    value: Any
    seconds: float
    cast_bytes: float
    n_casts: int
    plan: Plan
    per_node_seconds: Dict[int, float] = field(default_factory=dict)
    # measured (engine, op, input_elems, seconds) per node — sequential only
    node_obs: List[Tuple[str, str, float, float]] = field(default_factory=list)
    # measured (src_kind, dst_kind, bytes, seconds) per cast
    cast_obs: List[Tuple[str, str, float, float]] = field(default_factory=list)
    levels: int = 0                     # topological depth actually dispatched
    # post-order position -> measured logical output bytes (both modes) —
    # the monitor stores these per signature for size-estimate feedback
    size_obs: Dict[int, float] = field(default_factory=dict)
    # post-order position -> measured dense-equivalent output shape (both
    # modes, where the format carries one) — shape feedback for downstream
    # matmul/transpose output estimates
    shape_obs: Dict[int, Tuple[int, ...]] = field(default_factory=dict)


def _block(x):
    """Block on all device buffers in a container (honest timing)."""
    for leaf in jax.tree.leaves(getattr(x, "__dict__", x)):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return x


def topo_levels(query: PolyOp) -> List[List[PolyOp]]:
    """Nodes grouped by topological depth; everything within a level is
    mutually independent and can be dispatched together."""
    depth: Dict[int, int] = {}
    levels: List[List[PolyOp]] = []
    for node in query.nodes():              # post-order: inputs first
        if node.uid in depth:               # shared subtree: already placed
            continue
        d = 0
        for inp in node.inputs:
            if isinstance(inp, PolyOp):
                d = max(d, depth[inp.uid] + 1)
        depth[node.uid] = d
        while len(levels) <= d:
            levels.append([])
        levels[d].append(node)
    return levels


def _node_input_nbytes(node: PolyOp, catalog, values) -> float:
    """Physical bytes this node's inputs occupy right now — the cheap proxy
    the auto-threading gate uses for 'is this task heavy enough to overlap'."""
    total = 0.0
    for inp in node.inputs:
        if isinstance(inp, Ref):
            if catalog is not None and inp.name in catalog:
                total += float(getattr(catalog[inp.name].obj, "nbytes", 0.0))
        else:
            total += float(getattr(values.get(inp.uid), "nbytes", 0.0) or 0.0)
    return total


def _gather_args(node: PolyOp, eng, catalog, values, migrator):
    args = []
    for inp in node.inputs:
        if isinstance(inp, Ref):
            obj = catalog[inp.name].obj
        else:
            obj = values[inp.uid]
        args.append(migrator.to_engine(obj, eng.name))
    return args


def _deliver(query: PolyOp, result):
    """Deliver in the root island's data model (location transparency: the
    caller sees the island model regardless of which engine produced it)."""
    if query.island in ISLAND_KIND:
        want = ISLAND_KIND[query.island]
    else:                                    # degenerate:<engine>
        want = ENGINES[query.island.split(":", 1)[1]].kind
    if getattr(result, "kind", want) != want:
        from repro.core import cast as castmod
        result = castmod.cast(result, want)
        _block(result)
    return result


def execute_plan(query: PolyOp, plan: Plan, catalog,
                 concurrent: bool = False,
                 cost_model: Optional[CostModel] = None,
                 host_workers: Optional[int] = None) -> ExecutionResult:
    amap = plan.engine_map(query)
    migrator = Migrator(cost_model=cost_model)
    values: Dict[int, Any] = {}
    per_node: Dict[int, float] = {}
    node_obs: List[Tuple[str, str, float, float]] = []
    size_obs: Dict[int, float] = {}
    shape_obs: Dict[int, Tuple[int, ...]] = {}
    t0 = time.perf_counter()
    n_levels = 0

    def run_node(node: PolyOp):
        """One host task: migrate inputs (possibly multi-hop casts) and run
        the engine op — the numpy-eager parts release the GIL, so tasks of
        one level overlap on the pool.  Deliberately does NOT block on the
        result: XLA-backed ops stay async (dispatch returns immediately;
        blocking here would serialize the device pipeline behind each
        worker), and the level boundary blocks everything once."""
        eng = ENGINES[amap[node.uid]]
        tn = time.perf_counter()
        args = _gather_args(node, eng, catalog, values, migrator)
        out = eng.run(node.op, node.attrs, *args)
        per_node[node.uid] = time.perf_counter() - tn
        return node.uid, out

    if concurrent:
        lvls = topo_levels(query)
        n_levels = len(lvls)
        workers = host_workers if host_workers is not None else \
            int(os.environ.get("REPRO_HOST_WORKERS", 0)) or \
            DEFAULT_HOST_WORKERS
        pool = host_pool(workers) if workers > 1 else None
        for level in lvls:
            outs = []
            use_pool = pool is not None and len(level) > 1
            if use_pool and host_workers is None:
                # auto mode: thread only when >= 2 tasks are heavy enough to
                # overlap (see HOST_TASK_MIN_BYTES)
                heavy = sum(1 for n in level
                            if _node_input_nbytes(n, catalog, values)
                            >= HOST_TASK_MIN_BYTES)
                use_pool = heavy >= 2
            if not use_pool:
                for node in level:           # inline fallback / trivial level
                    uid, out = run_node(node)
                    values[uid] = out
                    outs.append(out)
            else:
                # one future per node; .result() re-raises the first worker
                # exception in submission order — a failing node fails the
                # plan, it does not vanish into the pool
                futs = [pool.submit(run_node, node) for node in level]
                for fut in futs:
                    uid, out = fut.result()
                    values[uid] = out
                    outs.append(out)
            for out in outs:                 # one block per level boundary
                _block(out)
    else:
        for node in query.nodes():          # post-order
            eng = ENGINES[amap[node.uid]]
            args = _gather_args(node, eng, catalog, values, migrator)
            elems = sum(container_elems(a) for a in args)
            tn = time.perf_counter()
            out = eng.run(node.op, node.attrs, *args)
            _block(out)
            dt = time.perf_counter() - tn
            per_node[node.uid] = dt
            node_obs.append((eng.name, node.op, elems, dt))
            values[node.uid] = out

    result = _deliver(query, values[query.uid])
    total = time.perf_counter() - t0
    # size/shape measurement happens OUTSIDE the timed window: observed_nbytes
    # can touch host memory (columnar validity sum) and must not inflate the
    # seconds the monitor records and the replan comparison consumes
    for pos, node in enumerate(query.nodes()):
        size_obs[pos] = observed_nbytes(values[node.uid])
        shp = observed_shape(values[node.uid])
        if shp is not None:
            shape_obs[pos] = shp
    return ExecutionResult(result, total, migrator.bytes_moved,
                           migrator.n_casts, plan, per_node, node_obs,
                           list(migrator.events), n_levels, size_obs,
                           shape_obs)
