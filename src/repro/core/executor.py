"""Executor (paper §III-C / [19]): runs a plan tree — resolves refs against
the catalog, migrates inputs to each node's engine via the migrator, invokes
the shim (engine op), and collects wall time + cast statistics for the
monitor."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict

import jax

from repro.core.engines import ENGINES
from repro.core.migrator import Migrator
from repro.core.ops import PolyOp, Ref
from repro.core.planner import Plan

# the data model a query's result is delivered in = its root island's model
ISLAND_KIND = {"array": "dense", "relational": "columnar", "text": "coo",
               "stream": "stream"}


@dataclass
class ExecutionResult:
    value: Any
    seconds: float
    cast_bytes: float
    n_casts: int
    plan: Plan
    per_node_seconds: Dict[int, float] = field(default_factory=dict)


def _block(x):
    """Block on all device buffers in a container (honest timing)."""
    for leaf in jax.tree.leaves(getattr(x, "__dict__", x)):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return x


def execute_plan(query: PolyOp, plan: Plan, catalog) -> ExecutionResult:
    amap = plan.engine_map(query)
    migrator = Migrator()
    values: Dict[int, Any] = {}
    per_node: Dict[int, float] = {}
    t0 = time.perf_counter()

    for node in query.nodes():                  # post-order
        eng = ENGINES[amap[node.uid]]
        args = []
        for inp in node.inputs:
            if isinstance(inp, Ref):
                obj = catalog[inp.name].obj
            else:
                obj = values[inp.uid]
            args.append(migrator.to_engine(obj, eng.name))
        tn = time.perf_counter()
        out = eng.run(node.op, node.attrs, *args)
        _block(out)
        per_node[node.uid] = time.perf_counter() - tn
        values[node.uid] = out

    # deliver in the root island's data model (location transparency: the
    # caller sees the island model regardless of which engine produced it)
    result = values[query.uid]
    if query.island in ISLAND_KIND:
        want = ISLAND_KIND[query.island]
    else:                                        # degenerate:<engine>
        want = ENGINES[query.island.split(":", 1)[1]].kind
    if getattr(result, "kind", want) != want:
        from repro.core import cast as castmod
        result = castmod.cast(result, want)
        _block(result)

    total = time.perf_counter() - t0
    return ExecutionResult(result, total, migrator.bytes_moved,
                           migrator.n_casts, plan, per_node)
