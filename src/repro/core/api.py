"""The user-facing polystore API — ``connect() -> Session``.

This is the front door the paper's client surface implies (§III: applications
speak to the middleware, which spans islands): one object that owns the
middleware stack (catalog + planner + monitor + executor + plan cache),
exposes the islands, executes queries — programmatic ``PolyOp`` trees, the
textual ``BIGDAWG(ISLAND(...))`` syntax, or a mix — and returns structured
``Result``s instead of the middleware's raw ``Report``.

    from repro.core import connect, DenseTensor

    s = connect("state/monitor.json", explore_budget=0.5)
    s.register("A", table_a, engine="columnar")
    s.register("B", table_b, engine="columnar")
    s.register("W", DenseTensor(w), engine="dense_array")

    # textual (the demo-paper surface) ...
    res = s.execute("RELATIONAL(join(A, B, left_on=key, right_on=key)) "
                    "|> ARRAY(matmul(_, W))")
    # ... or programmatic, with explicit island boundaries
    isl = s.islands
    q = isl.array.matmul(isl.array.scope(
            isl.relational.join("A", "B", left_on="key", right_on="key")),
            "W")
    res = s.execute(q)

    res.value               # the container, in the root island's data model
    res.islands             # ('relational', 'array') — every island involved
    res.provenance          # ('relational.join@columnar',
                            #  'array.scope@dense_array',
                            #  'array.matmul@dense_array')
    res.per_node_seconds    # post-order position -> measured seconds
    res.cast_bytes          # bytes the migrator moved across boundaries

    srv = s.server(max_pending=64)   # bounded-admission QueryServer

``BigDAWG.execute`` (returning the raw ``Report``) and the module-level
island objects (``repro.core.array`` etc.) remain supported as the low-level
API — ``Session`` is a veneer over them, so both surfaces share one catalog,
one plan cache, and one monitor history.
"""
from __future__ import annotations

from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.core import islands as islands_mod
from repro.core import qlang
from repro.core.engines import ENGINES
# the error taxonomy is part of the public API surface: sessions raise these
# (re-exported here so `from repro.core.api import EngineDown` works)
from repro.core.errors import (BigDAWGError, EngineDown, Overloaded,
                               PlanInfeasible, QueryParseError)
from repro.core.health import EngineHealth
from repro.core.middleware import BigDAWG, Report, _plan_from_key
from repro.core.monitor import Monitor
from repro.core.ops import PolyOp
from repro.core.reqpool import RequestPool


class IslandNamespace:
    """The islands a session can scope query fragments to — handles for
    ``session.islands.relational / .array / .text / .stream`` plus
    ``.degenerate(engine)`` (full power of one engine, zero location
    transparency, paper §III-B)."""

    def __init__(self):
        self.array = islands_mod.array
        self.relational = islands_mod.relational
        self.text = islands_mod.text
        self.stream = islands_mod.stream

    @staticmethod
    def degenerate(engine: str) -> islands_mod.Island:
        isl = islands_mod.ISLANDS.get(f"degenerate:{engine}")
        if isl is None:
            raise ValueError(f"no degenerate island for engine {engine!r}; "
                             f"engines: {', '.join(sorted(ENGINES))}")
        return isl


@dataclass(frozen=True)
class Result:
    """A structured query result: the value plus full plan provenance.

    ``provenance`` names, per post-order node, the island that governed it,
    the operator, and the engine the planner placed it on —
    ``"relational.join@columnar"`` — so a cross-island query's answer says
    exactly which islands took part (``islands``) and where every seam was
    cast.  ``per_node_seconds`` is keyed by post-order position (the same
    stable key plan keys and size feedback use)."""
    value: Any
    sig: str
    mode: str                      # "training" | "production"
    seconds: float
    cast_bytes: float
    plan_key: str
    provenance: Tuple[str, ...]    # per node: "island.op@engine"
    islands: Tuple[str, ...]       # distinct islands, first-appearance order
    per_node_seconds: Dict[int, float] = field(default_factory=dict)
    report: Optional[Report] = None    # the raw middleware report
    # -- resilience surface (meaningful when the middleware has a health
    #    registry; defaults describe a healthy, unmasked serve) ------------
    status: str = "ok"             # "ok" | "degraded"  (an Overloaded slot
    #                                from submit_many carries "shed")
    degraded: bool = False         # planned under an engine mask
    failovers: int = 0             # EngineDown retries this request survived
    # position groups that executed as single compiled segments (plan-level
    # kernel fusion; empty on training serves or with fuse=False)
    fused_segments: Tuple[Tuple[int, ...], ...] = ()
    # served by patching a materialized view with a delta fragment (or by
    # the view verbatim) instead of recomputing — streaming/IVM serves
    incremental: bool = False
    # the request's completed span tree (core.tracing.Trace) when the
    # session was opened with trace=True; None otherwise.  Inspect with
    # .trace.tree() / .trace.find("engine_op") or export .trace.to_json()
    trace: Any = None

    def describe(self) -> str:
        return " -> ".join(self.provenance)


def _result_from_report(query: PolyOp, rep: Report) -> Result:
    nodes = query.nodes()
    if getattr(rep, "shards", 0):
        # scatter–gather result: plan_key describes ONE shard fragment
        # (possibly scope-wrapped, so its node positions need not align
        # with the query's) — per-node provenance is not meaningful for
        # the merged whole
        provenance: Tuple[str, ...] = ()
    else:
        amap = dict(_plan_from_key(rep.plan_key).assignment)
        provenance = tuple(f"{n.island}.{n.op}@{amap[i]}"
                           for i, n in enumerate(nodes))
    seen: Dict[str, None] = {}
    for n in nodes:
        seen.setdefault(n.island)
    return Result(value=rep.result, sig=rep.sig, mode=rep.mode,
                  seconds=rep.seconds, cast_bytes=rep.cast_bytes,
                  plan_key=rep.plan_key, provenance=provenance,
                  islands=tuple(seen), per_node_seconds=rep.per_node_seconds,
                  report=rep, status=getattr(rep, "status", "ok"),
                  degraded=getattr(rep, "degraded", False),
                  failovers=getattr(rep, "failovers", 0),
                  fused_segments=getattr(rep, "fused_segments", ()),
                  incremental=getattr(rep, "incremental", False),
                  trace=getattr(rep, "trace", None))


class Session:
    """A connection to one middleware instance (see module docstring).

    Thread-safe to the same degree as the underlying ``BigDAWG``: ``execute``
    may be called from many threads (per-signature locking trains a cold
    signature exactly once); for managed concurrent admission use
    ``server()``."""

    def __init__(self, bigdawg: BigDAWG):
        self.bigdawg = bigdawg
        self.islands = IslandNamespace()
        # the session's request pool (PR 4 pattern, shared idiom with
        # QueryServer/BatchServer): execute_async futures and map batches
        # run here, NOT on the executor's host pool — request threads block
        # on level barriers and would starve the pool running the levels
        self._requests = RequestPool(thread_name_prefix="bigdawg-session")

    @property
    def catalog(self):
        return self.bigdawg.catalog

    def register(self, name: str, obj, engine: str,
                 shards: Optional[int] = None,
                 streaming: bool = False) -> "Session":
        """Home a container on an engine under ``name`` (casting it to the
        engine's native data model if needed).  ``shards=N`` additionally
        row-range splits the table for scatter–gather execution (shard
        parts are registered as ``name#i``; on a ``processes=`` session
        part ``i`` lives only on worker ``i % processes``).
        ``streaming=True`` declares an append-able STREAM-island table:
        ``session.append(name, rows)`` grows it, and warm serves over it
        may be patched incrementally from materialized views instead of
        recomputing (see ``connect(incremental=)``).  Returns the session,
        so registrations chain."""
        self.bigdawg.register(name, obj, engine, shards=shards,
                              streaming=streaming)
        return self

    def append(self, name: str, rows) -> int:
        """Append rows to a streaming registration (the STREAM island's
        ingest path) and return the table's new version.  The next serve of
        any cached query over ``name`` either patches its materialized view
        with the appended suffix (``Result.incremental`` is then True) or
        recomputes in full, whichever the cost model prices cheaper."""
        return self.bigdawg.append(name, rows)

    def parse(self, text: str) -> PolyOp:
        """Compile the textual ``BIGDAWG(ISLAND(...))`` / ``|>`` syntax to
        the PolyOp IR without executing it (``qlang.bigdawg``)."""
        return qlang.bigdawg(text)

    def execute(self, query: Union[PolyOp, str], mode: str = "auto") -> Result:
        """Plan and run a query — a ``PolyOp`` tree or a textual qlang
        string — and return a structured ``Result``.  ``mode`` follows the
        paper's protocol: ``"training"`` enumerates and measures candidate
        plans, ``"production"`` serves from the signature-keyed plan cache,
        ``"auto"`` picks by signature history."""
        if isinstance(query, str):
            query = qlang.bigdawg(query)
        return _result_from_report(query, self.bigdawg.execute(query, mode))

    def execute_async(self, query: Union[PolyOp, str], mode: str = "auto",
                      workers: Optional[int] = None) -> "Future[Result]":
        """``execute`` off the calling thread: returns a
        ``concurrent.futures.Future`` resolving to the ``Result`` (or
        carrying the structured ``BigDAWGError`` — ``EngineDown`` after
        failover exhaustion, ``PlanInfeasible``, ... — via
        ``future.exception()``).  A textual query is parsed EAGERLY, so a
        ``QueryParseError`` raises here at the call site, not inside the
        future — a syntactically-broken query should fail fast, not
        asynchronously.  Futures run on the session's request pool
        (``workers`` grows it); the middleware's per-signature locking makes
        any interleaving safe."""
        if isinstance(query, str):
            query = qlang.bigdawg(query)
        return self._requests.submit(self.execute, query, mode,
                                     workers=workers)

    def map(self, queries: Sequence[Union[PolyOp, str]], mode: str = "auto",
            workers: Optional[int] = None) -> List[Result]:
        """Execute a batch concurrently on the request pool and return the
        ``Result``s in input order (``workers<=1`` runs sequentially).  All
        textual queries are parsed up front — one malformed query fails the
        whole batch before anything executes.  The first structured error
        raised by a query propagates, input-order first."""
        parsed = [qlang.bigdawg(q) if isinstance(q, str) else q
                  for q in queries]
        return self._requests.map_ordered(
            lambda q: self.execute(q, mode), parsed, workers=workers)

    def server(self, max_pending: Optional[int] = None,
               latency_target_s: Optional[float] = None):
        """A ``QueryServer`` over this session's middleware — concurrent
        admission (``submit_many``/``serve``) with optional bounded
        admission: with ``max_pending=N``, batch overflow beyond N in-flight
        requests is shed (``stats["shed"]``) instead of queued;
        ``latency_target_s`` switches to the adaptive AIMD bound with
        degrade-before-shed (see ``QueryServer``)."""
        from repro.runtime.server import QueryServer
        return QueryServer(self.bigdawg, max_pending=max_pending,
                           latency_target_s=latency_target_s)

    def metrics(self, merged: bool = True) -> Dict[str, Any]:
        """Point-in-time snapshot of the middleware's telemetry registry:
        ``{"counters", "gauges", "histograms"}`` (histograms summarized as
        count/sum/p50/p95/p99).  With ``merged=True`` (default) and a
        ``state_path``-backed session, persisted sections from other
        processes (procpool workers, earlier lives) are folded in.  Empty
        snapshot when the backing middleware carries no registry."""
        reg = getattr(self.bigdawg, "metrics", None)
        if reg is None:
            return {"counters": {}, "gauges": {}, "histograms": {}}
        return reg.snapshot(merged=merged)

    def persist(self) -> None:
        """Flush monitor DB, calibration and plan cache (waiting for
        in-flight background explorations first) so a later ``connect`` to
        the same path starts warm."""
        self.bigdawg.persist()

    def close(self) -> None:
        """Release backend resources: a ``processes=`` session stops its
        worker pool; an in-process session is a no-op.  Sessions are also
        context managers (``with connect(processes=4) as s: ...``)."""
        closer = getattr(self.bigdawg, "close", None)
        if closer is not None:
            closer()

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


def connect(state_path: Optional[str] = None, *,
            monitor: Optional[Monitor] = None,
            bigdawg: Optional[BigDAWG] = None,
            resilient: bool = False,
            processes: Optional[int] = None,
            **bigdawg_kwargs) -> Session:
    """Open a polystore session.

    ``state_path`` — optional monitor-DB path; the calibration file and the
    plan cache ride beside it (``<root>.calib.json`` / ``<root>.plans.json``),
    so a second ``connect`` to the same path serves previously-trained
    signatures warm.  ``monitor`` passes a pre-built Monitor instead (e.g.
    with a custom ``decay``); ``bigdawg`` wraps an existing middleware
    instance as-is.  ``resilient=True`` attaches a default
    ``core.health.EngineHealth`` registry — per-engine circuit breakers with
    failover re-planning (pass ``health=EngineHealth(...)`` instead to tune
    thresholds or plug in a fault injector).  Remaining keyword arguments go
    to ``BigDAWG`` — ``train_plans``, ``explore_budget``, ``calibrate``,
    ``replan_factor``, ``health``, ``fuse`` (plan-level kernel fusion of
    warm serves, default on; ``fuse=False`` forces node-by-node dispatch),
    ``incremental`` (streaming IVM: ``True`` — the default — patches
    materialized views after ``append()`` when the cost model prices the
    delta path cheaper than recomputing, ``"force"`` skips the gate,
    ``False`` disables materialization entirely), ``trace`` (``trace=True``
    records a per-request span tree on every ``Result.trace`` — including
    worker-side spans on a ``processes=`` session), ``metrics_path``
    (where the telemetry registry persists; defaults to
    ``<root>.metrics.json`` beside the monitor DB)...

    ``processes=N`` backs the session with a ``core.procpool.ProcPool`` —
    N worker processes each running a full middleware stack, sharing plans
    and monitor history through the ``state_path`` files, with sharded
    scatter–gather execution for ``register(..., shards=)`` tables.  Close
    the session (or use it as a context manager) to stop the workers.
    """
    if processes is not None and processes > 1:
        if bigdawg is not None or monitor is not None:
            raise ValueError("processes= builds its own per-worker "
                             "middleware; it cannot be combined with "
                             "bigdawg=/monitor=")
        from repro.core.procpool import ProcPool
        return Session(ProcPool(processes=processes, state_path=state_path,
                                resilient=resilient, **bigdawg_kwargs))
    if bigdawg is not None:
        if state_path or monitor or resilient or bigdawg_kwargs:
            raise ValueError("bigdawg= wraps an existing instance; it cannot "
                             "be combined with state_path/monitor/kwargs")
        return Session(bigdawg)
    if resilient and "health" not in bigdawg_kwargs:
        bigdawg_kwargs["health"] = EngineHealth()
    if monitor is None and state_path is not None:
        monitor = Monitor(state_path)
    return Session(BigDAWG(monitor=monitor, **bigdawg_kwargs))
