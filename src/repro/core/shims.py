"""Shims (paper §III-C-2): adapters from an island's operator vocabulary to an
engine's native implementation.

The shim table is derived from the engine op registries plus explicit
adapters; ``resolve(island, op, engine)`` is what the executor invokes.  A
missing shim means that island/engine pair cannot run the op — the planner
must cast to an engine that can (partial coverage is a feature of the paper's
design, not an error).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

from repro.core.engines import ENGINES
from repro.core.islands import ISLANDS

# explicit adapters for island-op -> engine-op name mismatches
_RENAMES: Dict[Tuple[str, str], str] = {
    # text island "spmm" is the Graphulo server-side sparse multiply
    ("text", "matmul"): "spmm",
}


def resolve(island: str, op: str, engine: str) -> Optional[Callable]:
    eng = ENGINES[engine]
    name = _RENAMES.get((island, op), op)
    return eng.ops.get(name)


def shim_table() -> Dict[Tuple[str, str, str], str]:
    """Enumerate every legal (island, op, engine) triple — used by tests and
    the DESIGN.md inventory."""
    table = {}
    for iname, island in ISLANDS.items():
        for op, engines in island.ops.items():
            for e in engines:
                if resolve(iname, op, e) is not None:
                    table[(iname, op, e)] = _RENAMES.get((iname, op), op)
    return table


def validate() -> None:
    """Every advertised island op/engine pair must have a shim."""
    missing = []
    for iname, island in ISLANDS.items():
        for op, engines in island.ops.items():
            for e in engines:
                if resolve(iname, op, e) is None:
                    missing.append((iname, op, e))
    if missing:
        raise RuntimeError(f"islands advertise ops without shims: {missing}")
