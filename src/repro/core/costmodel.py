"""Calibrated planner cost model (paper §III-C): predicted seconds for
engine ops and casts, learned from two sources —

  1. a one-shot *microbenchmark calibration* pass (``CostModel.calibrate``)
     that measures per-engine per-op throughput (elements/s) and per-cast-pair
     bandwidth (bytes/s) on small containers, and
  2. *monitor history*: every measured execution feeds per-node op timings and
     per-cast transfer timings back via ``observe_op`` / ``observe_cast``.

Cast predictions route *multi-hop*: ``cast_route`` searches the registered
cast graph for the cheapest path under the calibrated per-pair bandwidths, so
e.g. coo->dense->columnar wins over a direct coo->columnar pair that has been
measured slow.  Multi-hop routes are only trusted when every edge on them has
been observed — optimistic defaults never beat a real measurement.  Each hop
is sized from the format the data is in when that hop starts (pass
``kind_nbytes``, see ``kind_nbytes_from_logical`` /
``container_kind_nbytes``): a coo->dense hop *densifies* the payload, so the
following dense->columnar hop must be charged for the inflated dense bytes,
not the original COO triple bytes.

Beyond op and cast rates, the model learns the **host thread-dispatch
overhead** (``observe_dispatch`` / ``dispatch_overhead_s``): the measured
cost of a submit→result round trip through the executor's host pool on THIS
machine.  The executor's auto-threading gate compares each task's predicted
seconds against a multiple of this overhead — a task must dwarf the pool
round trip to be worth dispatching — replacing the old static byte
threshold (see ``executor.execute_plan``).

The model is **thread-safe**: every observation and every prediction takes
an internal lock (concurrent production serves, training runs, and
background exploration all read and write it), and ``save`` snapshots under
the same lock.

Persistence: the model is saved as JSON *beside the monitor DB*
(``default_calibration_path`` maps ``monitor.json`` -> ``monitor.calib.json``)
through ``ioutil.atomic_json_dump`` — a same-directory temp file moved into
place with ``os.replace``, so a crash mid-save can never truncate the file.
The blob stores each running mean with its sample count::

    {"calibrated": true,
     "op_rate":   {"dense_array": {"matmul": [5.2e8, 3]}},   # elems/s, n
     "cast_rate": {"dense>columnar": [1.8e8, 2]},            # bytes/s, n
     "dispatch_overhead": [2.1e-4, 5]}                       # s/round-trip, n

Worked example (everything round-trips through one file)::

    >>> cm = CostModel("/tmp/demo.calib.json")
    >>> cm.observe_op("dense_array", "matmul", elems=1e6, seconds=0.002)
    >>> cm.observe_cast("dense", "coo", nbytes=4e6, seconds=0.01)
    >>> cm.save()                              # atomic write
    >>> cm2 = CostModel("/tmp/demo.calib.json")    # fresh process: warm start
    >>> round(cm2.op_seconds("dense_array", "matmul", 1e6), 4)
    0.0021
    >>> cm2.cast_route("dense", "coo", 4e6)[1]     # calibrated direct route
    ['dense', 'coo']

All predictions degrade gracefully: an unobserved (engine, op) pair falls
back to the engine's measured mean, then to a per-kind default.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.ioutil import atomic_json_dump, load_json

# a-priori throughput guesses per engine *kind* (elements/s on one host core);
# only used before any calibration/history exists.  Relative order encodes the
# engines' real strengths (dense MXU-shaped ops beat triple-scan layouts).
_DEFAULT_ELEMS_PER_S = {
    "dense": 5e8,
    "columnar": 1e8,
    "coo": 1.5e8,
    "stream": 3e8,
}
_DEFAULT_CAST_BYTES_PER_S = 2e8     # host-side format conversion, not ICI
# fixed per-dispatch overhead (python + jax dispatch), seconds
_OP_OVERHEAD_S = 5e-5
_CAST_OVERHEAD_S = 1e-4
# a-priori host-pool submit->result round-trip cost, before any measurement
_DEFAULT_DISPATCH_OVERHEAD_S = 2e-4


@dataclass
class _Mean:
    """Running mean with sample count (JSON-serializable)."""
    mean: float = 0.0
    n: int = 0

    def update(self, v: float):
        self.mean = (self.mean * self.n + v) / (self.n + 1)
        self.n += 1


def container_elems(obj) -> float:
    """LOGICAL element count of a tables.* container — the throughput unit.

    Columnar/COO count rows/nnz, not physical cells: the planner predicts
    from dense-equivalent sizes (it cannot know per-engine layouts of
    intermediates), so observed rates must use the same unit or row-store
    throughput gets inflated by the triples blow-up factor."""
    kind = getattr(obj, "kind", None)
    if kind == "dense":
        return float(obj.data.size)
    if kind == "columnar":
        return float(obj.nrows)
    if kind == "coo":
        return float(obj.nnz)
    if kind == "stream":
        return float(obj.data.size)
    return float(getattr(obj, "nbytes", 4)) / 4.0


def observed_nbytes(obj) -> float:
    """Measured LOGICAL output bytes of a container — the size-feedback unit
    the executor reports and ``Monitor`` stores per signature.

    Logical = the data an op semantically produced, not its physical layout:
    a dense select keeps its padded shape but only ``valid_count`` live cells,
    a columnar select masks rows without compacting, a COO result carries
    ``nnz`` triples.  This is what downstream cast volume and data-dependent
    op output (select/join/distinct) actually scale with — the quantity the
    planner's shape rules can only guess at.

    The unit is the valid-aware refinement of ``container_elems`` (4 bytes per
    dense-EQUIVALENT element): columnar counts valid rows, not cells, because
    a (i, j, value) triple table's rows ARE the dense equivalent's cells —
    index/coordinate columns are layout overhead the planner deliberately
    excludes (see ``_ref_size``), and op rates were learned in this unit."""
    kind = getattr(obj, "kind", None)
    if kind == "dense":
        n = obj.valid_count if obj.valid_count >= 0 else obj.data.size
        return 4.0 * float(n)
    if kind == "columnar":
        import numpy as np
        return 4.0 * float(np.asarray(obj.valid).sum())
    if kind == "coo":
        return 4.0 * float(obj.nnz)
    if kind == "stream":
        return 4.0 * float(obj.data.size)
    return float(getattr(obj, "nbytes", 4.0))


def observed_shape(obj) -> Optional[Tuple[int, ...]]:
    """Measured dense-equivalent SHAPE of a container, or None when the
    format does not carry one cheaply (columnar tables would need a max-scan
    over index columns).  This is the shape-feedback unit the executor
    reports (``ExecutionResult.shape_obs``) and the monitor stores so
    downstream matmul/transpose output estimates use observed shapes instead
    of rule-propagated guesses."""
    kind = getattr(obj, "kind", None)
    if kind in ("dense", "stream"):
        return tuple(int(d) for d in obj.data.shape)
    if kind == "coo":
        return tuple(int(d) for d in obj.shape)
    return None


def kind_nbytes_from_logical(logical_bytes: float,
                             shape: Optional[Tuple[int, ...]] = None
                             ) -> Dict[str, float]:
    """Predicted PHYSICAL bytes of a payload held in each data-model kind,
    from its logical size (4 bytes per live element) and, when known, its
    dense-equivalent shape.

    Dense/stream layouts materialize the full shape (densification: a sparse
    payload inflates to 4 * prod(shape)); triple layouts (columnar, coo)
    carry ~3 columns (i, j, value) per live element.  This is what makes
    per-hop cast sizing honest on multi-hop routes."""
    dense_b = float(logical_bytes)
    if shape:
        n = 1.0
        for d in shape:
            n *= d
        dense_b = 4.0 * n
    triple_b = 3.0 * float(logical_bytes)
    return {"dense": dense_b, "stream": dense_b,
            "columnar": triple_b, "coo": triple_b}


def container_kind_nbytes(obj) -> Dict[str, float]:
    """Per-kind physical bytes for an ACTUAL container (exact for the format
    the object is currently in, shape-derived estimates for the others) —
    what the migrator hands ``cast_route`` so every hop of a multi-hop cast
    is sized from its true intermediate format."""
    kn = kind_nbytes_from_logical(observed_nbytes(obj), observed_shape(obj))
    kind = getattr(obj, "kind", None)
    if kind in kn:
        kn[kind] = float(getattr(obj, "nbytes", kn[kind]))
    return kn


def _registered_cast_edges() -> Tuple[Tuple[str, str], ...]:
    """Edges of the executable cast graph (lazy: cast.py imports tables)."""
    from repro.core.cast import _CASTS
    return tuple(sorted(_CASTS))


def _simple_paths(src: str, dst: str,
                  edges: Tuple[Tuple[str, str], ...]) -> List[List[str]]:
    """All simple paths src -> dst over the registered cast edges (the kind
    graph has four nodes, so exhaustive DFS is trivially cheap)."""
    out_edges: Dict[str, List[str]] = {}
    for a, b in edges:
        out_edges.setdefault(a, []).append(b)
    paths: List[List[str]] = []

    def dfs(node: str, path: List[str]):
        if node == dst:
            paths.append(list(path))
            return
        for nxt in out_edges.get(node, ()):
            if nxt not in path:
                path.append(nxt)
                dfs(nxt, path)
                path.pop()

    dfs(src, [src])
    return paths


_PATHS_CACHE: Dict[Tuple[str, str, Tuple], List[List[str]]] = {}


def default_calibration_path(monitor_path: Optional[str]) -> Optional[str]:
    """Calibration file that rides alongside a monitor DB path."""
    if not monitor_path:
        return None
    root, _ = os.path.splitext(monitor_path)
    return root + ".calib.json"


class CostModel:
    """Predicts op and cast seconds from calibrated/learned throughputs."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        # engine -> op -> elements/s
        self.op_rate: Dict[str, Dict[str, _Mean]] = {}
        # "src>dst" (kinds) -> bytes/s
        self.cast_rate: Dict[str, _Mean] = {}
        # measured host-pool submit->result round trip on this machine — the
        # executor's predicted-seconds auto-threading gate compares against it
        self.dispatch_overhead = _Mean()
        # per-worker-count refinement: worker count -> per-task amortized
        # overhead (the executor probes at 1/2/4 workers; the gate
        # interpolates at the level's actual count).  ``dispatch_overhead``
        # stays as the legacy single-point fallback for old calib files
        self.dispatch_table: Dict[int, _Mean] = {}
        self.calibrated = False
        # guards every rate dict: observations arrive from concurrent serves
        # and background exploration while other threads predict
        self._lock = threading.RLock()
        if path and os.path.exists(path):
            self.load(path)

    # -- prediction ----------------------------------------------------------
    def op_seconds(self, engine: str, op: str, elems: float) -> float:
        """Predicted seconds for `op` on `engine` over `elems` input elements."""
        from repro.core.engines import ENGINES
        from repro.core.ops import SCOPE_OP
        if op == SCOPE_OP:
            # an island boundary is the identity on its input — all of its
            # real cost is the inter-island cast, which the planner charges
            # on the boundary edge via cast_seconds (never here, or the cast
            # would be double-counted)
            return 0.0
        rate = None
        with self._lock:
            per_op = self.op_rate.get(engine)
            if per_op:
                m = per_op.get(op)
                if m and m.n:
                    rate = m.mean
                else:                   # engine-level mean over observed ops
                    obs = [x.mean for x in per_op.values() if x.n]
                    if obs:
                        rate = sum(obs) / len(obs)
        if rate is None:
            kind = ENGINES[engine].kind if engine in ENGINES else "dense"
            rate = _DEFAULT_ELEMS_PER_S.get(kind, 1e8)
        return _OP_OVERHEAD_S + max(elems, 1.0) / max(rate, 1.0)

    def dispatch_overhead_s(self, workers: Optional[int] = None) -> float:
        """Learned per-task host-pool dispatch overhead (seconds), falling
        back to a conservative default before any measurement.

        With ``workers`` and a measured per-worker-count table, linearly
        interpolates between the bracketing measured counts (flat
        extrapolation outside the measured range); without a table — or
        without ``workers`` — the legacy single-point mean is used."""
        with self._lock:
            pts = sorted((w, m.mean) for w, m in self.dispatch_table.items()
                         if m.n)
            if workers is not None and pts:
                w = int(workers)
                if w <= pts[0][0]:
                    return pts[0][1]
                if w >= pts[-1][0]:
                    return pts[-1][1]
                for (w0, s0), (w1, s1) in zip(pts, pts[1:]):
                    if w0 <= w <= w1:
                        f = (w - w0) / float(w1 - w0)
                        return s0 + f * (s1 - s0)
            if pts:                       # table only: mean over the probes
                return sum(s for _, s in pts) / len(pts)
            if self.dispatch_overhead.n:
                return self.dispatch_overhead.mean
        return _DEFAULT_DISPATCH_OVERHEAD_S

    def _edge_seconds(self, src_kind: str, dst_kind: str, nbytes: float) -> float:
        """One hop: overhead + bytes over the (observed or default) bandwidth."""
        with self._lock:
            m = self.cast_rate.get(f"{src_kind}>{dst_kind}")
            bw = m.mean if (m and m.n) else _DEFAULT_CAST_BYTES_PER_S
        return _CAST_OVERHEAD_S + max(nbytes, 1.0) / max(bw, 1.0)

    def _edge_observed(self, src_kind: str, dst_kind: str) -> bool:
        with self._lock:
            m = self.cast_rate.get(f"{src_kind}>{dst_kind}")
            return bool(m and m.n)

    def cast_route(self, src_kind: str, dst_kind: str, nbytes: float,
                   kind_nbytes: Optional[Dict[str, float]] = None
                   ) -> Tuple[float, List[str]]:
        """(predicted seconds, hop path) of the cheapest cast route.

        Candidate routes are the direct registered pair plus every multi-hop
        simple path whose edges have ALL been observed — an uncalibrated
        default bandwidth must never make a detour look cheaper than a
        measured direct conversion.  When nothing on the graph is calibrated
        the shortest registered path (defaults) is used, and an unregistered,
        unreachable pair falls back to a direct-default estimate.

        ``kind_nbytes`` (kind -> physical bytes of this payload in that
        format, see ``kind_nbytes_from_logical``) sizes EACH HOP from the
        format the data is in when the hop starts — a coo->dense hop
        densifies, so a following dense->columnar hop moves more bytes than
        the original triples did.  Without it every hop is charged the flat
        ``nbytes`` (the pre-PR-3 behavior)."""
        if src_kind == dst_kind:
            return 0.0, [src_kind]

        def hop_bytes(kind: str) -> float:
            if kind_nbytes is not None:
                return kind_nbytes.get(kind, nbytes)
            return nbytes

        def route_cost(hops) -> float:
            return sum(self._edge_seconds(a, b, hop_bytes(a))
                       for a, b in hops)

        edges = _registered_cast_edges()
        ck = (src_kind, dst_kind, edges)
        paths = _PATHS_CACHE.get(ck)
        if paths is None:
            paths = _PATHS_CACHE[ck] = _simple_paths(src_kind, dst_kind, edges)
        best: Optional[Tuple[float, List[str]]] = None
        for path in paths:
            hops = list(itertools.pairwise(path))
            if len(hops) > 1 and not all(self._edge_observed(a, b)
                                         for a, b in hops):
                continue
            cost = route_cost(hops)
            if best is None or cost < best[0]:
                best = (cost, path)
        if best is not None:
            return best
        if paths:                       # registered routes, none fully observed:
            # cheapest under whatever mix of observed/default edge rates we
            # have — a partially-observed slow edge still steers away
            costed = [(route_cost(list(itertools.pairwise(p))), p)
                      for p in paths]
            return min(costed, key=lambda t: t[0])
        return (self._edge_seconds(src_kind, dst_kind, hop_bytes(src_kind)),
                [src_kind, dst_kind])

    def cast_seconds(self, src_kind: str, dst_kind: str, nbytes: float,
                     kind_nbytes: Optional[Dict[str, float]] = None) -> float:
        """Predicted seconds to move/convert `nbytes` between data models
        (cheapest route over the cast graph, possibly multi-hop; see
        ``cast_route`` for per-hop sizing via ``kind_nbytes``)."""
        if src_kind == dst_kind:
            return 0.0
        return self.cast_route(src_kind, dst_kind, nbytes, kind_nbytes)[0]

    # -- learning ------------------------------------------------------------
    def observe_op(self, engine: str, op: str, elems: float, seconds: float):
        if seconds <= 0 or elems <= 0:
            return
        with self._lock:
            self.op_rate.setdefault(engine, {}).setdefault(op, _Mean()) \
                .update(elems / seconds)

    def observe_cast(self, src_kind: str, dst_kind: str, nbytes: float,
                     seconds: float):
        if seconds <= 0 or nbytes <= 0:
            return
        with self._lock:
            self.cast_rate.setdefault(f"{src_kind}>{dst_kind}", _Mean()) \
                .update(nbytes / seconds)

    def observe_dispatch(self, seconds: float, workers: int = 1):
        """Fold one measured per-task host-pool dispatch overhead into the
        model (see ``executor._dispatch_overhead``): the per-worker-count
        table entry for ``workers``, plus the legacy single-point mean so
        old readers keep working."""
        if seconds <= 0:
            return
        with self._lock:
            self.dispatch_table.setdefault(int(workers), _Mean()) \
                .update(seconds)
            self.dispatch_overhead.update(seconds)

    def observe_execution(self, result):
        """Fold one measured ExecutionResult (sequential run) into the model."""
        for engine, op, elems, seconds in getattr(result, "node_obs", ()):
            self.observe_op(engine, op, elems, seconds)
        for src, dst, nbytes, seconds in getattr(result, "cast_obs", ()):
            self.observe_cast(src, dst, nbytes, seconds)

    # -- calibration ---------------------------------------------------------
    def calibrate(self, n: int = 128, repeats: int = 2):
        """One-shot microbenchmark: time a representative op per engine and
        every registered cast pair on an (n, n) container."""
        import numpy as np
        import jax
        import jax.numpy as jnp
        from repro.core import cast as castmod
        from repro.core.engines import ENGINES
        from repro.core.tables import DenseTensor

        rng = np.random.default_rng(0)
        base = DenseTensor(jnp.asarray(
            rng.normal(size=(n, n)).astype(np.float32)))
        jax.block_until_ready(base.data)

        # cast bandwidth per registered (src, dst) pair
        homed = {"dense": base}
        for (src, dst) in list(castmod._CASTS):
            try:
                if src not in homed:
                    homed[src] = castmod.cast(base, src)
                obj = homed[src]
                for _ in range(repeats):
                    t0 = time.perf_counter()
                    out = castmod.cast(obj, dst)
                    jax.block_until_ready(jax.tree.leaves(out.__dict__))
                    dt = time.perf_counter() - t0
                self.observe_cast(src, dst, obj.nbytes, dt)
            except Exception:
                continue            # pair not reachable from a dense sample

        # per-engine op throughput: cheap scans, the binary matmul (the
        # planner's dominant op), and the layout-sensitive transforms whose
        # cross-engine cost spread is widest (haar's ORDER BY + restructure in
        # a row store vs a strided slice in the array store)
        hb = {"nbins": 8, "levels": 2}
        probe = {
            "dense_array": [("count", {}), ("distinct", {}), ("tfidf", {}),
                            ("select", {"lo": 0.0}), ("matmul", {}),
                            ("haar", {"levels": 2}), ("bin_hist", dict(hb))],
            "columnar": [("count", {}), ("distinct", {"column": "value"}),
                         ("tfidf", {}),
                         ("select", {"column": "value", "lo": 0.0}),
                         ("matmul", {}),
                         ("haar", {"levels": 2}), ("bin_hist", dict(hb))],
            "kv_sparse": [("count", {}), ("distinct", {}), ("tfidf", {})],
            "stream": [("window_agg", {"fn": "mean"}), ("to_array", {}),
                       ("haar", {"levels": 2})],
        }
        for ename, ops in probe.items():
            eng = ENGINES[ename]
            try:
                inp = homed.get(eng.kind) or castmod.cast(base, eng.kind)
            except Exception:
                continue
            for op, attrs in ops:
                if not eng.supports(op):
                    continue
                args = (inp, inp) if op == "matmul" else (inp,)
                try:
                    for _ in range(repeats):
                        t0 = time.perf_counter()
                        out = eng.run(op, attrs, *args)
                        jax.block_until_ready(jax.tree.leaves(out.__dict__))
                        dt = time.perf_counter() - t0
                    elems = sum(container_elems(a) for a in args)
                    self.observe_op(ename, op, elems, dt)
                except Exception:
                    continue
        self.calibrated = True
        self.save()

    # -- persistence ---------------------------------------------------------
    def save(self, path: Optional[str] = None):
        path = path or self.path
        if not path:
            return
        with self._lock:
            blob = {
                "calibrated": self.calibrated,
                "op_rate": {e: {op: [m.mean, m.n] for op, m in ops.items()}
                            for e, ops in self.op_rate.items()},
                "cast_rate": {k: [m.mean, m.n]
                              for k, m in self.cast_rate.items()},
                "dispatch_overhead": [self.dispatch_overhead.mean,
                                      self.dispatch_overhead.n],
                "dispatch_table": {str(w): [m.mean, m.n]
                                   for w, m in self.dispatch_table.items()},
            }
        atomic_json_dump(path, blob)

    def load(self, path: str):
        blob = load_json(path)
        with self._lock:
            self.calibrated = bool(blob.get("calibrated", False))
            self.op_rate = {e: {op: _Mean(mean=m, n=cnt)
                                for op, (m, cnt) in ops.items()}
                            for e, ops in blob.get("op_rate", {}).items()}
            self.cast_rate = {k: _Mean(mean=m, n=cnt)
                              for k, (m, cnt)
                              in blob.get("cast_rate", {}).items()}
            do = blob.get("dispatch_overhead")
            if do:
                self.dispatch_overhead = _Mean(mean=float(do[0]),
                                               n=int(do[1]))
            self.dispatch_table = {int(w): _Mean(mean=float(m), n=int(cnt))
                                   for w, (m, cnt)
                                   in blob.get("dispatch_table", {}).items()}
