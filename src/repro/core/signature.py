"""Query signatures (paper §III-C-3): identity of the cross-engine remainder,
derived from (a) DAG structure, (b) referenced objects, (c) binned constants.

Island boundaries are part of identity: a ``scope`` node (``ops.SCOPE_OP``)
canonicalizes as ``<island>.scope[](<subtree>)``, so a query that pins a
subtree to another island's data model never shares history with its
unscoped sibling — they plan and execute differently (the boundary cast),
so they must not share monitor means or cached plans.

The same information a jit cache key carries — deliberately — so the
tensor-plan layer reuses this module for compiled-step plan caching.
"""
from __future__ import annotations

import hashlib
import math
from typing import Any

from repro.core.ops import PolyOp, Ref


def _bin_constant(v: Any) -> str:
    """Bucket constants so near-identical queries share signatures."""
    if isinstance(v, bool):
        return f"b{v}"
    if isinstance(v, int):
        if abs(v) <= 8:
            return f"i{v}"
        return f"i~2^{round(math.log2(abs(v)))}" + ("-" if v < 0 else "")
    if isinstance(v, float):
        if v == 0 or not math.isfinite(v):
            return f"f{v}"
        exp = math.floor(math.log10(abs(v)))
        lead = round(v / 10 ** exp)
        return f"f{lead}e{exp}"
    if isinstance(v, str):
        return f"s{v}"
    if isinstance(v, (tuple, list)):
        return "(" + ",".join(_bin_constant(x) for x in v) + ")"
    return f"o{type(v).__name__}"


def _node_str(node, catalog=None) -> str:
    if isinstance(node, Ref):
        shape = ""
        if catalog is not None and node.name in catalog:
            entry = catalog[node.name]
            obj = entry.obj
            if getattr(entry, "streaming", False):
                # streaming tables grow between serves: their shape must not
                # enter the signature, or every append would orphan the plan
                # cache / monitor history the incremental-serve path lives on
                shape = f":{obj.kind}~"
            else:
                data = getattr(obj, "data", None)
                shape = f":{obj.kind}" \
                    f"{tuple(data.shape) if data is not None else ''}"
        return f"${node.name}{shape}"
    attrs = ",".join(f"{k}={_bin_constant(v)}"
                     for k, v in sorted(node.attrs.items()))
    kids = ",".join(_node_str(i, catalog) for i in node.inputs)
    return f"{node.island}.{node.op}[{attrs}]({kids})"


def signature(query: PolyOp, catalog=None) -> str:
    s = _node_str(query, catalog)
    return hashlib.sha256(s.encode()).hexdigest()[:16]


def signature_text(query: PolyOp, catalog=None) -> str:
    """Human-readable canonical form (used in monitor dumps and tests)."""
    return _node_str(query, catalog)
