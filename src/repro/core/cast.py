"""Casts — data migration between engine formats (paper §III-C: "the Cast
operation sends information about the translation between data models and
moves the data as needed").

On a TPU deployment a cast is a resharding collective plus a layout/format
conversion; here the conversions are executed directly and the *cost model*
(bytes moved / link bandwidth + conversion cost) feeds the planner.  Dynamic-
shape conversions (dense->COO) run eagerly — on-device they would use
static-capacity buffers.

Casts INTO triple formats (columnar, coo) leave their output as **numpy**:
these conversions are eager host work, and wrapping the result in
``jnp.asarray`` would serialize concurrent host-pool workers on the XLA
transfer lock for arrays the consuming op may keep on the host anyway
(sort-merge join, the next cast hop).  The device transfer happens when a
dense consumer actually needs it — ``columnar_to_dense``/``coo_to_dense``
build device arrays, and long-lived catalog objects are homed explicitly
via ``tables.device_ready`` at registration.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.tables import COOMatrix, ColumnarTable, DenseTensor, StreamBuffer

# v5e ICI per-link bandwidth — shared with the roofline model
ICI_BYTES_PER_S = 50e9


def dense_to_columnar(d: DenseTensor) -> ColumnarTable:
    a = np.asarray(d.data)
    if a.ndim == 1:
        cols = {"i": np.arange(a.shape[0], dtype=np.int32),
                "value": a}
    elif a.ndim == 2:
        n, t = a.shape
        ii, jj = np.meshgrid(np.arange(n), np.arange(t), indexing="ij")
        cols = {"i": ii.ravel().astype(np.int32),
                "j": jj.ravel().astype(np.int32),
                "value": a.ravel()}
    else:
        raise ValueError("columnar cast supports <=2D")
    return ColumnarTable(cols)     # numpy-eager (see module docstring)


def columnar_to_dense(t: ColumnarTable, shape=None) -> DenseTensor:
    v = np.asarray(t.columns["value"])
    valid = np.asarray(t.valid)
    if "j" in t.columns:
        i = np.asarray(t.columns["i"])[valid]
        j = np.asarray(t.columns["j"])[valid]
        vv = v[valid]
        if shape is None:
            shape = (int(i.max()) + 1 if i.size else 0,
                     int(j.max()) + 1 if j.size else 0)
        out = np.zeros(shape, v.dtype)
        out[i, j] = vv
    else:
        i = np.asarray(t.columns["i"])[valid]
        vv = v[valid]
        if shape is None:
            shape = (int(i.max()) + 1 if i.size else 0,)
        out = np.zeros(shape, v.dtype)
        out[i] = vv
    return DenseTensor(jnp.asarray(out), valid_count=int(valid.sum()))


def dense_to_coo(d: DenseTensor) -> COOMatrix:
    a = np.asarray(d.data)
    assert a.ndim == 2
    r, c = np.nonzero(a != d.fill)
    return COOMatrix(r.astype(np.int32), c.astype(np.int32),
                     a[r, c], a.shape)     # numpy-eager


def coo_to_dense(m: COOMatrix) -> DenseTensor:
    out = np.zeros(m.shape, np.asarray(m.vals).dtype)
    out[np.asarray(m.rows), np.asarray(m.cols)] = np.asarray(m.vals)
    return DenseTensor(jnp.asarray(out), valid_count=m.nnz)


def coo_to_columnar(m: COOMatrix) -> ColumnarTable:
    return ColumnarTable({"i": m.rows, "j": m.cols, "value": m.vals})


def columnar_to_coo(t: ColumnarTable, shape=None) -> COOMatrix:
    valid = np.asarray(t.valid)
    r = np.asarray(t.columns["i"])[valid].astype(np.int32)
    c = np.asarray(t.columns["j"])[valid].astype(np.int32)
    v = np.asarray(t.columns["value"])[valid]
    if shape is None:
        shape = (int(r.max()) + 1 if r.size else 0,
                 int(c.max()) + 1 if c.size else 0)
    return COOMatrix(r, c, v, shape)       # numpy-eager


def stream_to_dense(s: StreamBuffer) -> DenseTensor:
    d = s.data
    if d.ndim == 2:                  # (n_windows, window_len): rows = windows
        return DenseTensor(d)
    return DenseTensor(d.reshape((-1,) + d.shape[2:]))


def dense_to_stream(d: DenseTensor) -> StreamBuffer:
    """Each row becomes one window (the ETL inverse of stream_to_dense)."""
    a = d.data
    assert a.ndim == 2, "stream cast expects (n_windows, window_len)"
    return StreamBuffer(a)


_CASTS = {
    ("dense", "columnar"): dense_to_columnar,
    ("columnar", "dense"): columnar_to_dense,
    ("dense", "coo"): dense_to_coo,
    ("coo", "dense"): coo_to_dense,
    ("coo", "columnar"): coo_to_columnar,
    ("columnar", "coo"): columnar_to_coo,
    ("stream", "dense"): stream_to_dense,
    ("dense", "stream"): dense_to_stream,
}


def can_cast(src_kind: str, dst_kind: str) -> bool:
    return src_kind == dst_kind or (src_kind, dst_kind) in _CASTS


def cast_step(obj, dst_kind: str):
    """One registered conversion hop (no routing, no fallback)."""
    if obj.kind == dst_kind:
        return obj
    return _CASTS[(obj.kind, dst_kind)](obj)


def cast_path(src_kind: str, dst_kind: str, nbytes: float = 0.0,
              cost_model=None, obj=None) -> list:
    """Hop sequence (kind names, inclusive of endpoints) for a cast.

    With a cost model: the cheapest route over the calibrated per-pair
    bandwidths (``CostModel.cast_route``) — possibly multi-hop even when a
    direct pair exists, if the direct pair has been measured slow.  When the
    actual container is at hand, pass it as ``obj`` so every hop is sized
    from its true intermediate format (coo->dense densifies; the dense
    onward hop moves more bytes than the triples did).  Without a model:
    the direct registered pair, else the legacy two-hop through dense."""
    if src_kind == dst_kind:
        return [src_kind]
    if cost_model is not None:
        kind_nbytes = None
        if obj is not None:
            from repro.core.costmodel import container_kind_nbytes
            kind_nbytes = container_kind_nbytes(obj)
        return cost_model.cast_route(src_kind, dst_kind, nbytes,
                                     kind_nbytes)[1]
    if (src_kind, dst_kind) in _CASTS:
        return [src_kind, dst_kind]
    return [src_kind, "dense", dst_kind]


def cast(obj, dst_kind: str, cost_model=None):
    for k in cast_path(obj.kind, dst_kind, getattr(obj, "nbytes", 0.0),
                       cost_model, obj=obj)[1:]:
        obj = cast_step(obj, k)
    return obj


# planner-side cast cost estimates live in costmodel.CostModel.cast_seconds /
# cast_route (calibrated bytes/s per (src, dst) pair, shortest-path routed,
# with a measured-default fallback)
