# The paper's primary contribution — the BigDAWG polystore middleware,
# adapted to TPU execution regimes (see DESIGN.md §2).
from repro.core.tables import DenseTensor, ColumnarTable, COOMatrix, StreamBuffer
from repro.core.ops import PolyOp, Ref, SCOPE_OP
from repro.core.engines import ENGINES, Engine
from repro.core.islands import (ISLANDS, ISLAND_KIND, array, relational, text,
                                stream, degenerate, island_kind, scope,
                                scope_candidates)
from repro.core.signature import signature, signature_text
from repro.core.costmodel import (CostModel, default_calibration_path,
                                  kind_nbytes_from_logical,
                                  container_kind_nbytes, observed_shape)
from repro.core.planner import (Plan, enumerate_plans, find_containers,
                                plan_containers, plan_cost, dp_plans,
                                exhaustive_plans, estimate_sizes,
                                estimate_sizes_shapes)
from repro.core.monitor import Monitor, usage_snapshot
from repro.core.errors import (BigDAWGError, EngineDown, Overloaded,
                               PlanInfeasible, QueryParseError,
                               is_engine_failure)
from repro.core.health import CircuitBreaker, EngineHealth
from repro.core.executor import (execute_plan, ExecutionResult, topo_levels,
                                 host_pool)
from repro.core.fuseplan import (FusedPlan, FusedSegment, fuse_plan,
                                 query_fingerprint)
from repro.core.deltaplan import (UpdatePlan, apply_update, delta_name,
                                  derive)
from repro.core.middleware import (BigDAWG, CachedPlan, MaterializedView,
                                   Report, masked_sig,
                                   default_plan_cache_path,
                                   default_view_cache_path)
from repro.core.tracing import NULL_TRACER, Span, Trace, Tracer
from repro.core.qlang import bigdawg
from repro.core.reqpool import RequestPool
from repro.core.shardplan import (ScatterGather, ShardInfo, analyze,
                                  analyze_catalog, run_scatter_gather)
from repro.core.procpool import ProcPool, worker_channel
from repro.core.api import IslandNamespace, Result, Session, connect

__all__ = [
    "DenseTensor", "ColumnarTable", "COOMatrix", "StreamBuffer",
    "PolyOp", "Ref", "SCOPE_OP", "ENGINES", "Engine", "ISLANDS",
    "ISLAND_KIND", "array", "relational", "text", "stream", "degenerate",
    "island_kind", "scope", "scope_candidates",
    "signature", "signature_text", "CostModel", "default_calibration_path",
    "kind_nbytes_from_logical", "container_kind_nbytes", "observed_shape",
    "Plan", "enumerate_plans", "find_containers", "plan_containers",
    "plan_cost", "dp_plans", "exhaustive_plans", "estimate_sizes",
    "estimate_sizes_shapes", "Monitor", "usage_snapshot", "execute_plan",
    "ExecutionResult", "topo_levels", "host_pool", "FusedPlan",
    "FusedSegment", "fuse_plan", "query_fingerprint",
    "UpdatePlan", "apply_update", "delta_name", "derive",
    "BigDAWG", "CachedPlan", "MaterializedView",
    "Report", "default_plan_cache_path", "default_view_cache_path",
    "masked_sig",
    "BigDAWGError", "EngineDown", "Overloaded", "PlanInfeasible",
    "QueryParseError", "is_engine_failure", "CircuitBreaker", "EngineHealth",
    "NULL_TRACER", "Span", "Trace", "Tracer",
    "RequestPool", "bigdawg", "ScatterGather", "ShardInfo", "analyze",
    "analyze_catalog", "run_scatter_gather", "ProcPool", "worker_channel",
    "IslandNamespace", "Result", "Session", "connect",
]
