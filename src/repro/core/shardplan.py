"""Scatter–gather planning over row-range sharded tables.

BigDAWG's middleware is a process-per-engine architecture: it "dispatches
query fragments to independent engine processes and reassembles results".
This module is the reassembly algebra for OUR partitioned path: given a
query whose leaves include row-range sharded registrations
(``register(..., shards=N)`` stores ``A#0 .. A#N-1`` alongside ``A``),
``analyze`` decides whether the query decomposes into N per-shard fragments
plus ONE merge node, and which merge reassembles it:

* ``concat`` — row-preserving ops (select, project, join with a replicated
  right side, matmul/spmm with a replicated right operand, haar, bin_hist,
  scale, add, window_agg): shard i's output rows ARE rows ``lo_i..hi_i`` of
  the full output, so the gather is row concatenation in shard order.
* ``sum``   — decomposable aggregates: ``count`` (per-shard totals add) and
  ``groupby_sum`` (every shard emits the full aligned ``0..num_groups`` key
  range, so group partials add position-wise).
* ``kmerge`` — ``sort``: each shard returns its rows ordered by the sort
  column; the gather is a k-way ordered merge (heap, stable across shards).

The analysis is *conservative*: ops whose semantics are not row-decomposable
(distinct, tfidf — global document frequencies, knn — global neighbors,
transpose) and island boundaries (scope) inside the sharded lineage return
``None``, which sends the query down the ordinary unsharded path.  An op is
also only row-decomposable against the right container semantics — matmul
row-shards a DENSE matrix, spmm a COO row range, join/groupby_sum/sort a
COLUMNAR record table — so the sharded lineage's container kind is tracked
through the tree and checked per op.

A ``concat``-merged fragment is wrapped in ``scope(root island)`` so every
shard delivers the island's data model regardless of which engine each
worker's planner picked — the merge needs kind-uniform parts.  Aggregate
roots already have engine-independent output kinds and go unwrapped.

``run_scatter_gather`` executes the decomposition against any fragment
runner (the in-process form the property tests use); ``core/procpool.py``
fans the same fragments out to worker processes and calls the same
``gather``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

from repro.core import tables
from repro.core.islands import scope
from repro.core.ops import SCOPE_OP, PolyOp, Ref

# rowwise ops: {op: (sharded input positions, allowed lineage kinds)} — the
# op keeps "output row i of shard == output row lo+i of the full input" when
# the listed input positions carry the sharded lineage (all other inputs
# must be replicated) and the lineage's container kind is in the allowed set
_ANY = ("dense", "columnar", "coo", "stream")
_ROWWISE: Dict[str, Tuple[Tuple[int, ...], Tuple[str, ...]]] = {
    "select":     ((0,), _ANY),
    "project":    ((0,), ("columnar",)),
    "join":       ((0,), ("columnar",)),
    "matmul":     ((0,), ("dense",)),
    "spmm":       ((0,), ("coo",)),
    "haar":       ((0,), ("dense", "stream")),
    "bin_hist":   ((0,), ("dense",)),
    "scale":      ((0,), ("dense",)),
    "add":        ((0, 1), ("dense",)),
    "window_agg": ((0,), ("stream",)),
}

# aggregate ops (root-only): op -> (merge kind, allowed lineage kinds)
_AGG: Dict[str, Tuple[str, Tuple[str, ...]]] = {
    "count":       ("sum", _ANY),
    "groupby_sum": ("sum", ("columnar",)),
    "sort":        ("kmerge", ("columnar",)),
}

# lineage container kind after a rowwise op (given an allowed input kind)
_KIND_OUT = {
    "select": None,          # None: passes the input kind through
    "haar": None,
    "project": "columnar",
    "join": "columnar",
    "matmul": "dense",
    "spmm": "dense",
    "bin_hist": "dense",
    "scale": "dense",
    "add": "dense",
    "window_agg": "dense",
}


def shard_name(name: str, i: int) -> str:
    """Catalog name of shard ``i`` of table ``name``."""
    return f"{name}#{i}"


@dataclass(frozen=True)
class ShardInfo:
    """Registration-time record of one sharded table: shard count, the
    ORIGINAL container kind (row semantics follow the source object even
    when the home engine stores a cast), and the leading-dimension row
    count (alignment check for multi-table co-sharding)."""
    n_shards: int
    kind: str
    rows: int


def nrows_of(obj) -> int:
    """Leading-dimension length of a container (what ``shard_rows`` splits)."""
    if isinstance(obj, tables.ColumnarTable):
        return obj.nrows
    if isinstance(obj, tables.COOMatrix):
        return obj.shape[0]
    data = getattr(obj, "data", None)
    if data is not None and getattr(data, "ndim", 0) >= 1:
        return int(data.shape[0])
    raise TypeError(f"no row dimension on {type(obj).__name__}")


def analyze_catalog(query: PolyOp,
                    infos: Dict[str, "ShardInfo"]) -> Optional[ScatterGather]:
    """``analyze`` against a registry of ``ShardInfo`` records (the form the
    middleware and procpool keep)."""
    if not infos:
        return None
    return analyze(query,
                   {n: i.n_shards for n, i in infos.items()},
                   {n: i.kind for n, i in infos.items()},
                   {n: i.rows for n, i in infos.items()})


class _NotShardable(Exception):
    pass


@dataclass(frozen=True)
class ScatterGather:
    """A validated decomposition: ``fragment(i)`` is the per-shard query
    (sharded refs renamed to their shard-i registrations), ``merge``/
    ``merge_by`` name the gather."""
    query: PolyOp
    n_shards: int
    merge: str                    # concat | sum | kmerge
    merge_by: Optional[str]       # kmerge sort column
    sharded_names: Tuple[str, ...]
    wrap_scope: bool              # concat roots: deliver the island's model

    def fragment(self, i: int) -> PolyOp:
        if not 0 <= i < self.n_shards:
            raise IndexError(f"shard {i} of {self.n_shards}")
        names = set(self.sharded_names)

        def clone(node):
            if isinstance(node, Ref):
                return Ref(shard_name(node.name, i)) if node.name in names \
                    else node
            return PolyOp(op=node.op, island=node.island,
                          inputs=tuple(clone(x) for x in node.inputs),
                          attrs=dict(node.attrs))

        frag = clone(self.query)
        if self.wrap_scope:
            frag = scope(self.query.island, frag)
        return frag


def analyze(query: PolyOp, sharded: Dict[str, int],
            kinds: Dict[str, str],
            rows: Optional[Dict[str, int]] = None
            ) -> Optional[ScatterGather]:
    """Decide whether ``query`` decomposes over its sharded leaves.

    ``sharded`` maps table name -> shard count for every sharded
    registration; ``kinds`` maps table name -> container kind (``"dense"``,
    ``"columnar"``, ...); ``rows`` (optional) maps name -> registered row
    count — required to co-shard TWO different tables in one query (``add``),
    whose row ranges only align when the counts match.  Returns ``None``
    whenever any op on the sharded lineage is not row-decomposable — the
    caller falls back to the unsharded path, so a ``None`` is never wrong,
    only slower.
    """
    names = tuple(sorted({r.name for r in query.refs() if r.name in sharded}))
    if not names:
        return None
    counts = {sharded[n] for n in names}
    if len(counts) != 1:
        return None                       # mixed shard counts cannot align
    n_shards = counts.pop()
    if len(names) > 1:
        # two sharded tables must partition on identical row ranges
        nrows = {rows.get(n) for n in names} if rows else {None}
        if len(nrows) != 1 or None in nrows:
            return None

    def visit(node, is_root):
        # -> (lineage_sharded, lineage_kind)
        if isinstance(node, Ref):
            return node.name in sharded, kinds.get(node.name, "columnar")
        child = [visit(x, False) for x in node.inputs]
        if not any(s for s, _ in child):
            return False, _KIND_OUT.get(node.op) or \
                (child[0][1] if child else "columnar")
        if node.op == SCOPE_OP:
            raise _NotShardable          # boundary inside the sharded lineage
        if node.op in _AGG:
            if not is_root:
                raise _NotShardable      # aggregates only merge at the root
            _, allowed = _AGG[node.op]
            if child[0][1] not in allowed or not child[0][0] \
                    or any(s for s, _ in child[1:]):
                raise _NotShardable
            return True, "dense" if node.op == "count" else "columnar"
        policy = _ROWWISE.get(node.op)
        if policy is None:
            raise _NotShardable          # distinct/tfidf/knn/transpose/...
        positions, allowed = policy
        for pos, (s, k) in enumerate(child):
            if s and pos not in positions:
                raise _NotShardable      # sharded data on a replicated slot
            if pos in positions and not s and any(q for q, _ in child):
                # ops whose sharded slots must shard TOGETHER (add): one
                # sharded + one replicated operand cannot align row ranges
                if len(positions) > 1:
                    raise _NotShardable
        lineage = next(k for s, k in child if s)
        if lineage not in allowed:
            raise _NotShardable
        out = _KIND_OUT.get(node.op)
        return True, lineage if out is None else out

    try:
        root_sharded, _ = visit(query, True)
    except _NotShardable:
        return None
    if not root_sharded:
        return None
    if query.op in _AGG:
        merge, _ = _AGG[query.op]
        merge_by = query.attrs.get("by") if merge == "kmerge" else None
        wrap = False
    else:
        merge, merge_by, wrap = "concat", None, True
    return ScatterGather(query=query, n_shards=n_shards, merge=merge,
                         merge_by=merge_by, sharded_names=names,
                         wrap_scope=wrap)


def gather(sg: ScatterGather, parts):
    """Reassemble per-shard fragment results (numpy-only — safe in the
    procpool master, which never touches the XLA runtime)."""
    from repro.core.executor import merge_shard_results
    out, _ = merge_shard_results(sg.merge, parts, by=sg.merge_by)
    return out


def run_scatter_gather(sg: ScatterGather,
                       run_fragment: Callable[[int, PolyOp], object]):
    """Sequential reference execution of the decomposition: run every
    fragment through ``run_fragment(shard_index, fragment_query)`` and
    gather.  The procpool fans fragments out to distinct workers instead,
    then calls the same ``gather`` — this form is the correctness oracle
    the property suite compares against."""
    parts = [run_fragment(i, sg.fragment(i)) for i in range(sg.n_shards)]
    return gather(sg, parts)
