"""BigDAWG middleware facade (paper Fig. 3): planner + monitor + executor +
migrator behind one ``execute()`` entry point with the training/production
phase protocol of §III-C-3, plus the adaptive feedback loop the paper's
monitor sketches ("collects performance data ... and uses it to improve
future plans"):

  training   — enumerate candidate plans via the cost-model DP (sized from
               measured intermediate sizes where history exists), run (up to
               ``train_plans`` of) them sequentially (per-node timings feed
               the calibrated cost model), record stats + actual sizes,
               return the best run's result, and cache the winning Plan with
               its predicted cost.
  production — serve from the signature-keyed plan cache (no re-enumeration,
               no plan-key parsing), dispatching DAG levels concurrently over
               the executor's host thread pool; on signature miss fall back
               to training; on usage drift, re-train (paper: "rerun the
               query under the training phase under the current usage") and
               queue the DP's true runner-up plans for background
               exploration.  After every run, the measured seconds are
               compared against the cached plan's predicted cost: divergence
               beyond ``replan_factor`` invalidates the entry and re-runs the
               cheap DP under the updated cost model + measured sizes and
               shapes (online re-planning, no training-phase trials needed).
  auto       — production if the signature is known, else training.

Each cache entry carries the k-best DP's runner-up plans
(``CachedPlan.alternates``).  With a non-zero ``explore_budget``, production
occasionally *explores*: after serving the winner, it schedules the next
alternate in rotation as a **background task on the executor's host pool**
— the request path never pays for it — and the task records its measured
seconds/sizes/shapes into the monitor (the paper's "the monitor must
continuously try alternate plans" loop), bounded so exploration time never
exceeds ``explore_budget`` x cumulative serve time.  An alternate that
proves faster becomes the monitor's best and is promoted on a later serve.
``drain_explorations()`` waits for in-flight trials (tests, shutdown).

**Concurrent admission.**  ``execute`` is safe to call from many request
threads at once: a per-signature lock serializes requests for the SAME
signature (two cold requests train once — the second waits, then serves the
fresh cache entry) while different signatures train and serve fully in
parallel.  The monitor and cost model take their own internal locks, the
plan cache is guarded here, the stats counters live in the lock-free
``runtime.telemetry.Metrics`` registry, and exploration runs
off-path, so the whole middleware admits multi-threaded traffic (see
``runtime.server.QueryServer.submit_many``).

**Resilient serving.**  Constructed with a ``core.health.EngineHealth``
registry, ``execute`` runs through a failover driver: every request plans
under the current circuit-breaker mask, an ``EngineDown`` mid-plan feeds the
engine's breaker and retries (first burning the breaker's failure threshold
on the incumbent path, then — breaker open, engine masked — re-running the
cheap k=1 DP around the dead engine), and masked plans are cached and
monitored under a mask-suffixed signature so the incumbent's history stays
pure and recovery (the breaker's half-open probe succeeding) restores it
verbatim.  Reports then carry ``status``/``degraded``/``failovers``.

The plan cache (winning plan + predicted cost + alternate keys) persists
beside the monitor DB (``<monitor>.plans.json``, atomic JSON via
``ioutil``), so a restarted production process serves previously-trained
signatures warm — zero plan enumerations — and keeps exploring the same
alternates.
"""
from __future__ import annotations

import os
import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, FrozenSet, List, Optional, Set, Tuple, Union

from repro.core import deltaplan, tables, tracing
from repro.core.costmodel import CostModel, default_calibration_path
from repro.core.engines import ENGINES
from repro.core.errors import EngineDown, PlanInfeasible
from repro.core.executor import ExecutionResult, execute_plan, host_pool
from repro.core.health import EngineHealth
from repro.core.ioutil import (atomic_json_dump, file_version, load_json,
                               load_json_versioned)
from repro.core.monitor import Monitor, usage_snapshot
from repro.core.ops import PolyOp
from repro.core.planner import (Plan, dp_plans, estimate_sizes_shapes,
                                plan_cost, price_incremental)
from repro.core.signature import signature

# separator between a signature and the engine mask it was served under:
# masked (failover/degraded) plans live in the plan cache and the monitor
# under "sig@!engine+engine", so the UNMASKED signature's history and cache
# entry stay pure — when the breaker closes again, monitor.best(sig) still
# names the incumbent and recovery restores it verbatim
MASK_SEP = "@!"


def masked_sig(sig: str, mask: FrozenSet[str]) -> str:
    return sig + MASK_SEP + "+".join(sorted(mask))


def _plan_from_key(plan_key: str) -> Plan:
    """Parse ``pos:engine|pos:engine|...``; raises ValueError on malformed or
    unknown-engine keys (callers decide whether to skip or retrain)."""
    try:
        pairs = tuple((int(u), e) for u, e in
                      (p.split(":") for p in plan_key.split("|")))
    except (ValueError, AttributeError) as exc:
        raise ValueError(f"malformed plan key {plan_key!r}") from exc
    for _, eng in pairs:
        if eng not in ENGINES:
            raise ValueError(f"plan key {plan_key!r} names unknown engine "
                             f"{eng!r}")
    if [u for u, _ in pairs] != list(range(len(pairs))):
        raise ValueError(f"plan key {plan_key!r} positions are not "
                         f"consecutive from 0")
    return Plan(pairs)


def default_plan_cache_path(monitor_path: Optional[str]) -> Optional[str]:
    """Plan-cache file that rides alongside a monitor DB path."""
    if not monitor_path:
        return None
    root, _ = os.path.splitext(monitor_path)
    return root + ".plans.json"


def default_view_cache_path(monitor_path: Optional[str]) -> Optional[str]:
    """Materialized-view file that rides alongside a monitor DB path."""
    if not monitor_path:
        return None
    root, _ = os.path.splitext(monitor_path)
    return root + ".views.json"


def default_health_path(monitor_path: Optional[str]) -> Optional[str]:
    """Breaker-state file that rides alongside a monitor DB path."""
    if not monitor_path:
        return None
    root, _ = os.path.splitext(monitor_path)
    return root + ".health.json"


# views above this physical size are served and patched in memory but not
# persisted: the JSON codec is for warm-start of SMALL hot results, not a
# second storage engine (a restarted process simply re-materializes)
VIEW_PERSIST_MAX_BYTES = 4 << 20


@dataclass
class CatalogEntry:
    name: str
    obj: Any                 # a tables.* container
    engine: str              # home engine
    # STREAM island append semantics: a streaming registration may grow by
    # appended rows (BigDAWG.append), its signature renders shape-free, and
    # warm serves may be patched incrementally from a materialized view
    streaming: bool = False
    # registration generation (bumped when register() replaces the name) —
    # a view stamped under another epoch must not be delta-patched, the
    # content may be unrelated even at identical row counts
    epoch: int = 0
    # append generation (bumped per append) — cheap change detection
    version: int = 0


@dataclass
class MaterializedView:
    """A signature's materialized result: the delivered value plus, per
    referenced table, the (epoch, version, rows, kind) stamp it was computed
    at.  A warm serve whose only drift from the stamps is appended rows on
    streaming tables may run the derived ``deltaplan.UpdatePlan`` against
    the pending suffixes and patch ``value`` in place of recomputing."""
    value: Any
    refs: Dict[str, Dict[str, Any]]
    # frozenset(changed names) -> UpdatePlan | None (None = proven
    # non-incremental for that change set; derivation runs once per set)
    update_plans: Dict[FrozenSet[str], Optional[deltaplan.UpdatePlan]] = \
        field(default_factory=dict)
    # loaded from a persisted view file: stamps carry another process's
    # epochs, so the first freshness check trusts (kind, rows) identity —
    # the procpool deployment contract, where every worker registers the
    # same tables — and then adopts this process's epochs
    restored: bool = False


@dataclass
class CachedPlan:
    """A plan-cache entry: the winning Plan plus the predicted cost it was
    cached under (the baseline the online re-planner diverges against), and
    the k-best DP's runner-up plans for budgeted exploration."""
    plan: Plan
    predicted_s: float = 0.0
    # a freshly re-planned entry is served once ahead of monitor history so
    # its measured seconds enter the history and the comparison is live
    pinned: bool = False
    # loaded from a persisted cache: the first serve re-syncs the prediction
    # to this process's runtime instead of re-planning (a cold jit cache can
    # legitimately be >2x slower than the recording process was)
    restored: bool = False
    # the DP's true runner-up plans (training order, best first) — what the
    # budgeted exploration path executes in rotation
    alternates: Tuple[Plan, ...] = ()
    next_alt: int = 0        # rotation cursor (not persisted)
    # the fusion pass's output for this entry's plan (fuseplan.FusedPlan),
    # built lazily on the first fused serve and invalidated when the plan or
    # the query's exact structure changes.  Runtime-only, like next_alt: the
    # compiled callables live in fuseplan's process-wide cache, and a
    # restarted process re-runs the (cheap) segmentation pass
    fused: Any = None
    # the signature's materialized view (streaming/IVM serves) — validity is
    # plan-independent (query + data only), so entry replacements carry it
    view: Optional[MaterializedView] = None


@dataclass
class Report:
    result: Any
    plan_key: str
    mode: str                # "training" | "production"
    seconds: float
    cast_bytes: float
    sig: str
    plans_tried: int = 1
    drifted: bool = False
    cache_hit: bool = False  # plan came from the signature-keyed plan cache
    replanned: bool = False  # predicted/measured divergence re-ran the DP
    predicted_s: float = 0.0  # cached prediction for the executed plan
    # this serve scheduled a background alternate trial (it runs off-path on
    # the host pool; drain_explorations() waits for its measurement)
    explored: bool = False
    explored_key: str = ""   # which alternate (empty when explored is False)
    # post-order position -> measured seconds of that node in the served run
    # (position-keyed like plan keys and size feedback, so it survives query
    # rebuilds; the Session API surfaces it as Result.per_node_seconds)
    per_node_seconds: Dict[int, float] = field(default_factory=dict)
    # -- resilience surface (populated when the middleware has a health
    #    registry; defaults describe the non-resilient path) ---------------
    status: str = "ok"       # "ok" | "degraded" ("shed" is stamped by the
    #                          server on Overloaded results, never here)
    degraded: bool = False   # served under an engine mask (failover/degrade)
    failovers: int = 0       # EngineDown retries this request survived
    # scatter–gather: number of shard fragments this result was merged from
    # (0 = ordinary unsharded execution; plan_key then describes one
    # fragment's plan — fragments share a node structure with the query)
    shards: int = 0
    # position groups that executed as single compiled segments this serve
    # (empty on training serves — calibration stays unfused — and when
    # fusion is off, nothing was fusable, or every segment fell back)
    fused_segments: Tuple[Tuple[int, ...], ...] = ()
    # fused segments that failed to trace/compile/run this serve and were
    # re-executed node-by-node (sticky: later serves skip the fused attempt)
    fusion_fallbacks: int = 0
    # served by patching the materialized view with a delta fragment (or by
    # the view verbatim when nothing changed) instead of a full recompute
    incremental: bool = False
    # the request's span tree (core.tracing.Trace) when tracing was on (or a
    # propagated cross-process context forced it); the Session surfaces it
    # as Result.trace.  Inside a procpool worker this is converted to its
    # portable dict form before crossing the pipe
    trace: Any = None


def _pos_seconds(query: PolyOp, res: ExecutionResult) -> Dict[int, float]:
    """Re-key an ExecutionResult's uid-keyed per-node timings by post-order
    position (shared subtrees collapse to their one executed timing)."""
    return {pos: res.per_node_seconds.get(n.uid, 0.0)
            for pos, n in enumerate(query.nodes())}


def _metric_prop(name: str, cast=int) -> property:
    """A lifetime counter backed by the Metrics registry, exposed under the
    historical attribute name (``bd.replans`` etc.) so every existing reader
    keeps working — reads are lock-free snapshot lookups, writes go through
    the registry (one lock for ALL middleware stats instead of a private
    ``_stats_lock``)."""
    def _get(self):
        return cast(self.metrics.value(name))

    def _set(self, v):
        self.metrics.set_counter(name, float(v))
    return property(_get, _set)


class BigDAWG:
    # measured/predicted divergence factor that triggers online re-planning
    REPLAN_FACTOR = 2.0
    # max fraction of cumulative production serve seconds spendable on
    # executing alternate plans (0.0 disables exploration)
    EXPLORE_BUDGET = 0.0
    # how many DP runner-ups each cache entry keeps for exploration
    MAX_ALTERNATES = 3

    def __init__(self, monitor: Optional[Monitor] = None,
                 train_plans: int = 8, train_repeats: int = 2,
                 cost_model: Optional[CostModel] = None,
                 calibrate: bool = False,
                 plan_cache_path: Optional[str] = None,
                 replan_factor: float = REPLAN_FACTOR,
                 explore_budget: float = EXPLORE_BUDGET,
                 health: Optional[EngineHealth] = None,
                 fuse: bool = True, fusion_injector: Any = None,
                 incremental: Union[bool, str] = True,
                 trace: bool = False, metrics: Any = None,
                 metrics_path: Optional[str] = None):
        self.catalog: Dict[str, CatalogEntry] = {}
        # name -> shardplan.ShardInfo for tables registered with shards=N
        # (the shard parts live in the catalog as "name#i")
        self.sharded: Dict[str, "shardplan.ShardInfo"] = {}
        self.monitor = monitor or Monitor()
        # request tracing (core.tracing): trace=True makes every execute()
        # build a per-request span tree, returned on Report.trace.  Off by
        # default — the disabled tracer allocates nothing and every
        # instrumentation site is a single None check
        self.tracer = tracing.Tracer(enabled=bool(trace))
        # process-wide metrics registry (runtime.telemetry): absorbs the old
        # per-middleware stats counters behind lock-free-read properties
        # (below) and persists merge-on-save beside the monitor DB
        if metrics is None:
            from repro.runtime.telemetry import (Metrics,
                                                 default_metrics_path)
            mpath = metrics_path or (default_metrics_path(self.monitor.path)
                                     if self.monitor.path else None)
            metrics = Metrics(mpath, shared=self.monitor.shared)
        self.metrics = metrics
        # optional per-engine circuit-breaker registry: when present, every
        # execute() runs through the failover driver (_execute_resilient) —
        # tripped engines are masked out of planning, EngineDown retries
        # re-plan, successes/stragglers feed the breakers
        self.health = health
        if health is not None and getattr(health, "metrics", None) is None:
            health.metrics = self.metrics    # breaker trips -> registry
        self.train_plans = train_plans
        # run each candidate plan this many times during training and record
        # only the last — first-run jit/compile cost would otherwise bias the
        # monitor toward never-compiled plans (cold-start bias)
        self.train_repeats = max(1, train_repeats)
        # cost model persists alongside the monitor DB when the latter has one
        self.cost_model = cost_model or CostModel(
            default_calibration_path(self.monitor.path))
        if calibrate and not self.cost_model.calibrated:
            self.cost_model.calibrate()
        self.replan_factor = replan_factor
        # budgeted alternate exploration (see module docstring): exploration
        # seconds may never exceed explore_budget x cumulative serve seconds.
        # The counters themselves (replans/explorations/explore_seconds/
        # serve_seconds/failovers/fusion/ivm stats) live in the metrics
        # registry, exposed under their historical names via _metric_prop
        self.explore_budget = explore_budget
        # plan-level kernel fusion (core.fuseplan): production serves execute
        # each cached plan's same-engine fusable chains as single jitted
        # callables.  Safe to flip at runtime (the FusedPlan rides the cache
        # entry; fuse=False simply stops passing it to the executor).
        # fusion_injector (runtime.fault.FusionFaultInjector) is the
        # compile-failure seam for the fallback fault tests
        self.fuse = fuse
        self.fusion_injector = fusion_injector
        # incremental view maintenance (core.deltaplan): warm serves whose
        # only drift is appended rows on streaming registrations run the
        # derived update fragment and patch the materialized view.  True
        # gates each serve on the cost model (incremental-vs-full); the
        # string "force" skips the gate (tests/benchmarks pinning the delta
        # path); False disables materialization and patching entirely.
        # Inert without streaming registrations, safe to flip at runtime.
        self.incremental = incremental
        # registration-epoch counter (CatalogEntry.epoch source)
        self._catalog_epoch = 0
        # signature -> CachedPlan: production requests skip re-enumeration
        # and plan-key parsing entirely; persisted beside the monitor DB so
        # restarted processes serve warm
        self.plan_cache: Dict[str, CachedPlan] = {}
        self.plan_cache_path = plan_cache_path or default_plan_cache_path(
            self.monitor.path)
        # -- concurrency state (see module docstring) -----------------------
        # per-signature serialization: same-signature requests queue (one
        # training per signature), different signatures run in parallel
        self._sig_locks: Dict[str, threading.RLock] = {}
        self._sig_locks_guard = threading.Lock()
        # guards plan_cache dict mutation + CachedPlan alternate rotation
        self._cache_lock = threading.RLock()
        # background exploration bookkeeping: at most one in-flight trial per
        # signature, futures kept so drain_explorations() can wait
        self._explore_guard = threading.Lock()
        self._explore_inflight: set = set()
        self._explore_futures: List = []
        # cross-process plan-cache sharing: stamp of the file we last
        # read/wrote (reload_plan_cache_if_changed polls it)
        self._plan_cache_version = None
        if self.plan_cache_path and os.path.exists(self.plan_cache_path):
            self.load_plan_cache(self.plan_cache_path)
        # materialized views ride beside the plan cache; breaker state
        # beside the monitor DB (satellite files of one state root)
        self.view_cache_path = default_view_cache_path(self.monitor.path)
        if self.view_cache_path and os.path.exists(self.view_cache_path):
            self.load_views(self.view_cache_path)
        self.health_path = default_health_path(self.monitor.path)
        if self.health is not None and self.health_path \
                and os.path.exists(self.health_path):
            self._restore_health(self.health_path)

    # -- lifetime stats (metrics-registry backed, historical names) ----------
    replans = _metric_prop("bd.replans")
    explorations = _metric_prop("bd.explorations")
    explore_seconds = _metric_prop("bd.explore_seconds", float)
    serve_seconds = _metric_prop("bd.serve_seconds", float)
    failovers = _metric_prop("bd.failovers")
    fused_serves = _metric_prop("bd.fused_serves")
    fusion_segments = _metric_prop("bd.fusion_segments")
    fusion_fallbacks = _metric_prop("bd.fusion_fallbacks")
    ivm_serves = _metric_prop("bd.ivm_serves")
    ivm_fallbacks = _metric_prop("bd.ivm_fallbacks")

    def _sig_lock(self, sig: str) -> threading.RLock:
        with self._sig_locks_guard:
            return self._sig_locks.setdefault(sig, threading.RLock())

    # -- catalog -----------------------------------------------------------
    def register(self, name: str, obj, engine: str,
                 shards: Optional[int] = None, streaming: bool = False):
        """Home ``obj`` on ``engine`` under ``name``.  With ``shards=N`` the
        object is ALSO split into N contiguous row-range parts registered as
        ``name#0 .. name#N-1`` (each homed/cast like any registration), and
        the shard registry records the decomposition — what
        ``shardplan.analyze`` consults to offer scatter–gather execution.

        ``streaming=True`` declares an append-able STREAM-island table:
        ``append(name, rows)`` grows it in place, its signature renders
        shape-free (appends keep plan-cache/monitor history), and warm
        serves over it may be patched incrementally from materialized
        views.  Streaming registrations must be homed on an engine whose
        native data model matches the object (a cast home would explode
        rows, breaking append row-identity) and cannot be sharded."""
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine}")
        if streaming:
            if shards is not None:
                raise ValueError("streaming registrations cannot be sharded")
            if ENGINES[engine].kind != obj.kind:
                raise ValueError(
                    f"streaming registration {name!r} must be homed "
                    f"natively: object kind {obj.kind!r} vs engine "
                    f"{engine!r} ({ENGINES[engine].kind!r}) — casts are not "
                    f"append-preserving")
            tables.leading_rows(obj)     # raises for 0-d: nothing to append
        if shards is not None:
            from repro.core import shardplan
            if shards < 1:
                raise ValueError(f"shards must be >= 1, got {shards}")
            parts = tables.shard_rows(obj, shards)   # split BEFORE the home
            info = shardplan.ShardInfo(              # cast: row semantics
                shards, obj.kind, shardplan.nrows_of(obj))   # follow the src
            for i, part in enumerate(parts):
                self.register(shardplan.shard_name(name, i), part, engine)
            self.sharded[name] = info
        if ENGINES[engine].kind != obj.kind:
            from repro.core import cast as castmod
            # casts leave triple formats numpy-eager (right for short-lived
            # intermediates); a catalog object is long-lived and re-consumed
            # by device ops every query, so home it on the device once here
            obj = tables.device_ready(
                castmod.cast(obj, ENGINES[engine].kind, self.cost_model))
        elif streaming:
            # streaming tables stay HOST-resident: every append reshapes
            # them, so device residency never amortizes — and host storage
            # makes the hot IVM path compile-free (numpy append, zero-copy
            # suffix slice) where device arrays would pay one XLA
            # recompilation per new shape, per serve
            obj = tables.host_copy(obj)
        self._catalog_epoch += 1
        self.catalog[name] = CatalogEntry(name, obj, engine,
                                          streaming=streaming,
                                          epoch=self._catalog_epoch)

    def append(self, name: str, rows) -> int:
        """Append ``rows`` (a container of the table's kind) to streaming
        registration ``name`` — the STREAM island's ingest path.  The table
        grows in place along its leading dimension and its version bumps;
        the signature is shape-free for streaming tables, so warm plans and
        materialized views stay valid and the next serve either patches the
        view with the pending suffix (``deltaplan``) or recomputes, per the
        cost model.  Returns the new version number."""
        entry = self.catalog.get(name)
        if entry is None:
            raise KeyError(f"no registration named {name!r}")
        if not entry.streaming:
            raise ValueError(f"{name!r} is not a streaming registration; "
                             f"register(..., streaming=True) enables "
                             f"append()")
        if getattr(rows, "kind", None) != entry.obj.kind:
            raise TypeError(f"append to {name!r} needs a "
                            f"{entry.obj.kind!r} container, got "
                            f"{type(rows).__name__}")
        rows = tables.host_copy(rows)    # host-resident, like the base
        with self._cache_lock:
            entry.obj = tables.append_rows(entry.obj, rows)
            entry.version += 1
            return entry.version

    # -- plan-cache persistence ---------------------------------------------
    def save_plan_cache(self, path: Optional[str] = None,
                        merge: Optional[bool] = None):
        """Persist the plan cache atomically.  With ``merge`` (default: the
        monitor's ``shared`` flag, so procpool workers merge automatically)
        the current file is read first and signatures this process has no
        local entry for are carried through — concurrent workers training
        DIFFERENT signatures never drop each other's entries; the same
        signature resolves last-writer-wins."""
        path = path or self.plan_cache_path
        if not path:
            return
        if merge is None:
            merge = self.monitor.shared
        with self._cache_lock:     # snapshot: concurrent trainings of other
            blob = {"format": 2,   # signatures keep mutating the dict
                    "entries": {sig: {"plan": e.plan.key,
                                      "predicted_s": e.predicted_s,
                                      "alternates": [p.key
                                                     for p in e.alternates]}
                                for sig, e in self.plan_cache.items()
                                # masked (degraded) entries are transient —
                                # tied to this process's breaker state, they
                                # must not warm-start a healthy restart
                                if MASK_SEP not in sig}}
            if merge:
                try:
                    cur = load_json(path)
                except (OSError, ValueError):
                    cur = None
                if isinstance(cur, dict):
                    for sig, ent in cur.get("entries", {}).items():
                        # a sibling that crashed mid-outage (or a hand edit)
                        # can leave masked entries in the file; adopting one
                        # would resurrect transient degraded state forever —
                        # masked signatures never survive a merge
                        if sig not in self.plan_cache and MASK_SEP not in sig:
                            blob["entries"][sig] = ent
            atomic_json_dump(path, blob)
            self._plan_cache_version = file_version(path)

    def reload_plan_cache_if_changed(self) -> bool:
        """Cross-process read path: adopt plan-cache entries other workers
        have persisted since we last read/wrote the file.  Local entries are
        never clobbered (this process's live pin/alternate state wins);
        adopted entries arrive ``restored=True`` so their first serve
        re-syncs the prediction to this process's runtime.  One ``stat``
        when nothing changed."""
        path = self.plan_cache_path
        if not path:
            return False
        with self._cache_lock:
            blob, ver = load_json_versioned(path, self._plan_cache_version)
            if blob is None:
                return False
            self._plan_cache_version = ver
            adopted = False
            for sig, ent in (blob.get("entries", {})
                             if isinstance(blob, dict) else {}).items():
                if sig in self.plan_cache or MASK_SEP in sig:
                    continue
                try:
                    alts = tuple(_plan_from_key(k)
                                 for k in ent.get("alternates", []) or [])
                    self.plan_cache[sig] = CachedPlan(
                        _plan_from_key(ent["plan"]),
                        float(ent.get("predicted_s", 0.0)),
                        restored=True, alternates=alts)
                    adopted = True
                except (ValueError, KeyError, TypeError) as exc:
                    warnings.warn(f"plan cache {path}: skipping bad shared "
                                  f"entry {sig!r}: {exc}")
            return adopted

    def reload_shared(self) -> bool:
        """Poll both shared-state files (monitor DB + plan cache) for changes
        by other processes — the procpool worker calls this before serving
        each request (two ``stat`` calls on the idle path)."""
        m = self.monitor.reload_if_changed()
        p = self.reload_plan_cache_if_changed()
        return m or p

    def load_plan_cache(self, path: str):
        """Load a persisted plan cache, skipping (with a warning) any entry a
        hand edit or corruption has mangled — bad entries, or a whole file
        that no longer parses, must not take down the warm-start path."""
        try:
            blob = load_json(path)
        except (OSError, ValueError) as exc:   # JSONDecodeError is a ValueError
            warnings.warn(f"plan cache {path}: unreadable ({exc}); "
                          f"starting cold")
            return
        self._plan_cache_version = file_version(path)
        entries = blob.get("entries", {}) if isinstance(blob, dict) else {}
        for sig, ent in entries.items():
            if MASK_SEP in sig:
                # a crashed sibling's degraded entry — masked plans are tied
                # to that process's breaker state and must never warm-start
                # a healthy one
                continue
            try:
                if not isinstance(ent, dict):
                    raise ValueError(f"entry for {sig!r} is not an object")
                plan = _plan_from_key(ent["plan"])
                alts = []
                for ak in ent.get("alternates", []) or []:
                    try:
                        alts.append(_plan_from_key(ak))
                    except ValueError as exc:   # one bad alternate must not
                        warnings.warn(           # sink the whole entry
                            f"plan cache {path}: dropping bad alternate "
                            f"for {sig!r}: {exc}")
                with self._cache_lock:
                    self.plan_cache[sig] = CachedPlan(
                        plan, float(ent.get("predicted_s", 0.0)),
                        restored=True, alternates=tuple(alts))
            except (ValueError, KeyError, TypeError) as exc:
                warnings.warn(f"plan cache {path}: skipping bad entry "
                              f"{sig!r}: {exc}")

    # -- materialized-view persistence ---------------------------------------
    def save_views(self, path: Optional[str] = None,
                   merge: Optional[bool] = None):
        """Persist materialized views atomically beside the plan cache, so a
        restarted production process patches instead of re-materializing.
        Views above ``VIEW_PERSIST_MAX_BYTES`` stay memory-only (the JSON
        codec warm-starts SMALL hot results, it is not a storage engine).
        Merge-on-save follows ``save_plan_cache``: signatures this process
        has no local view for are carried through, masked signatures never
        persist, same-signature resolves local-wins."""
        path = path or self.view_cache_path
        if not path:
            return
        if merge is None:
            merge = self.monitor.shared
        with self._cache_lock:
            entries = {}
            for sig, e in self.plan_cache.items():
                v = e.view
                if v is None or MASK_SEP in sig:
                    continue
                if getattr(v.value, "nbytes", VIEW_PERSIST_MAX_BYTES + 1) \
                        > VIEW_PERSIST_MAX_BYTES:
                    continue
                blob_v = tables.container_to_jsonable(
                    tables.host_copy(v.value))
                if blob_v is None:        # unknown container: memory-only
                    continue
                entries[sig] = {"value": blob_v, "refs": v.refs}
            blob = {"format": 1, "entries": entries}
            if merge:
                try:
                    cur = load_json(path)
                except (OSError, ValueError):
                    cur = None
                if isinstance(cur, dict):
                    for sig, ent in cur.get("entries", {}).items():
                        if sig not in entries and MASK_SEP not in sig:
                            blob["entries"][sig] = ent
            atomic_json_dump(path, blob)

    def load_views(self, path: str):
        """Load persisted materialized views, attaching each (``restored``,
        so the first freshness check trusts (kind, rows) identity and adopts
        this process's epochs) to its signature's plan-cache entry.  A view
        whose signature has no cache entry is dropped — the view rides the
        entry, and without a plan the serve retrains and re-materializes
        anyway.  Bad entries are skipped with a warning, like the plan
        cache."""
        try:
            blob = load_json(path)
        except (OSError, ValueError) as exc:
            warnings.warn(f"view cache {path}: unreadable ({exc}); "
                          f"starting cold")
            return
        entries = blob.get("entries", {}) if isinstance(blob, dict) else {}
        for sig, ent in entries.items():
            try:
                value = tables.container_from_jsonable(ent["value"])
                refs = {str(n): dict(st) for n, st in ent["refs"].items()}
                with self._cache_lock:
                    entry = self.plan_cache.get(sig)
                    if entry is not None:
                        entry.view = MaterializedView(value, refs,
                                                      restored=True)
            except (ValueError, KeyError, TypeError) as exc:
                warnings.warn(f"view cache {path}: skipping bad entry "
                              f"{sig!r}: {exc}")

    # -- breaker-state persistence -------------------------------------------
    def _save_health(self, path: Optional[str] = None):
        """Persist the circuit-breaker registry's snapshot beside the
        monitor DB, so a restarted process does not re-burn an EngineDown
        failure budget rediscovering an outage it already knew about."""
        path = path or self.health_path
        if self.health is None or not path:
            return
        atomic_json_dump(path, {"format": 1,
                                "channels": self.health.snapshot()})

    def _restore_health(self, path: str):
        """Restore persisted breaker state (warn-and-continue on damage:
        health state is an optimization, never worth failing startup over)."""
        try:
            blob = load_json(path)
        except (OSError, ValueError) as exc:
            warnings.warn(f"health state {path}: unreadable ({exc}); "
                          f"starting closed")
            return
        channels = blob.get("channels", {}) if isinstance(blob, dict) else {}
        try:
            self.health.restore(channels)
        except (ValueError, KeyError, TypeError) as exc:
            warnings.warn(f"health state {path}: not restored ({exc})")

    # -- phases --------------------------------------------------------------
    def _predict(self, query: PolyOp, plan: Plan, sig: str) -> float:
        """Current predicted seconds for a plan, under measured sizes and
        shapes."""
        sizes, shapes = estimate_sizes_shapes(
            query, self.catalog, measured=self.monitor.measured_sizes(sig),
            measured_shapes=self.monitor.measured_shapes(sig))
        return plan_cost(query, plan, self.catalog, self.cost_model,
                         sizes=sizes, shapes=shapes)

    def _train(self, query: PolyOp, sig: str,
               span: Optional[tracing.Span] = None) -> Report:
        tspan = span.child("train", sig=sig) if span is not None else None
        ranked = dp_plans(query, self.catalog, max_plans=self.train_plans,
                          cost_model=self.cost_model,
                          measured_sizes=self.monitor.measured_sizes(sig),
                          measured_shapes=self.monitor.measured_shapes(sig))
        best: Optional[ExecutionResult] = None
        usage = usage_snapshot()
        for _, plan in ranked:
            # sequential warm-up runs: kill cold-start jit bias AND feed
            # honest per-node timings to the cost model (sequential only)
            for _ in range(self.train_repeats):
                res = execute_plan(query, plan, self.catalog,
                                   cost_model=self.cost_model,
                                   health=self.health)
            self.cost_model.observe_execution(res)
            # the RECORDED measurement uses concurrent dispatch — the same
            # mode production executes in, so every seconds value a
            # Monitor.best() comparison sees is from one dispatch mode
            res = execute_plan(query, plan, self.catalog, concurrent=True,
                               cost_model=self.cost_model,
                               health=self.health, trace=tspan)
            self.monitor.record(sig, plan.key, res.seconds,
                                cast_bytes=res.cast_bytes, usage=usage,
                                sizes=res.size_obs, shapes=res.shape_obs)
            if best is None or res.seconds < best.seconds:
                best = res
        # the cached prediction is recomputed AFTER the training observations
        # and size measurements landed — the freshest model state, the
        # baseline online re-planning diverges against.  If the model is
        # still off by more than the replan factor from the measurement we
        # JUST took, the measurement is the better baseline (caching a known-
        # bad prediction would trigger a pointless re-plan on the very next
        # production run)
        predicted = self._predict(query, best.plan, sig)
        if self._diverged(predicted, best.seconds):
            predicted = best.seconds
        # the DP's runner-ups are the TRUE alternates (ROADMAP: background
        # exploration must try these, not whatever the monitor happens to
        # have recorded) — kept with the entry for budgeted exploration
        alternates = tuple(p for _, p in ranked
                           if p.key != best.plan.key)[:self.MAX_ALTERNATES]
        with self._cache_lock:
            self.plan_cache[sig] = CachedPlan(best.plan, predicted,
                                              alternates=alternates)
        if tspan is not None:
            tspan.annotate(plans=len(ranked))
            tspan.end()
        self.cost_model.save()
        self.monitor.save()
        self.save_plan_cache()
        self._maybe_materialize(query, sig, best.value)
        return Report(best.value, best.plan.key, "training", best.seconds,
                      best.cast_bytes, sig, plans_tried=len(ranked),
                      predicted_s=predicted,
                      per_node_seconds=_pos_seconds(query, best))

    def _diverged(self, predicted: float, measured: float) -> bool:
        """The online re-planner's divergence policy: prediction and
        measurement disagree by more than ``replan_factor`` in either
        direction (non-positive values never diverge)."""
        if predicted <= 0.0 or measured <= 0.0:
            return False
        return max(measured / predicted,
                   predicted / measured) > self.replan_factor

    def _maybe_replan(self, query: PolyOp, sig: str, measured: float,
                      entry: CachedPlan) -> bool:
        """Online re-planning: >replan_factor divergence between the measured
        cost (the monitor's history-damped mean for the served plan — a
        single run's timing noise on short queries can exceed the factor by
        itself) and the cached prediction invalidates the entry and re-runs
        the cheap DP under the updated cost model + measured sizes."""
        pred = entry.predicted_s
        if measured <= 0.0:
            return False
        if entry.restored:
            # first serve after a warm restart: a cold jit cache makes this
            # run incomparable to the recording process's baseline — re-sync
            # the prediction instead of re-planning.  A restored entry with
            # no usable baseline (predicted_s missing from the file -> 0.0)
            # must also adopt the measurement, or the loop stays dead
            entry.restored = False
            if pred <= 0.0 or self._diverged(pred, measured):
                entry.predicted_s = measured
            return False
        if pred <= 0.0 or not self._diverged(pred, measured):
            return False
        # the "cheap DP": only the new optimum is consumed, so k=1 (per-engine
        # fronts keep the top-1 exact — see dp_plans)
        ranked = dp_plans(query, self.catalog, max_plans=1,
                          cost_model=self.cost_model,
                          measured_sizes=self.monitor.measured_sizes(sig),
                          measured_shapes=self.monitor.measured_shapes(sig))
        cost, plan = ranked[0]
        if plan.key == entry.plan.key:
            # same plan still wins — the divergence is model form error, not
            # a placement mistake; adopt the measured cost as the entry's
            # prediction so a stable runtime stops re-triggering
            with self._cache_lock:
                self.plan_cache[sig] = CachedPlan(plan, measured,
                                                  alternates=entry.alternates,
                                                  view=entry.view)
        else:
            # prefer the plan's measured history (training trials measured
            # every candidate) over the raw model cost as the new baseline —
            # a model-based baseline could itself diverge and cascade
            stats = self.monitor.known_plans(sig).get(plan.key)
            pred_new = stats.mean_seconds if stats is not None and stats.n \
                else cost
            with self._cache_lock:
                self.plan_cache[sig] = CachedPlan(
                    plan, pred_new, pinned=True,
                    # the dethroned incumbent joins the alternates —
                    # exploration keeps measuring it so a wrong re-plan can
                    # be reversed
                    alternates=tuple(
                        p for p in (entry.plan,) + entry.alternates
                        if p.key != plan.key)[:self.MAX_ALTERNATES],
                    view=entry.view)
        self.metrics.counter("bd.replans")
        self.save_plan_cache()
        return True

    def _fused_for(self, query: PolyOp, plan: Plan,
                   entry: Optional[CachedPlan]):
        """The FusedPlan to serve ``plan`` with (None when fusion is off).
        Cached on the plan-cache entry and reused only when both the plan
        key and the query's EXACT structural fingerprint still match —
        signatures bin constant attrs, so two queries can share a signature
        (and this entry) yet need differently-closed-over callables."""
        if not self.fuse:
            return None
        from repro.core import fuseplan
        fp = fuseplan.query_fingerprint(query)
        with self._cache_lock:
            f = entry.fused if entry is not None else None
            if f is not None and f.plan_key == plan.key \
                    and f.fingerprint == fp:
                return f
        f = fuseplan.fuse_plan(query, plan, self.catalog,
                               cost_model=self.cost_model,
                               injector=self.fusion_injector)
        with self._cache_lock:
            if entry is not None:
                entry.fused = f
        return f

    def _note_fusion(self, res: ExecutionResult) -> None:
        """Roll one serve's fusion outcome into the lifetime counters."""
        if res.fused_segments:
            self.metrics.counter("bd.fused_serves")
            self.metrics.counter("bd.fusion_segments",
                                 float(len(res.fused_segments)))
        if res.fusion_fallbacks:
            self.metrics.counter("bd.fusion_fallbacks",
                                 float(res.fusion_fallbacks))

    # -- incremental view maintenance ----------------------------------------
    def _ref_stamps(self, query: PolyOp) -> Optional[Dict[str, Dict]]:
        """Current (epoch, version, rows, kind, streaming) stamp for every
        table the query references — what a materialized view records at
        materialization time and what the freshness check compares against.
        None when a ref is unregistered (the serve will fail anyway)."""
        stamps: Dict[str, Dict] = {}
        for r in query.refs():
            e = self.catalog.get(r.name)
            if e is None:
                return None
            try:
                rows = tables.leading_rows(e.obj)
            except TypeError:        # 0-d scalar: no append axis to track
                rows = None
            stamps[r.name] = {"epoch": e.epoch, "version": e.version,
                              "rows": rows, "kind": e.obj.kind,
                              "streaming": bool(e.streaming)}
        return stamps

    def _maybe_materialize(self, query: PolyOp, sig: str, value) -> None:
        """Attach a full serve's result to the signature's cache entry as a
        materialized view (only when incremental serving is on and the query
        touches at least one streaming table — views over static tables
        would never be patched, only invalidated)."""
        if not self.incremental or MASK_SEP in sig:
            return
        stamps = self._ref_stamps(query)
        if not stamps or not any(st["streaming"] for st in stamps.values()):
            return
        with self._cache_lock:
            entry = self.plan_cache.get(sig)
            if entry is not None:
                # host-resident like the streaming tables it tracks: the
                # patch concat then runs in numpy (compile-free) instead of
                # re-jitting for every grown view shape
                entry.view = MaterializedView(tables.host_copy(value),
                                              stamps)

    def _try_incremental(self, query: PolyOp, sig: str, entry: CachedPlan,
                         span: Optional[tracing.Span] = None
                         ) -> Optional[Report]:
        """Serve from the materialized view when the only drift since
        materialization is appended rows on streaming tables: derive (once
        per change set) the ``deltaplan`` update fragment, price it against
        the full recompute, execute it over the pending suffixes through the
        ordinary concurrent executor path, and patch the view.  Returns None
        — full recompute, never wrong — when the view is stale in any other
        way (re-registration, shrinkage, kind change), the lineage is not
        provably incremental, the cost model prefers recomputing, or the
        delta execution fails.  Deliberately feeds NEITHER the monitor nor
        the health stragglers: a delta serve's near-zero per-node seconds
        would corrupt the full-serve statistics both consume."""
        view = entry.view
        if view is None:
            return None
        t0 = time.perf_counter()
        stamps = self._ref_stamps(query)
        if stamps is None or set(stamps) != set(view.refs):
            entry.view = None
            return None
        changed: Dict[str, int] = {}
        for name, st in stamps.items():
            old = view.refs[name]
            if old.get("kind") != st["kind"] or \
                    (not view.restored and old.get("epoch") != st["epoch"]):
                entry.view = None     # re-registered / re-homed: the content
                return None           # may be unrelated at equal row counts
            o_rows, n_rows = old.get("rows"), st["rows"]
            if st["streaming"] and o_rows is not None \
                    and n_rows is not None and n_rows > o_rows:
                changed[name] = int(o_rows)
            elif o_rows != n_rows:
                # shrunk, or a non-streaming table grew: not append history
                entry.view = None
                return None
        if view.restored:
            # persisted by another process (or a previous life): the stamps
            # carry foreign epochs, so the check above trusted (kind, rows)
            # identity — the procpool deployment contract, every worker
            # registers the same tables.  Adopt this process's epochs so
            # later re-registrations invalidate normally
            view.restored = False
            for name, st in stamps.items():
                view.refs[name]["epoch"] = st["epoch"]
                view.refs[name]["version"] = st["version"]
        if not changed:
            # nothing drifted at all: the view IS the answer
            self.metrics.counter("bd.ivm_serves")
            return Report(view.value, entry.plan.key, "production",
                          time.perf_counter() - t0, 0.0, sig, cache_hit=True,
                          predicted_s=entry.predicted_s, incremental=True)
        if len(changed) > 1:
            # multi-table appends must align (the only derivable multi-hot
            # ops, add-family, consume their operands row-for-row): equal
            # old sizes and equal delta sizes, else recompute
            if len({changed[n] for n in changed}) > 1 or \
                    len({stamps[n]["rows"] - changed[n]
                         for n in changed}) > 1:
                self.metrics.counter("bd.ivm_fallbacks")
                return None
        key = frozenset(changed)
        if key not in view.update_plans:
            view.update_plans[key] = deltaplan.derive(
                query, set(key),
                kinds={n: st["kind"] for n, st in stamps.items()})
        up = view.update_plans[key]
        if up is None:               # proven non-incremental for this set
            self.metrics.counter("bd.ivm_fallbacks")
            return None
        # bind each pending suffix under its delta name in a temporary
        # catalog overlay — the fragment executes through the ordinary
        # planner/executor path against it
        tmp = dict(self.catalog)
        for name, old_rows in changed.items():
            src = self.catalog[name]
            dn = deltaplan.delta_name(name)
            tmp[dn] = CatalogEntry(dn, tables.suffix_rows(src.obj, old_rows),
                                   src.engine)
        # restrict the fragment's planning to the incumbent plan's engine
        # set (plus the root island's natives, for the delivery scope): the
        # delta operands are tiny, and an unconstrained DP flips to
        # cast-heavy placements the full serve never validated
        from repro.core.islands import scope_candidates
        allowed = {eng for _, eng in entry.plan.assignment}
        allowed.update(scope_candidates(up.fragment.island))
        mask = frozenset(e for e in ENGINES if e not in allowed)
        try:
            price, fplan = price_incremental(
                up.fragment, tmp, cost_model=self.cost_model,
                view_bytes=float(getattr(view.value, "nbytes", 0.0)),
                full_s=entry.predicted_s or
                self._predict(query, entry.plan, sig), mask=mask)
        except Exception as exc:
            warnings.warn(f"incremental pricing for {sig!r} failed "
                          f"({exc}); recomputing")
            self.metrics.counter("bd.ivm_fallbacks")
            return None
        if self.incremental != "force" and not price.worthwhile:
            # the delta dominates (or the patch would stream more bytes than
            # recomputing costs): the gate picks the full path
            self.metrics.counter("bd.ivm_fallbacks")
            return None
        try:
            res = execute_plan(up.fragment, fplan, tmp, concurrent=True,
                               cost_model=self.cost_model,
                               health=self.health, trace=span)
            merged = deltaplan.apply_update(up, view.value, res.value)
        except EngineDown:
            raise    # the failover driver owns breaker-feeding and retries
        except Exception as exc:
            warnings.warn(f"incremental update for {sig!r} failed ({exc}); "
                          f"dropping the view and recomputing")
            entry.view = None
            self.metrics.counter("bd.ivm_fallbacks")
            return None
        with self._cache_lock:
            view.value = merged
            view.refs = stamps
        seconds = time.perf_counter() - t0
        self.metrics.counter("bd.ivm_serves")
        self.metrics.counter("bd.serve_seconds", seconds)
        self.metrics.observe("bd.serve_latency", seconds)
        return Report(merged, entry.plan.key, "production", seconds,
                      res.cast_bytes, sig, cache_hit=True,
                      predicted_s=entry.predicted_s, incremental=True)

    def _production(self, query: PolyOp, sig: str,
                    span: Optional[tracing.Span] = None) -> Report:
        usage = usage_snapshot()
        # the "plan" span covers plan SELECTION (monitor lookup + cache
        # resolution); it is ended explicitly before any fall-through to
        # _train so training time never hides inside it
        pspan = span.child("plan", sig=sig) if span is not None else None
        plan_key, stats, drifted = self.monitor.best(sig, usage)
        if plan_key is None:
            if pspan is not None:
                pspan.end()
            return self._train(query, sig, span=span)
        if drifted:
            # usage changed too much since training — re-train now, queue the
            # DP's true runner-up plans for background exploration (not the
            # monitor's historical leftovers, which may never have been
            # planner candidates under the current sizes)
            with self._cache_lock:
                self.plan_cache.pop(sig, None)
            if pspan is not None:
                pspan.end()
            rep = self._train(query, sig, span=span)
            for alt in self.plan_cache[sig].alternates:
                self.monitor.queue_background(sig, alt.key)
            rep.drifted = True
            return rep
        with self._cache_lock:
            entry = self.plan_cache.get(sig)
            if entry is not None and entry.pinned:
                # freshly re-planned entry: serve the DP's new choice once
                # ahead of monitor history so its measured seconds enter the
                # comparison
                plan, plan_key, hit = entry.plan, entry.plan.key, True
                entry.pinned = False
            else:
                hit = entry is not None and entry.plan.key == plan_key
                if hit:
                    plan = entry.plan
                else:
                    try:
                        plan = _plan_from_key(plan_key)
                    except ValueError as exc:    # corrupted monitor history
                        warnings.warn(f"monitor best for {sig!r} unusable "
                                      f"({exc}); retraining")
                        # retrain OUTSIDE the cache lock: training runs every
                        # candidate plan — holding the global lock that long
                        # would stall every other signature's serve
                        plan = None
                    if plan is not None:
                        # measured history as the baseline (stats exist:
                        # best() just picked this plan by mean seconds) —
                        # model predictions are only baselines when no
                        # measurement is available.  An exploration win lands
                        # here: the promoted alternate keeps the old entry's
                        # alternate pool (incumbent included) so exploration
                        # continues to challenge it
                        alts = ()
                        view = None
                        if entry is not None:
                            alts = tuple(
                                p for p in (entry.plan,) + entry.alternates
                                if p.key != plan_key)[:self.MAX_ALTERNATES]
                            # view validity is plan-independent (query +
                            # data only) — a promoted alternate keeps it
                            view = entry.view
                        entry = CachedPlan(plan,
                                           stats.mean_seconds if stats.n
                                           else self._predict(query, plan,
                                                              sig),
                                           alternates=alts, view=view)
                        self.plan_cache[sig] = entry
        if plan is None:
            if pspan is not None:
                pspan.end()
            return self._train(query, sig, span=span)
        if len(plan.assignment) != len(query.nodes()):
            # a persisted entry (or hand-edited history) for a different
            # query shape under this signature: unusable, retrain
            warnings.warn(f"plan for {sig!r} covers {len(plan.assignment)} "
                          f"positions, query has {len(query.nodes())}; "
                          f"retraining")
            with self._cache_lock:
                self.plan_cache.pop(sig, None)
            if pspan is not None:
                pspan.end()
            return self._train(query, sig, span=span)
        if pspan is not None:
            pspan.annotate(plan_key=plan_key)
            pspan.end()
            span.event("cache_hit" if hit else "cache_miss",
                       plan_key=plan_key)
        if self.incremental:
            ispan = span.child("ivm_patch", sig=sig) if span is not None \
                else None
            served = False
            try:
                rep = self._try_incremental(query, sig, entry, span=ispan)
                served = rep is not None
            finally:
                if ispan is not None:
                    ispan.annotate(served=served)
                    ispan.end()
            if rep is not None:
                return rep
        res = execute_plan(query, plan, self.catalog, concurrent=True,
                           cost_model=self.cost_model, health=self.health,
                           fused=self._fused_for(query, plan, entry),
                           trace=span)
        self._note_fusion(res)
        if res.fusion_cold_compiles:
            # first serve of a fused segment signature at these shapes: the
            # wall time includes trace+compile, a one-off.  Treat the serve
            # as a warm-up — neither the plan's measured mean nor the
            # divergence re-plan trigger may see the compile spike (sizes/
            # shapes were already learned from the unfused training serves)
            replanned = False
        else:
            self.monitor.record(sig, plan_key, res.seconds,
                                cast_bytes=res.cast_bytes, usage=usage,
                                sizes=res.size_obs, shapes=res.shape_obs)
            after = self.monitor.known_plans(sig).get(plan_key)
            measured = after.mean_seconds if after is not None and after.n \
                else res.seconds
            replanned = self._maybe_replan(query, sig, measured, entry)
        self.metrics.counter("bd.serve_seconds", res.seconds)
        self.metrics.observe("bd.serve_latency", res.seconds)
        self._maybe_materialize(query, sig, res.value)
        explored_key = self._maybe_explore(query, sig, usage)
        return Report(res.value, plan_key, "production", res.seconds,
                      res.cast_bytes, sig, cache_hit=hit, replanned=replanned,
                      predicted_s=entry.predicted_s,
                      explored=bool(explored_key), explored_key=explored_key,
                      per_node_seconds=_pos_seconds(query, res),
                      fused_segments=res.fused_segments,
                      fusion_fallbacks=res.fusion_fallbacks)

    def _maybe_explore(self, query: PolyOp, sig: str,
                       usage: Dict[str, float]) -> str:
        """Budgeted alternate exploration (paper: the monitor "continuously"
        tries alternate plans), OFF the request path: pick the next DP
        runner-up in rotation and schedule it as a background task on the
        executor's host pool.  The serve returns immediately; the task feeds
        its measured seconds/sizes/shapes to the monitor's batched record
        queue (which the planner and cost model consume on every later
        planning pass).  Scheduling happens only while cumulative
        exploration time stays within ``explore_budget`` x cumulative serve
        time (at most one in-flight trial per signature, so the overshoot is
        bounded by one trial).  Returns the scheduled plan key, or '' when
        nothing was scheduled."""
        if self.explore_budget <= 0.0:
            return ""
        if self.metrics.value("bd.explore_seconds") > \
                self.explore_budget * self.metrics.value("bd.serve_seconds"):
            return ""
        with self._explore_guard:
            if sig in self._explore_inflight:    # one trial per sig at a time
                return ""                        # (before burning a rotation
        n_pos = len(query.nodes())               # slot on a skipped serve)
        with self._cache_lock:               # alternate rotation is shared
            entry = self.plan_cache.get(sig)
            if entry is None or not entry.alternates:
                return ""
            for _ in range(len(entry.alternates)):
                alt = entry.alternates[entry.next_alt % len(entry.alternates)]
                entry.next_alt += 1
                if len(alt.assignment) == n_pos and alt.key != entry.plan.key:
                    break
            else:
                return ""
        with self._explore_guard:
            # same-signature callers hold the signature lock, so the
            # inflight check above cannot race another scheduler for sig
            self._explore_inflight.add(sig)
            self._explore_futures = [f for f in self._explore_futures
                                     if not f.done()]
            self._explore_futures.append(host_pool().submit(
                self._explore_task, query, sig, alt, dict(usage)))
        return alt.key

    def _explore_task(self, query: PolyOp, sig: str, alt: Plan,
                      usage: Dict[str, float]) -> None:
        """One background alternate trial (runs on a host-pool worker).

        Level dispatch is concurrent-but-inline (``host_workers=1``): a pool
        worker must never submit to its own pool (a saturated pool would
        deadlock on the level barrier).  The auto gate keeps serve-path
        levels inline for sub-threshold tasks anyway, so the alternate's
        measured mean stays comparable to the incumbent's for exactly the
        levels where threading could have diverged them.  The COST MODEL is
        deliberately NOT fed here: background-mode cast hops time worker
        contention, and folding them into cast_rate would corrupt the
        calibration that training keeps sequential-only.  The model still
        benefits through the monitor channel (sizes/shapes sharpen its size
        inputs)."""
        try:
            res = execute_plan(query, alt, self.catalog, concurrent=True,
                               host_workers=1, cost_model=self.cost_model)
            self.metrics.counter("bd.explore_seconds", res.seconds)
            self.metrics.counter("bd.explorations")
            self.monitor.record(sig, alt.key, res.seconds,
                                cast_bytes=res.cast_bytes, usage=usage,
                                sizes=res.size_obs, shapes=res.shape_obs)
        except Exception as exc:     # an alternate that fails must not take
            warnings.warn(           # down the worker or block the drain
                f"background exploration of {alt.key!r} for {sig!r} "
                f"failed: {exc}")
            # evict it from the rotation: a doomed alternate charges no
            # explore_seconds, so the budget would never stop the serve path
            # from rescheduling it on every request
            with self._cache_lock:
                entry = self.plan_cache.get(sig)
                if entry is not None:
                    entry.alternates = tuple(p for p in entry.alternates
                                             if p.key != alt.key)
        finally:
            with self._explore_guard:
                self._explore_inflight.discard(sig)

    def reset_exploration_budget(self) -> None:
        """Zero the exploration-budget accounting (``explore_seconds`` and
        ``serve_seconds``).  The budget check compares *cumulative* totals,
        so a long stretch of cheap trials banks credit that a later busy
        phase can burn in a burst; epoch-style callers (benchmarks, load
        phases) re-anchor here so every phase sees the same steady-state
        ``explore_budget`` fraction."""
        self.metrics.set_counter("bd.explore_seconds", 0.0)
        self.metrics.set_counter("bd.serve_seconds", 0.0)

    def persist(self) -> None:
        """Flush all persistent state — monitor DB, cost-model calibration
        and plan cache — to their side-by-side files, waiting for in-flight
        background explorations first so their measurements are included
        (no-ops for components constructed without a path).  The one flush
        sequence `Session.persist` and `QueryServer.persist` both call."""
        self.drain_explorations()
        self.monitor.save()
        self.cost_model.save()
        self.save_plan_cache()
        self.save_views()
        self._save_health()
        self.metrics.save()

    def drain_explorations(self, timeout: Optional[float] = None) -> int:
        """Block until all in-flight background exploration trials finish
        (their measurements are then in the monitor's pending queue).
        Returns how many finished futures were retired.  With a ``timeout``
        (per future, seconds), ``concurrent.futures.TimeoutError``
        propagates and the unfinished trials STAY tracked — a later drain
        (or ``QueryServer.persist()``) still waits for them."""
        with self._explore_guard:
            futures = list(self._explore_futures)
        try:
            for f in futures:
                f.exception(timeout=timeout)   # surface nothing, just wait
        finally:
            with self._explore_guard:          # retire only what finished;
                done = sum(1 for f in futures if f.done())
                self._explore_futures = [f for f in self._explore_futures
                                         if not f.done()]
        return done

    # -- resilient serving ---------------------------------------------------
    def _serve_masked(self, query: PolyOp, sig: str, mask: FrozenSet[str],
                      span: Optional[tracing.Span] = None) -> Report:
        """Failover/degraded serve: plan and execute with ``mask`` engines
        excluded.  The plan comes from a mask-keyed cache entry (first
        request under a given mask pays one cheap k=1 DP; the rest of the
        outage serves cached) and the measurement is recorded under the
        mask-keyed monitor signature — the unmasked signature's history
        never sees degraded runs, so when the breaker closes,
        ``monitor.best(sig)`` still names the pre-failure incumbent and the
        half-open probe restores it verbatim.  Raises ``PlanInfeasible``
        when the mask leaves some op with no engine."""
        mkey = masked_sig(sig, mask)
        with self._cache_lock:
            entry = self.plan_cache.get(mkey)
            hit = entry is not None
        if entry is None:
            ranked = dp_plans(query, self.catalog, max_plans=1,
                              cost_model=self.cost_model,
                              measured_sizes=self.monitor.measured_sizes(sig),
                              measured_shapes=self.monitor.measured_shapes(
                                  sig),
                              mask=mask)
            cost, plan = ranked[0]
            entry = CachedPlan(plan, cost)
            with self._cache_lock:
                entry = self.plan_cache.setdefault(mkey, entry)
        res = execute_plan(query, entry.plan, self.catalog, concurrent=True,
                           cost_model=self.cost_model, health=self.health,
                           fused=self._fused_for(query, entry.plan, entry),
                           trace=span)
        self._note_fusion(res)
        if not res.fusion_cold_compiles:   # compile spikes stay out of the
            self.monitor.record(mkey, entry.plan.key, res.seconds,
                                cast_bytes=res.cast_bytes,
                                usage=usage_snapshot(),   # masked mean too
                                sizes=res.size_obs, shapes=res.shape_obs)
        self.metrics.counter("bd.serve_seconds", res.seconds)
        self.metrics.observe("bd.serve_latency", res.seconds)
        return Report(res.value, entry.plan.key, "production", res.seconds,
                      res.cast_bytes, sig, cache_hit=hit,
                      predicted_s=entry.predicted_s,
                      per_node_seconds=_pos_seconds(query, res),
                      fused_segments=res.fused_segments,
                      fusion_fallbacks=res.fusion_fallbacks)

    def _feed_health(self, rep: Report) -> None:
        """Feed one successful serve to the health registry: the executed
        plan's per-node (engine, seconds) pairs drive the per-engine
        straggler detectors and reset/close the breakers."""
        try:
            pairs = _plan_from_key(rep.plan_key).assignment
        except ValueError:
            return
        self.health.after_plan(
            (eng, rep.per_node_seconds.get(pos, 0.0)) for pos, eng in pairs)

    def _execute_resilient(self, query: PolyOp, sig: str, mode: str,
                           degrade: bool,
                           span: Optional[tracing.Span] = None) -> Report:
        """The failover driver (requires ``self.health``): plan under the
        current breaker mask, execute, and on ``EngineDown`` retry — the
        failed attempt fed the engine's breaker, so retries first burn the
        breaker's failure threshold on the incumbent path and then (breaker
        open, engine masked) re-plan around the dead engine.  Bounded: once
        every breaker could have tripped, the last ``EngineDown`` is
        surfaced (everything is down).  ``degrade`` additionally masks every
        non-always-up engine — the server's graceful-degradation path under
        overload."""
        health = self.health
        limit = 1 + sum(br.failure_threshold
                        for br in health.breakers.values())
        failovers = 0
        while True:
            mask, probes = health.mask_for_request()
            if degrade:
                mask = frozenset(mask | health.degrade_mask())
            try:
                rep = self._serve_masked(query, sig, mask, span=span) \
                    if mask else self._dispatch(query, sig, mode, span=span)
            except EngineDown as exc:
                failovers += 1
                self.metrics.counter("bd.failovers")
                if span is not None:
                    span.event("failover", engine=exc.engine, op=exc.op)
                if failovers >= limit:
                    raise
                continue
            except PlanInfeasible:
                if degrade:
                    # the degrade mask (on top of tripped breakers) left
                    # some op with no engine — degrading was too aggressive
                    # for this query; retry with the breaker mask alone
                    degrade = False
                    continue
                raise
            finally:
                health.release_probes(probes)
            if not rep.incremental:
                # a delta serve's near-zero per-node seconds would feed the
                # straggler z-stats a stream of false outliers-in-reverse
                # and skew every engine's mean toward zero
                self._feed_health(rep)
            rep.failovers = failovers
            rep.degraded = bool(mask)
            rep.status = "degraded" if mask else "ok"
            return rep

    @property
    def breaker_trips(self) -> int:
        """Lifetime circuit-breaker trips across engines (0 without a
        health registry) — surfaced as ``QueryServer.stats["breaker_trips"]``."""
        return self.health.trips() if self.health is not None else 0

    # -- public API ----------------------------------------------------------
    def _dispatch(self, query: PolyOp, sig: str, mode: str,
                  span: Optional[tracing.Span] = None) -> Report:
        """The paper's phase protocol (caller holds the signature lock)."""
        if mode == "training":
            return self._train(query, sig, span=span)
        if mode == "production":
            return self._production(query, sig, span=span)
        if mode == "auto":
            known, _, _ = self.monitor.best(sig)
            return self._production(query, sig, span=span) if known else \
                self._train(query, sig, span=span)
        raise ValueError(mode)

    def execute(self, query: PolyOp, mode: str = "auto", *,
                degrade: bool = False,
                trace_ctx: Optional[Tuple[str, Optional[str]]] = None
                ) -> Report:
        """Thread-safe entry point.  Requests for the SAME signature are
        serialized on a per-signature lock — two cold requests racing in
        ``auto`` mode train exactly once: the loser blocks, then re-checks
        the monitor inside the lock and serves the winner's fresh plan.
        Requests for different signatures hold different locks and
        train/serve fully in parallel.

        With a health registry (``BigDAWG(health=...)``) the request runs
        through the failover driver: tripped engines are masked out of
        planning, ``EngineDown`` mid-plan retries (re-planning around the
        dead engine once its breaker opens), and the Report carries
        ``status``/``degraded``/``failovers``.  ``degrade=True`` (the
        server's overload path) plans on the always-up engine set only.

        With tracing on (``BigDAWG(trace=True)``), or when an upstream
        process propagated a ``trace_ctx`` ``(trace_id, parent_span_id)``
        across the pipe RPC, the request records a span tree returned on
        ``Report.trace`` — a root ``request`` span over plan / train /
        cast / engine_op / ivm_patch / failover children."""
        sig = signature(query, self.catalog)
        trace = self.tracer.start(trace_ctx)
        span = trace.root("request", sig=sig, mode=mode) \
            if trace is not None else None
        try:
            with self._sig_lock(sig):
                if self.health is not None:
                    rep = self._execute_resilient(query, sig, mode, degrade,
                                                  span=span)
                else:
                    rep = self._dispatch(query, sig, mode, span=span)
        finally:
            if span is not None:
                span.end()
        rep.trace = trace
        return rep

    def run_background_queue(self, query_by_sig: Dict[str, PolyOp]):
        """Re-explore queued alternate plans 'when the system is
        underutilized' (paper §III-C-3)."""
        done = 0
        while True:
            item = self.monitor.pop_background()     # atomic: two drainers
            if item is None:                         # cannot double-pop
                break
            sig, plan_key = item
            if sig not in query_by_sig:
                continue
            query = query_by_sig[sig]
            try:
                plan = _plan_from_key(plan_key)
                if len(plan.assignment) != len(query.nodes()):
                    raise ValueError(f"plan covers {len(plan.assignment)} "
                                     f"positions, query has "
                                     f"{len(query.nodes())}")
            except ValueError as exc:    # corrupted history: skip, keep
                warnings.warn(f"background queue: skipping bad plan for "
                              f"{sig!r}: {exc}")       # draining the rest
                continue
            # concurrent, like production: exploration exists to challenge the
            # incumbent's production-mode mean, so its seconds must be
            # measured under the same dispatch mode or the comparison is
            # structurally biased toward whichever plan won training
            res = execute_plan(query, plan,
                               self.catalog, concurrent=True,
                               cost_model=self.cost_model)
            self.monitor.record(sig, plan_key, res.seconds,
                                cast_bytes=res.cast_bytes, sizes=res.size_obs,
                                shapes=res.shape_obs)
            done += 1
        return done
