"""BigDAWG middleware facade (paper Fig. 3): planner + monitor + executor +
migrator behind one ``execute()`` entry point with the training/production
phase protocol of §III-C-3, plus the adaptive feedback loop the paper's
monitor sketches ("collects performance data ... and uses it to improve
future plans"):

  training   — enumerate candidate plans via the cost-model DP (sized from
               measured intermediate sizes where history exists), run (up to
               ``train_plans`` of) them sequentially (per-node timings feed
               the calibrated cost model), record stats + actual sizes,
               return the best run's result, and cache the winning Plan with
               its predicted cost.
  production — serve from the signature-keyed plan cache (no re-enumeration,
               no plan-key parsing), dispatching DAG levels concurrently; on
               signature miss fall back to training; on usage drift, re-train
               (paper: "rerun the query under the training phase under the
               current usage") and queue the losers for background
               exploration.  After every run, the measured seconds are
               compared against the cached plan's predicted cost: divergence
               beyond ``replan_factor`` invalidates the entry and re-runs the
               cheap DP under the updated cost model + measured sizes
               (online re-planning, no training-phase trials needed).
  auto       — production if the signature is known, else training.

The plan cache persists beside the monitor DB (``<monitor>.plans.json``,
atomic JSON via ``ioutil``), so a restarted production process serves
previously-trained signatures warm — zero plan enumerations.
"""
from __future__ import annotations

import os
import warnings
from dataclasses import dataclass
from typing import Any, Dict, Optional

from repro.core.costmodel import CostModel, default_calibration_path
from repro.core.engines import ENGINES
from repro.core.executor import ExecutionResult, execute_plan
from repro.core.ioutil import atomic_json_dump, load_json
from repro.core.monitor import Monitor, usage_snapshot
from repro.core.ops import PolyOp
from repro.core.planner import (Plan, dp_plans, estimate_sizes, plan_cost)
from repro.core.signature import signature


def _plan_from_key(plan_key: str) -> Plan:
    """Parse ``pos:engine|pos:engine|...``; raises ValueError on malformed or
    unknown-engine keys (callers decide whether to skip or retrain)."""
    try:
        pairs = tuple((int(u), e) for u, e in
                      (p.split(":") for p in plan_key.split("|")))
    except (ValueError, AttributeError) as exc:
        raise ValueError(f"malformed plan key {plan_key!r}") from exc
    for _, eng in pairs:
        if eng not in ENGINES:
            raise ValueError(f"plan key {plan_key!r} names unknown engine "
                             f"{eng!r}")
    if [u for u, _ in pairs] != list(range(len(pairs))):
        raise ValueError(f"plan key {plan_key!r} positions are not "
                         f"consecutive from 0")
    return Plan(pairs)


def default_plan_cache_path(monitor_path: Optional[str]) -> Optional[str]:
    """Plan-cache file that rides alongside a monitor DB path."""
    if not monitor_path:
        return None
    root, _ = os.path.splitext(monitor_path)
    return root + ".plans.json"


@dataclass
class CatalogEntry:
    name: str
    obj: Any                 # a tables.* container
    engine: str              # home engine


@dataclass
class CachedPlan:
    """A plan-cache entry: the winning Plan plus the predicted cost it was
    cached under (the baseline the online re-planner diverges against)."""
    plan: Plan
    predicted_s: float = 0.0
    # a freshly re-planned entry is served once ahead of monitor history so
    # its measured seconds enter the history and the comparison is live
    pinned: bool = False
    # loaded from a persisted cache: the first serve re-syncs the prediction
    # to this process's runtime instead of re-planning (a cold jit cache can
    # legitimately be >2x slower than the recording process was)
    restored: bool = False


@dataclass
class Report:
    result: Any
    plan_key: str
    mode: str                # "training" | "production"
    seconds: float
    cast_bytes: float
    sig: str
    plans_tried: int = 1
    drifted: bool = False
    cache_hit: bool = False  # plan came from the signature-keyed plan cache
    replanned: bool = False  # predicted/measured divergence re-ran the DP
    predicted_s: float = 0.0  # cached prediction for the executed plan


class BigDAWG:
    # measured/predicted divergence factor that triggers online re-planning
    REPLAN_FACTOR = 2.0

    def __init__(self, monitor: Optional[Monitor] = None,
                 train_plans: int = 8, train_repeats: int = 2,
                 cost_model: Optional[CostModel] = None,
                 calibrate: bool = False,
                 plan_cache_path: Optional[str] = None,
                 replan_factor: float = REPLAN_FACTOR):
        self.catalog: Dict[str, CatalogEntry] = {}
        self.monitor = monitor or Monitor()
        self.train_plans = train_plans
        # run each candidate plan this many times during training and record
        # only the last — first-run jit/compile cost would otherwise bias the
        # monitor toward never-compiled plans (cold-start bias)
        self.train_repeats = max(1, train_repeats)
        # cost model persists alongside the monitor DB when the latter has one
        self.cost_model = cost_model or CostModel(
            default_calibration_path(self.monitor.path))
        if calibrate and not self.cost_model.calibrated:
            self.cost_model.calibrate()
        self.replan_factor = replan_factor
        self.replans = 0
        # signature -> CachedPlan: production requests skip re-enumeration
        # and plan-key parsing entirely; persisted beside the monitor DB so
        # restarted processes serve warm
        self.plan_cache: Dict[str, CachedPlan] = {}
        self.plan_cache_path = plan_cache_path or default_plan_cache_path(
            self.monitor.path)
        if self.plan_cache_path and os.path.exists(self.plan_cache_path):
            self.load_plan_cache(self.plan_cache_path)

    # -- catalog -----------------------------------------------------------
    def register(self, name: str, obj, engine: str):
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine}")
        if ENGINES[engine].kind != obj.kind:
            from repro.core import cast as castmod
            obj = castmod.cast(obj, ENGINES[engine].kind, self.cost_model)
        self.catalog[name] = CatalogEntry(name, obj, engine)

    # -- plan-cache persistence ---------------------------------------------
    def save_plan_cache(self, path: Optional[str] = None):
        path = path or self.plan_cache_path
        if not path:
            return
        blob = {"format": 1,
                "entries": {sig: {"plan": e.plan.key,
                                  "predicted_s": e.predicted_s}
                            for sig, e in self.plan_cache.items()}}
        atomic_json_dump(path, blob)

    def load_plan_cache(self, path: str):
        """Load a persisted plan cache, skipping (with a warning) any entry a
        hand edit or corruption has mangled — bad entries, or a whole file
        that no longer parses, must not take down the warm-start path."""
        try:
            blob = load_json(path)
        except (OSError, ValueError) as exc:   # JSONDecodeError is a ValueError
            warnings.warn(f"plan cache {path}: unreadable ({exc}); "
                          f"starting cold")
            return
        entries = blob.get("entries", {}) if isinstance(blob, dict) else {}
        for sig, ent in entries.items():
            try:
                if not isinstance(ent, dict):
                    raise ValueError(f"entry for {sig!r} is not an object")
                plan = _plan_from_key(ent["plan"])
                self.plan_cache[sig] = CachedPlan(
                    plan, float(ent.get("predicted_s", 0.0)), restored=True)
            except (ValueError, KeyError, TypeError) as exc:
                warnings.warn(f"plan cache {path}: skipping bad entry "
                              f"{sig!r}: {exc}")

    # -- phases --------------------------------------------------------------
    def _predict(self, query: PolyOp, plan: Plan, sig: str) -> float:
        """Current predicted seconds for a plan, under measured sizes."""
        sizes = estimate_sizes(query, self.catalog,
                               measured=self.monitor.measured_sizes(sig))
        return plan_cost(query, plan, self.catalog, self.cost_model,
                         sizes=sizes)

    def _train(self, query: PolyOp, sig: str) -> Report:
        ranked = dp_plans(query, self.catalog, max_plans=self.train_plans,
                          cost_model=self.cost_model,
                          measured_sizes=self.monitor.measured_sizes(sig))
        best: Optional[ExecutionResult] = None
        usage = usage_snapshot()
        for _, plan in ranked:
            # sequential warm-up runs: kill cold-start jit bias AND feed
            # honest per-node timings to the cost model (sequential only)
            for _ in range(self.train_repeats):
                res = execute_plan(query, plan, self.catalog,
                                   cost_model=self.cost_model)
            self.cost_model.observe_execution(res)
            # the RECORDED measurement uses concurrent dispatch — the same
            # mode production executes in, so every seconds value a
            # Monitor.best() comparison sees is from one dispatch mode
            res = execute_plan(query, plan, self.catalog, concurrent=True,
                               cost_model=self.cost_model)
            self.monitor.record(sig, plan.key, res.seconds,
                                cast_bytes=res.cast_bytes, usage=usage,
                                sizes=res.size_obs)
            if best is None or res.seconds < best.seconds:
                best = res
        # the cached prediction is recomputed AFTER the training observations
        # and size measurements landed — the freshest model state, the
        # baseline online re-planning diverges against.  If the model is
        # still off by more than the replan factor from the measurement we
        # JUST took, the measurement is the better baseline (caching a known-
        # bad prediction would trigger a pointless re-plan on the very next
        # production run)
        predicted = self._predict(query, best.plan, sig)
        if self._diverged(predicted, best.seconds):
            predicted = best.seconds
        self.plan_cache[sig] = CachedPlan(best.plan, predicted)
        self.cost_model.save()
        self.monitor.save()
        self.save_plan_cache()
        return Report(best.value, best.plan.key, "training", best.seconds,
                      best.cast_bytes, sig, plans_tried=len(ranked),
                      predicted_s=predicted)

    def _diverged(self, predicted: float, measured: float) -> bool:
        """The online re-planner's divergence policy: prediction and
        measurement disagree by more than ``replan_factor`` in either
        direction (non-positive values never diverge)."""
        if predicted <= 0.0 or measured <= 0.0:
            return False
        return max(measured / predicted,
                   predicted / measured) > self.replan_factor

    def _maybe_replan(self, query: PolyOp, sig: str, measured: float,
                      entry: CachedPlan) -> bool:
        """Online re-planning: >replan_factor divergence between the measured
        cost (the monitor's history-damped mean for the served plan — a
        single run's timing noise on short queries can exceed the factor by
        itself) and the cached prediction invalidates the entry and re-runs
        the cheap DP under the updated cost model + measured sizes."""
        pred = entry.predicted_s
        if measured <= 0.0:
            return False
        if entry.restored:
            # first serve after a warm restart: a cold jit cache makes this
            # run incomparable to the recording process's baseline — re-sync
            # the prediction instead of re-planning.  A restored entry with
            # no usable baseline (predicted_s missing from the file -> 0.0)
            # must also adopt the measurement, or the loop stays dead
            entry.restored = False
            if pred <= 0.0 or self._diverged(pred, measured):
                entry.predicted_s = measured
            return False
        if pred <= 0.0 or not self._diverged(pred, measured):
            return False
        # the "cheap DP": only the new optimum is consumed, so k=1 (per-engine
        # fronts keep the top-1 exact — see dp_plans)
        ranked = dp_plans(query, self.catalog, max_plans=1,
                          cost_model=self.cost_model,
                          measured_sizes=self.monitor.measured_sizes(sig))
        cost, plan = ranked[0]
        if plan.key == entry.plan.key:
            # same plan still wins — the divergence is model form error, not
            # a placement mistake; adopt the measured cost as the entry's
            # prediction so a stable runtime stops re-triggering
            self.plan_cache[sig] = CachedPlan(plan, measured)
        else:
            # prefer the plan's measured history (training trials measured
            # every candidate) over the raw model cost as the new baseline —
            # a model-based baseline could itself diverge and cascade
            stats = self.monitor.known_plans(sig).get(plan.key)
            pred_new = stats.mean_seconds if stats is not None and stats.n \
                else cost
            self.plan_cache[sig] = CachedPlan(plan, pred_new, pinned=True)
        self.replans += 1
        self.save_plan_cache()
        return True

    def _production(self, query: PolyOp, sig: str) -> Report:
        usage = usage_snapshot()
        plan_key, stats, drifted = self.monitor.best(sig, usage)
        if plan_key is None:
            return self._train(query, sig)
        if drifted:
            # usage changed too much since training — re-train now, queue the
            # alternates for background exploration
            self.plan_cache.pop(sig, None)
            rep = self._train(query, sig)
            for pk in self.monitor.known_plans(sig):
                if pk != rep.plan_key:
                    self.monitor.queue_background(sig, pk)
            rep.drifted = True
            return rep
        entry = self.plan_cache.get(sig)
        if entry is not None and entry.pinned:
            # freshly re-planned entry: serve the DP's new choice once ahead
            # of monitor history so its measured seconds enter the comparison
            plan, plan_key, hit = entry.plan, entry.plan.key, True
            entry.pinned = False
        else:
            hit = entry is not None and entry.plan.key == plan_key
            if hit:
                plan = entry.plan
            else:
                try:
                    plan = _plan_from_key(plan_key)
                except ValueError as exc:    # corrupted monitor history
                    warnings.warn(f"monitor best for {sig!r} unusable "
                                  f"({exc}); retraining")
                    return self._train(query, sig)
                # measured history as the baseline (stats exist: best() just
                # picked this plan by mean seconds) — model predictions are
                # only baselines when no measurement is available
                entry = CachedPlan(plan, stats.mean_seconds if stats.n
                                   else self._predict(query, plan, sig))
                self.plan_cache[sig] = entry
        if len(plan.assignment) != len(query.nodes()):
            # a persisted entry (or hand-edited history) for a different
            # query shape under this signature: unusable, retrain
            warnings.warn(f"plan for {sig!r} covers {len(plan.assignment)} "
                          f"positions, query has {len(query.nodes())}; "
                          f"retraining")
            self.plan_cache.pop(sig, None)
            return self._train(query, sig)
        res = execute_plan(query, plan, self.catalog, concurrent=True,
                           cost_model=self.cost_model)
        self.monitor.record(sig, plan_key, res.seconds,
                            cast_bytes=res.cast_bytes, usage=usage,
                            sizes=res.size_obs)
        after = self.monitor.known_plans(sig).get(plan_key)
        measured = after.mean_seconds if after is not None and after.n \
            else res.seconds
        replanned = self._maybe_replan(query, sig, measured, entry)
        return Report(res.value, plan_key, "production", res.seconds,
                      res.cast_bytes, sig, cache_hit=hit, replanned=replanned,
                      predicted_s=entry.predicted_s)

    # -- public API ----------------------------------------------------------
    def execute(self, query: PolyOp, mode: str = "auto") -> Report:
        sig = signature(query, self.catalog)
        if mode == "training":
            return self._train(query, sig)
        if mode == "production":
            return self._production(query, sig)
        if mode == "auto":
            known, _, _ = self.monitor.best(sig)
            return self._production(query, sig) if known else \
                self._train(query, sig)
        raise ValueError(mode)

    def run_background_queue(self, query_by_sig: Dict[str, PolyOp]):
        """Re-explore queued alternate plans 'when the system is
        underutilized' (paper §III-C-3)."""
        done = 0
        while self.monitor.background_queue:
            sig, plan_key = self.monitor.background_queue.pop()
            if sig not in query_by_sig:
                continue
            query = query_by_sig[sig]
            try:
                plan = _plan_from_key(plan_key)
                if len(plan.assignment) != len(query.nodes()):
                    raise ValueError(f"plan covers {len(plan.assignment)} "
                                     f"positions, query has "
                                     f"{len(query.nodes())}")
            except ValueError as exc:    # corrupted history: skip, keep
                warnings.warn(f"background queue: skipping bad plan for "
                              f"{sig!r}: {exc}")       # draining the rest
                continue
            # concurrent, like production: exploration exists to challenge the
            # incumbent's production-mode mean, so its seconds must be
            # measured under the same dispatch mode or the comparison is
            # structurally biased toward whichever plan won training
            res = execute_plan(query, plan,
                               self.catalog, concurrent=True,
                               cost_model=self.cost_model)
            self.monitor.record(sig, plan_key, res.seconds,
                                cast_bytes=res.cast_bytes, sizes=res.size_obs)
            done += 1
        return done
