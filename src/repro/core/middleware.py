"""BigDAWG middleware facade (paper Fig. 3): planner + monitor + executor +
migrator behind one ``execute()`` entry point with the training/production
phase protocol of §III-C-3.

  training   — enumerate candidate plans, run (up to ``train_plans`` of) them,
               record stats, return the best run's result.
  production — match the query signature in the monitor DB, run the best
               recorded plan; on signature miss fall back to training; on
               usage drift, re-train (paper: "rerun the query under the
               training phase under the current usage") and queue the losers
               for background exploration.
  auto       — production if the signature is known, else training.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, Optional

from repro.core.engines import ENGINES
from repro.core.executor import ExecutionResult, execute_plan
from repro.core.monitor import Monitor, usage_snapshot
from repro.core.ops import PolyOp
from repro.core.planner import Plan, enumerate_plans
from repro.core.signature import signature


def _plan_from_key(plan_key: str) -> Plan:
    return Plan(tuple((int(u), e) for u, e in
                      (p.split(":") for p in plan_key.split("|"))))


@dataclass
class CatalogEntry:
    name: str
    obj: Any                 # a tables.* container
    engine: str              # home engine


@dataclass
class Report:
    result: Any
    plan_key: str
    mode: str                # "training" | "production"
    seconds: float
    cast_bytes: float
    sig: str
    plans_tried: int = 1
    drifted: bool = False


class BigDAWG:
    def __init__(self, monitor: Optional[Monitor] = None,
                 train_plans: int = 8, train_repeats: int = 2):
        self.catalog: Dict[str, CatalogEntry] = {}
        self.monitor = monitor or Monitor()
        self.train_plans = train_plans
        # run each candidate plan this many times during training and record
        # only the last — first-run jit/compile cost would otherwise bias the
        # monitor toward never-compiled plans (cold-start bias)
        self.train_repeats = max(1, train_repeats)

    # -- catalog -----------------------------------------------------------
    def register(self, name: str, obj, engine: str):
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine}")
        if ENGINES[engine].kind != obj.kind:
            from repro.core import cast as castmod
            obj = castmod.cast(obj, ENGINES[engine].kind)
        self.catalog[name] = CatalogEntry(name, obj, engine)

    # -- phases --------------------------------------------------------------
    def _train(self, query: PolyOp, sig: str) -> Report:
        plans = enumerate_plans(query, self.catalog, max_plans=self.train_plans)
        best: Optional[ExecutionResult] = None
        usage = usage_snapshot()
        for plan in plans:
            for _ in range(self.train_repeats):
                res = execute_plan(query, plan, self.catalog)
            self.monitor.record(sig, plan.key, res.seconds,
                                cast_bytes=res.cast_bytes, usage=usage)
            if best is None or res.seconds < best.seconds:
                best = res
        return Report(best.value, best.plan.key, "training", best.seconds,
                      best.cast_bytes, sig, plans_tried=len(plans))

    def _production(self, query: PolyOp, sig: str) -> Report:
        usage = usage_snapshot()
        plan_key, stats, drifted = self.monitor.best(sig, usage)
        if plan_key is None:
            return self._train(query, sig)
        if drifted:
            # usage changed too much since training — re-train now, queue the
            # alternates for background exploration
            rep = self._train(query, sig)
            for pk in self.monitor.known_plans(sig):
                if pk != rep.plan_key:
                    self.monitor.queue_background(sig, pk)
            rep.drifted = True
            return rep
        plan = _plan_from_key(plan_key)
        res = execute_plan(query, plan, self.catalog)
        self.monitor.record(sig, plan_key, res.seconds,
                            cast_bytes=res.cast_bytes, usage=usage)
        return Report(res.value, plan_key, "production", res.seconds,
                      res.cast_bytes, sig)

    # -- public API ----------------------------------------------------------
    def execute(self, query: PolyOp, mode: str = "auto") -> Report:
        sig = signature(query, self.catalog)
        if mode == "training":
            return self._train(query, sig)
        if mode == "production":
            return self._production(query, sig)
        if mode == "auto":
            known, _, _ = self.monitor.best(sig)
            return self._production(query, sig) if known else \
                self._train(query, sig)
        raise ValueError(mode)

    def run_background_queue(self, query_by_sig: Dict[str, PolyOp]):
        """Re-explore queued alternate plans 'when the system is
        underutilized' (paper §III-C-3)."""
        done = 0
        while self.monitor.background_queue:
            sig, plan_key = self.monitor.background_queue.pop()
            if sig not in query_by_sig:
                continue
            res = execute_plan(query_by_sig[sig], _plan_from_key(plan_key),
                               self.catalog)
            self.monitor.record(sig, plan_key, res.seconds,
                                cast_bytes=res.cast_bytes)
            done += 1
        return done
