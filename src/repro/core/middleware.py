"""BigDAWG middleware facade (paper Fig. 3): planner + monitor + executor +
migrator behind one ``execute()`` entry point with the training/production
phase protocol of §III-C-3.

  training   — enumerate candidate plans via the cost-model DP, run (up to
               ``train_plans`` of) them sequentially (per-node timings feed
               the calibrated cost model), record stats, return the best
               run's result, and cache the winning Plan by signature.
  production — serve from the signature-keyed plan cache (no re-enumeration,
               no plan-key parsing), dispatching DAG levels concurrently; on
               signature miss fall back to training; on usage drift, re-train
               (paper: "rerun the query under the training phase under the
               current usage") and queue the losers for background
               exploration.
  auto       — production if the signature is known, else training.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

from repro.core.costmodel import CostModel, default_calibration_path
from repro.core.engines import ENGINES
from repro.core.executor import ExecutionResult, execute_plan
from repro.core.monitor import Monitor, usage_snapshot
from repro.core.ops import PolyOp
from repro.core.planner import Plan, enumerate_plans
from repro.core.signature import signature


def _plan_from_key(plan_key: str) -> Plan:
    return Plan(tuple((int(u), e) for u, e in
                      (p.split(":") for p in plan_key.split("|"))))


@dataclass
class CatalogEntry:
    name: str
    obj: Any                 # a tables.* container
    engine: str              # home engine


@dataclass
class Report:
    result: Any
    plan_key: str
    mode: str                # "training" | "production"
    seconds: float
    cast_bytes: float
    sig: str
    plans_tried: int = 1
    drifted: bool = False
    cache_hit: bool = False  # plan came from the signature-keyed plan cache


class BigDAWG:
    def __init__(self, monitor: Optional[Monitor] = None,
                 train_plans: int = 8, train_repeats: int = 2,
                 cost_model: Optional[CostModel] = None,
                 calibrate: bool = False):
        self.catalog: Dict[str, CatalogEntry] = {}
        self.monitor = monitor or Monitor()
        self.train_plans = train_plans
        # run each candidate plan this many times during training and record
        # only the last — first-run jit/compile cost would otherwise bias the
        # monitor toward never-compiled plans (cold-start bias)
        self.train_repeats = max(1, train_repeats)
        # cost model persists alongside the monitor DB when the latter has one
        self.cost_model = cost_model or CostModel(
            default_calibration_path(self.monitor.path))
        if calibrate and not self.cost_model.calibrated:
            self.cost_model.calibrate()
        # signature -> winning Plan: production requests skip re-enumeration
        # and plan-key parsing entirely
        self.plan_cache: Dict[str, Plan] = {}

    # -- catalog -----------------------------------------------------------
    def register(self, name: str, obj, engine: str):
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine}")
        if ENGINES[engine].kind != obj.kind:
            from repro.core import cast as castmod
            obj = castmod.cast(obj, ENGINES[engine].kind)
        self.catalog[name] = CatalogEntry(name, obj, engine)

    # -- phases --------------------------------------------------------------
    def _train(self, query: PolyOp, sig: str) -> Report:
        plans = enumerate_plans(query, self.catalog,
                                max_plans=self.train_plans,
                                cost_model=self.cost_model)
        best: Optional[ExecutionResult] = None
        usage = usage_snapshot()
        for plan in plans:
            # sequential warm-up runs: kill cold-start jit bias AND feed
            # honest per-node timings to the cost model (sequential only)
            for _ in range(self.train_repeats):
                res = execute_plan(query, plan, self.catalog)
            self.cost_model.observe_execution(res)
            # the RECORDED measurement uses concurrent dispatch — the same
            # mode production executes in, so every seconds value a
            # Monitor.best() comparison sees is from one dispatch mode
            res = execute_plan(query, plan, self.catalog, concurrent=True)
            self.monitor.record(sig, plan.key, res.seconds,
                                cast_bytes=res.cast_bytes, usage=usage)
            if best is None or res.seconds < best.seconds:
                best = res
        self.plan_cache[sig] = best.plan
        self.cost_model.save()
        return Report(best.value, best.plan.key, "training", best.seconds,
                      best.cast_bytes, sig, plans_tried=len(plans))

    def _production(self, query: PolyOp, sig: str) -> Report:
        usage = usage_snapshot()
        plan_key, stats, drifted = self.monitor.best(sig, usage)
        if plan_key is None:
            return self._train(query, sig)
        if drifted:
            # usage changed too much since training — re-train now, queue the
            # alternates for background exploration
            self.plan_cache.pop(sig, None)
            rep = self._train(query, sig)
            for pk in self.monitor.known_plans(sig):
                if pk != rep.plan_key:
                    self.monitor.queue_background(sig, pk)
            rep.drifted = True
            return rep
        cached = self.plan_cache.get(sig)
        hit = cached is not None and cached.key == plan_key
        plan = cached if hit else _plan_from_key(plan_key)
        self.plan_cache[sig] = plan
        res = execute_plan(query, plan, self.catalog, concurrent=True)
        self.monitor.record(sig, plan_key, res.seconds,
                            cast_bytes=res.cast_bytes, usage=usage)
        return Report(res.value, plan_key, "production", res.seconds,
                      res.cast_bytes, sig, cache_hit=hit)

    # -- public API ----------------------------------------------------------
    def execute(self, query: PolyOp, mode: str = "auto") -> Report:
        sig = signature(query, self.catalog)
        if mode == "training":
            return self._train(query, sig)
        if mode == "production":
            return self._production(query, sig)
        if mode == "auto":
            known, _, _ = self.monitor.best(sig)
            return self._production(query, sig) if known else \
                self._train(query, sig)
        raise ValueError(mode)

    def run_background_queue(self, query_by_sig: Dict[str, PolyOp]):
        """Re-explore queued alternate plans 'when the system is
        underutilized' (paper §III-C-3)."""
        done = 0
        while self.monitor.background_queue:
            sig, plan_key = self.monitor.background_queue.pop()
            if sig not in query_by_sig:
                continue
            # concurrent, like production: exploration exists to challenge the
            # incumbent's production-mode mean, so its seconds must be
            # measured under the same dispatch mode or the comparison is
            # structurally biased toward whichever plan won training
            res = execute_plan(query_by_sig[sig], _plan_from_key(plan_key),
                               self.catalog, concurrent=True)
            self.monitor.record(sig, plan_key, res.seconds,
                                cast_bytes=res.cast_bytes)
            done += 1
        return done
