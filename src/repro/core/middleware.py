"""BigDAWG middleware facade (paper Fig. 3): planner + monitor + executor +
migrator behind one ``execute()`` entry point with the training/production
phase protocol of §III-C-3, plus the adaptive feedback loop the paper's
monitor sketches ("collects performance data ... and uses it to improve
future plans"):

  training   — enumerate candidate plans via the cost-model DP (sized from
               measured intermediate sizes where history exists), run (up to
               ``train_plans`` of) them sequentially (per-node timings feed
               the calibrated cost model), record stats + actual sizes,
               return the best run's result, and cache the winning Plan with
               its predicted cost.
  production — serve from the signature-keyed plan cache (no re-enumeration,
               no plan-key parsing), dispatching DAG levels concurrently over
               the executor's host thread pool; on signature miss fall back
               to training; on usage drift, re-train (paper: "rerun the
               query under the training phase under the current usage") and
               queue the DP's true runner-up plans for background
               exploration.  After every run, the measured seconds are
               compared against the cached plan's predicted cost: divergence
               beyond ``replan_factor`` invalidates the entry and re-runs the
               cheap DP under the updated cost model + measured sizes and
               shapes (online re-planning, no training-phase trials needed).
  auto       — production if the signature is known, else training.

Each cache entry carries the k-best DP's runner-up plans
(``CachedPlan.alternates``).  With a non-zero ``explore_budget``, production
occasionally *explores*: after serving the winner, it schedules the next
alternate in rotation as a **background task on the executor's host pool**
— the request path never pays for it — and the task records its measured
seconds/sizes/shapes into the monitor (the paper's "the monitor must
continuously try alternate plans" loop), bounded so exploration time never
exceeds ``explore_budget`` x cumulative serve time.  An alternate that
proves faster becomes the monitor's best and is promoted on a later serve.
``drain_explorations()`` waits for in-flight trials (tests, shutdown).

**Concurrent admission.**  ``execute`` is safe to call from many request
threads at once: a per-signature lock serializes requests for the SAME
signature (two cold requests train once — the second waits, then serves the
fresh cache entry) while different signatures train and serve fully in
parallel.  The monitor and cost model take their own internal locks, the
plan cache and the stats counters are guarded here, and exploration runs
off-path, so the whole middleware admits multi-threaded traffic (see
``runtime.server.QueryServer.submit_many``).

**Resilient serving.**  Constructed with a ``core.health.EngineHealth``
registry, ``execute`` runs through a failover driver: every request plans
under the current circuit-breaker mask, an ``EngineDown`` mid-plan feeds the
engine's breaker and retries (first burning the breaker's failure threshold
on the incumbent path, then — breaker open, engine masked — re-running the
cheap k=1 DP around the dead engine), and masked plans are cached and
monitored under a mask-suffixed signature so the incumbent's history stays
pure and recovery (the breaker's half-open probe succeeding) restores it
verbatim.  Reports then carry ``status``/``degraded``/``failovers``.

The plan cache (winning plan + predicted cost + alternate keys) persists
beside the monitor DB (``<monitor>.plans.json``, atomic JSON via
``ioutil``), so a restarted production process serves previously-trained
signatures warm — zero plan enumerations — and keeps exploring the same
alternates.
"""
from __future__ import annotations

import os
import threading
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, FrozenSet, List, Optional, Tuple

from repro.core.costmodel import CostModel, default_calibration_path
from repro.core.engines import ENGINES
from repro.core.errors import EngineDown, PlanInfeasible
from repro.core.executor import ExecutionResult, execute_plan, host_pool
from repro.core.health import EngineHealth
from repro.core.ioutil import (atomic_json_dump, file_version, load_json,
                               load_json_versioned)
from repro.core.monitor import Monitor, usage_snapshot
from repro.core.ops import PolyOp
from repro.core.planner import (Plan, dp_plans, estimate_sizes_shapes,
                                plan_cost)
from repro.core.signature import signature

# separator between a signature and the engine mask it was served under:
# masked (failover/degraded) plans live in the plan cache and the monitor
# under "sig@!engine+engine", so the UNMASKED signature's history and cache
# entry stay pure — when the breaker closes again, monitor.best(sig) still
# names the incumbent and recovery restores it verbatim
MASK_SEP = "@!"


def masked_sig(sig: str, mask: FrozenSet[str]) -> str:
    return sig + MASK_SEP + "+".join(sorted(mask))


def _plan_from_key(plan_key: str) -> Plan:
    """Parse ``pos:engine|pos:engine|...``; raises ValueError on malformed or
    unknown-engine keys (callers decide whether to skip or retrain)."""
    try:
        pairs = tuple((int(u), e) for u, e in
                      (p.split(":") for p in plan_key.split("|")))
    except (ValueError, AttributeError) as exc:
        raise ValueError(f"malformed plan key {plan_key!r}") from exc
    for _, eng in pairs:
        if eng not in ENGINES:
            raise ValueError(f"plan key {plan_key!r} names unknown engine "
                             f"{eng!r}")
    if [u for u, _ in pairs] != list(range(len(pairs))):
        raise ValueError(f"plan key {plan_key!r} positions are not "
                         f"consecutive from 0")
    return Plan(pairs)


def default_plan_cache_path(monitor_path: Optional[str]) -> Optional[str]:
    """Plan-cache file that rides alongside a monitor DB path."""
    if not monitor_path:
        return None
    root, _ = os.path.splitext(monitor_path)
    return root + ".plans.json"


@dataclass
class CatalogEntry:
    name: str
    obj: Any                 # a tables.* container
    engine: str              # home engine


@dataclass
class CachedPlan:
    """A plan-cache entry: the winning Plan plus the predicted cost it was
    cached under (the baseline the online re-planner diverges against), and
    the k-best DP's runner-up plans for budgeted exploration."""
    plan: Plan
    predicted_s: float = 0.0
    # a freshly re-planned entry is served once ahead of monitor history so
    # its measured seconds enter the history and the comparison is live
    pinned: bool = False
    # loaded from a persisted cache: the first serve re-syncs the prediction
    # to this process's runtime instead of re-planning (a cold jit cache can
    # legitimately be >2x slower than the recording process was)
    restored: bool = False
    # the DP's true runner-up plans (training order, best first) — what the
    # budgeted exploration path executes in rotation
    alternates: Tuple[Plan, ...] = ()
    next_alt: int = 0        # rotation cursor (not persisted)
    # the fusion pass's output for this entry's plan (fuseplan.FusedPlan),
    # built lazily on the first fused serve and invalidated when the plan or
    # the query's exact structure changes.  Runtime-only, like next_alt: the
    # compiled callables live in fuseplan's process-wide cache, and a
    # restarted process re-runs the (cheap) segmentation pass
    fused: Any = None


@dataclass
class Report:
    result: Any
    plan_key: str
    mode: str                # "training" | "production"
    seconds: float
    cast_bytes: float
    sig: str
    plans_tried: int = 1
    drifted: bool = False
    cache_hit: bool = False  # plan came from the signature-keyed plan cache
    replanned: bool = False  # predicted/measured divergence re-ran the DP
    predicted_s: float = 0.0  # cached prediction for the executed plan
    # this serve scheduled a background alternate trial (it runs off-path on
    # the host pool; drain_explorations() waits for its measurement)
    explored: bool = False
    explored_key: str = ""   # which alternate (empty when explored is False)
    # post-order position -> measured seconds of that node in the served run
    # (position-keyed like plan keys and size feedback, so it survives query
    # rebuilds; the Session API surfaces it as Result.per_node_seconds)
    per_node_seconds: Dict[int, float] = field(default_factory=dict)
    # -- resilience surface (populated when the middleware has a health
    #    registry; defaults describe the non-resilient path) ---------------
    status: str = "ok"       # "ok" | "degraded" ("shed" is stamped by the
    #                          server on Overloaded results, never here)
    degraded: bool = False   # served under an engine mask (failover/degrade)
    failovers: int = 0       # EngineDown retries this request survived
    # scatter–gather: number of shard fragments this result was merged from
    # (0 = ordinary unsharded execution; plan_key then describes one
    # fragment's plan — fragments share a node structure with the query)
    shards: int = 0
    # position groups that executed as single compiled segments this serve
    # (empty on training serves — calibration stays unfused — and when
    # fusion is off, nothing was fusable, or every segment fell back)
    fused_segments: Tuple[Tuple[int, ...], ...] = ()
    # fused segments that failed to trace/compile/run this serve and were
    # re-executed node-by-node (sticky: later serves skip the fused attempt)
    fusion_fallbacks: int = 0


def _pos_seconds(query: PolyOp, res: ExecutionResult) -> Dict[int, float]:
    """Re-key an ExecutionResult's uid-keyed per-node timings by post-order
    position (shared subtrees collapse to their one executed timing)."""
    return {pos: res.per_node_seconds.get(n.uid, 0.0)
            for pos, n in enumerate(query.nodes())}


class BigDAWG:
    # measured/predicted divergence factor that triggers online re-planning
    REPLAN_FACTOR = 2.0
    # max fraction of cumulative production serve seconds spendable on
    # executing alternate plans (0.0 disables exploration)
    EXPLORE_BUDGET = 0.0
    # how many DP runner-ups each cache entry keeps for exploration
    MAX_ALTERNATES = 3

    def __init__(self, monitor: Optional[Monitor] = None,
                 train_plans: int = 8, train_repeats: int = 2,
                 cost_model: Optional[CostModel] = None,
                 calibrate: bool = False,
                 plan_cache_path: Optional[str] = None,
                 replan_factor: float = REPLAN_FACTOR,
                 explore_budget: float = EXPLORE_BUDGET,
                 health: Optional[EngineHealth] = None,
                 fuse: bool = True, fusion_injector: Any = None):
        self.catalog: Dict[str, CatalogEntry] = {}
        # name -> shardplan.ShardInfo for tables registered with shards=N
        # (the shard parts live in the catalog as "name#i")
        self.sharded: Dict[str, "shardplan.ShardInfo"] = {}
        self.monitor = monitor or Monitor()
        # optional per-engine circuit-breaker registry: when present, every
        # execute() runs through the failover driver (_execute_resilient) —
        # tripped engines are masked out of planning, EngineDown retries
        # re-plan, successes/stragglers feed the breakers
        self.health = health
        self.failovers = 0
        self.train_plans = train_plans
        # run each candidate plan this many times during training and record
        # only the last — first-run jit/compile cost would otherwise bias the
        # monitor toward never-compiled plans (cold-start bias)
        self.train_repeats = max(1, train_repeats)
        # cost model persists alongside the monitor DB when the latter has one
        self.cost_model = cost_model or CostModel(
            default_calibration_path(self.monitor.path))
        if calibrate and not self.cost_model.calibrated:
            self.cost_model.calibrate()
        self.replan_factor = replan_factor
        self.replans = 0
        # budgeted alternate exploration (see module docstring): exploration
        # seconds may never exceed explore_budget x cumulative serve seconds
        self.explore_budget = explore_budget
        self.explorations = 0
        self.explore_seconds = 0.0
        self.serve_seconds = 0.0
        # plan-level kernel fusion (core.fuseplan): production serves execute
        # each cached plan's same-engine fusable chains as single jitted
        # callables.  Safe to flip at runtime (the FusedPlan rides the cache
        # entry; fuse=False simply stops passing it to the executor).
        # fusion_injector (runtime.fault.FusionFaultInjector) is the
        # compile-failure seam for the fallback fault tests
        self.fuse = fuse
        self.fusion_injector = fusion_injector
        self.fused_serves = 0        # production serves with >=1 fused segment
        self.fusion_segments = 0     # fused segments executed, lifetime
        self.fusion_fallbacks = 0    # sticky fused->unfused fallbacks, lifetime
        # signature -> CachedPlan: production requests skip re-enumeration
        # and plan-key parsing entirely; persisted beside the monitor DB so
        # restarted processes serve warm
        self.plan_cache: Dict[str, CachedPlan] = {}
        self.plan_cache_path = plan_cache_path or default_plan_cache_path(
            self.monitor.path)
        # -- concurrency state (see module docstring) -----------------------
        # per-signature serialization: same-signature requests queue (one
        # training per signature), different signatures run in parallel
        self._sig_locks: Dict[str, threading.RLock] = {}
        self._sig_locks_guard = threading.Lock()
        # guards the counters above (replans/explorations/*_seconds)
        self._stats_lock = threading.Lock()
        # guards plan_cache dict mutation + CachedPlan alternate rotation
        self._cache_lock = threading.RLock()
        # background exploration bookkeeping: at most one in-flight trial per
        # signature, futures kept so drain_explorations() can wait
        self._explore_guard = threading.Lock()
        self._explore_inflight: set = set()
        self._explore_futures: List = []
        # cross-process plan-cache sharing: stamp of the file we last
        # read/wrote (reload_plan_cache_if_changed polls it)
        self._plan_cache_version = None
        if self.plan_cache_path and os.path.exists(self.plan_cache_path):
            self.load_plan_cache(self.plan_cache_path)

    def _sig_lock(self, sig: str) -> threading.RLock:
        with self._sig_locks_guard:
            return self._sig_locks.setdefault(sig, threading.RLock())

    # -- catalog -----------------------------------------------------------
    def register(self, name: str, obj, engine: str,
                 shards: Optional[int] = None):
        """Home ``obj`` on ``engine`` under ``name``.  With ``shards=N`` the
        object is ALSO split into N contiguous row-range parts registered as
        ``name#0 .. name#N-1`` (each homed/cast like any registration), and
        the shard registry records the decomposition — what
        ``shardplan.analyze`` consults to offer scatter–gather execution."""
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine}")
        if shards is not None:
            from repro.core import shardplan, tables
            if shards < 1:
                raise ValueError(f"shards must be >= 1, got {shards}")
            parts = tables.shard_rows(obj, shards)   # split BEFORE the home
            info = shardplan.ShardInfo(              # cast: row semantics
                shards, obj.kind, shardplan.nrows_of(obj))   # follow the src
            for i, part in enumerate(parts):
                self.register(shardplan.shard_name(name, i), part, engine)
            self.sharded[name] = info
        if ENGINES[engine].kind != obj.kind:
            from repro.core import cast as castmod
            from repro.core.tables import device_ready
            # casts leave triple formats numpy-eager (right for short-lived
            # intermediates); a catalog object is long-lived and re-consumed
            # by device ops every query, so home it on the device once here
            obj = device_ready(
                castmod.cast(obj, ENGINES[engine].kind, self.cost_model))
        self.catalog[name] = CatalogEntry(name, obj, engine)

    # -- plan-cache persistence ---------------------------------------------
    def save_plan_cache(self, path: Optional[str] = None,
                        merge: Optional[bool] = None):
        """Persist the plan cache atomically.  With ``merge`` (default: the
        monitor's ``shared`` flag, so procpool workers merge automatically)
        the current file is read first and signatures this process has no
        local entry for are carried through — concurrent workers training
        DIFFERENT signatures never drop each other's entries; the same
        signature resolves last-writer-wins."""
        path = path or self.plan_cache_path
        if not path:
            return
        if merge is None:
            merge = self.monitor.shared
        with self._cache_lock:     # snapshot: concurrent trainings of other
            blob = {"format": 2,   # signatures keep mutating the dict
                    "entries": {sig: {"plan": e.plan.key,
                                      "predicted_s": e.predicted_s,
                                      "alternates": [p.key
                                                     for p in e.alternates]}
                                for sig, e in self.plan_cache.items()
                                # masked (degraded) entries are transient —
                                # tied to this process's breaker state, they
                                # must not warm-start a healthy restart
                                if MASK_SEP not in sig}}
            if merge:
                try:
                    cur = load_json(path)
                except (OSError, ValueError):
                    cur = None
                if isinstance(cur, dict):
                    for sig, ent in cur.get("entries", {}).items():
                        if sig not in self.plan_cache:
                            blob["entries"][sig] = ent
            atomic_json_dump(path, blob)
            self._plan_cache_version = file_version(path)

    def reload_plan_cache_if_changed(self) -> bool:
        """Cross-process read path: adopt plan-cache entries other workers
        have persisted since we last read/wrote the file.  Local entries are
        never clobbered (this process's live pin/alternate state wins);
        adopted entries arrive ``restored=True`` so their first serve
        re-syncs the prediction to this process's runtime.  One ``stat``
        when nothing changed."""
        path = self.plan_cache_path
        if not path:
            return False
        with self._cache_lock:
            blob, ver = load_json_versioned(path, self._plan_cache_version)
            if blob is None:
                return False
            self._plan_cache_version = ver
            adopted = False
            for sig, ent in (blob.get("entries", {})
                             if isinstance(blob, dict) else {}).items():
                if sig in self.plan_cache:
                    continue
                try:
                    alts = tuple(_plan_from_key(k)
                                 for k in ent.get("alternates", []) or [])
                    self.plan_cache[sig] = CachedPlan(
                        _plan_from_key(ent["plan"]),
                        float(ent.get("predicted_s", 0.0)),
                        restored=True, alternates=alts)
                    adopted = True
                except (ValueError, KeyError, TypeError) as exc:
                    warnings.warn(f"plan cache {path}: skipping bad shared "
                                  f"entry {sig!r}: {exc}")
            return adopted

    def reload_shared(self) -> bool:
        """Poll both shared-state files (monitor DB + plan cache) for changes
        by other processes — the procpool worker calls this before serving
        each request (two ``stat`` calls on the idle path)."""
        m = self.monitor.reload_if_changed()
        p = self.reload_plan_cache_if_changed()
        return m or p

    def load_plan_cache(self, path: str):
        """Load a persisted plan cache, skipping (with a warning) any entry a
        hand edit or corruption has mangled — bad entries, or a whole file
        that no longer parses, must not take down the warm-start path."""
        try:
            blob = load_json(path)
        except (OSError, ValueError) as exc:   # JSONDecodeError is a ValueError
            warnings.warn(f"plan cache {path}: unreadable ({exc}); "
                          f"starting cold")
            return
        self._plan_cache_version = file_version(path)
        entries = blob.get("entries", {}) if isinstance(blob, dict) else {}
        for sig, ent in entries.items():
            try:
                if not isinstance(ent, dict):
                    raise ValueError(f"entry for {sig!r} is not an object")
                plan = _plan_from_key(ent["plan"])
                alts = []
                for ak in ent.get("alternates", []) or []:
                    try:
                        alts.append(_plan_from_key(ak))
                    except ValueError as exc:   # one bad alternate must not
                        warnings.warn(           # sink the whole entry
                            f"plan cache {path}: dropping bad alternate "
                            f"for {sig!r}: {exc}")
                with self._cache_lock:
                    self.plan_cache[sig] = CachedPlan(
                        plan, float(ent.get("predicted_s", 0.0)),
                        restored=True, alternates=tuple(alts))
            except (ValueError, KeyError, TypeError) as exc:
                warnings.warn(f"plan cache {path}: skipping bad entry "
                              f"{sig!r}: {exc}")

    # -- phases --------------------------------------------------------------
    def _predict(self, query: PolyOp, plan: Plan, sig: str) -> float:
        """Current predicted seconds for a plan, under measured sizes and
        shapes."""
        sizes, shapes = estimate_sizes_shapes(
            query, self.catalog, measured=self.monitor.measured_sizes(sig),
            measured_shapes=self.monitor.measured_shapes(sig))
        return plan_cost(query, plan, self.catalog, self.cost_model,
                         sizes=sizes, shapes=shapes)

    def _train(self, query: PolyOp, sig: str) -> Report:
        ranked = dp_plans(query, self.catalog, max_plans=self.train_plans,
                          cost_model=self.cost_model,
                          measured_sizes=self.monitor.measured_sizes(sig),
                          measured_shapes=self.monitor.measured_shapes(sig))
        best: Optional[ExecutionResult] = None
        usage = usage_snapshot()
        for _, plan in ranked:
            # sequential warm-up runs: kill cold-start jit bias AND feed
            # honest per-node timings to the cost model (sequential only)
            for _ in range(self.train_repeats):
                res = execute_plan(query, plan, self.catalog,
                                   cost_model=self.cost_model,
                                   health=self.health)
            self.cost_model.observe_execution(res)
            # the RECORDED measurement uses concurrent dispatch — the same
            # mode production executes in, so every seconds value a
            # Monitor.best() comparison sees is from one dispatch mode
            res = execute_plan(query, plan, self.catalog, concurrent=True,
                               cost_model=self.cost_model,
                               health=self.health)
            self.monitor.record(sig, plan.key, res.seconds,
                                cast_bytes=res.cast_bytes, usage=usage,
                                sizes=res.size_obs, shapes=res.shape_obs)
            if best is None or res.seconds < best.seconds:
                best = res
        # the cached prediction is recomputed AFTER the training observations
        # and size measurements landed — the freshest model state, the
        # baseline online re-planning diverges against.  If the model is
        # still off by more than the replan factor from the measurement we
        # JUST took, the measurement is the better baseline (caching a known-
        # bad prediction would trigger a pointless re-plan on the very next
        # production run)
        predicted = self._predict(query, best.plan, sig)
        if self._diverged(predicted, best.seconds):
            predicted = best.seconds
        # the DP's runner-ups are the TRUE alternates (ROADMAP: background
        # exploration must try these, not whatever the monitor happens to
        # have recorded) — kept with the entry for budgeted exploration
        alternates = tuple(p for _, p in ranked
                           if p.key != best.plan.key)[:self.MAX_ALTERNATES]
        with self._cache_lock:
            self.plan_cache[sig] = CachedPlan(best.plan, predicted,
                                              alternates=alternates)
        self.cost_model.save()
        self.monitor.save()
        self.save_plan_cache()
        return Report(best.value, best.plan.key, "training", best.seconds,
                      best.cast_bytes, sig, plans_tried=len(ranked),
                      predicted_s=predicted,
                      per_node_seconds=_pos_seconds(query, best))

    def _diverged(self, predicted: float, measured: float) -> bool:
        """The online re-planner's divergence policy: prediction and
        measurement disagree by more than ``replan_factor`` in either
        direction (non-positive values never diverge)."""
        if predicted <= 0.0 or measured <= 0.0:
            return False
        return max(measured / predicted,
                   predicted / measured) > self.replan_factor

    def _maybe_replan(self, query: PolyOp, sig: str, measured: float,
                      entry: CachedPlan) -> bool:
        """Online re-planning: >replan_factor divergence between the measured
        cost (the monitor's history-damped mean for the served plan — a
        single run's timing noise on short queries can exceed the factor by
        itself) and the cached prediction invalidates the entry and re-runs
        the cheap DP under the updated cost model + measured sizes."""
        pred = entry.predicted_s
        if measured <= 0.0:
            return False
        if entry.restored:
            # first serve after a warm restart: a cold jit cache makes this
            # run incomparable to the recording process's baseline — re-sync
            # the prediction instead of re-planning.  A restored entry with
            # no usable baseline (predicted_s missing from the file -> 0.0)
            # must also adopt the measurement, or the loop stays dead
            entry.restored = False
            if pred <= 0.0 or self._diverged(pred, measured):
                entry.predicted_s = measured
            return False
        if pred <= 0.0 or not self._diverged(pred, measured):
            return False
        # the "cheap DP": only the new optimum is consumed, so k=1 (per-engine
        # fronts keep the top-1 exact — see dp_plans)
        ranked = dp_plans(query, self.catalog, max_plans=1,
                          cost_model=self.cost_model,
                          measured_sizes=self.monitor.measured_sizes(sig),
                          measured_shapes=self.monitor.measured_shapes(sig))
        cost, plan = ranked[0]
        if plan.key == entry.plan.key:
            # same plan still wins — the divergence is model form error, not
            # a placement mistake; adopt the measured cost as the entry's
            # prediction so a stable runtime stops re-triggering
            with self._cache_lock:
                self.plan_cache[sig] = CachedPlan(plan, measured,
                                                  alternates=entry.alternates)
        else:
            # prefer the plan's measured history (training trials measured
            # every candidate) over the raw model cost as the new baseline —
            # a model-based baseline could itself diverge and cascade
            stats = self.monitor.known_plans(sig).get(plan.key)
            pred_new = stats.mean_seconds if stats is not None and stats.n \
                else cost
            with self._cache_lock:
                self.plan_cache[sig] = CachedPlan(
                    plan, pred_new, pinned=True,
                    # the dethroned incumbent joins the alternates —
                    # exploration keeps measuring it so a wrong re-plan can
                    # be reversed
                    alternates=tuple(
                        p for p in (entry.plan,) + entry.alternates
                        if p.key != plan.key)[:self.MAX_ALTERNATES])
        with self._stats_lock:
            self.replans += 1
        self.save_plan_cache()
        return True

    def _fused_for(self, query: PolyOp, plan: Plan,
                   entry: Optional[CachedPlan]):
        """The FusedPlan to serve ``plan`` with (None when fusion is off).
        Cached on the plan-cache entry and reused only when both the plan
        key and the query's EXACT structural fingerprint still match —
        signatures bin constant attrs, so two queries can share a signature
        (and this entry) yet need differently-closed-over callables."""
        if not self.fuse:
            return None
        from repro.core import fuseplan
        fp = fuseplan.query_fingerprint(query)
        with self._cache_lock:
            f = entry.fused if entry is not None else None
            if f is not None and f.plan_key == plan.key \
                    and f.fingerprint == fp:
                return f
        f = fuseplan.fuse_plan(query, plan, self.catalog,
                               cost_model=self.cost_model,
                               injector=self.fusion_injector)
        with self._cache_lock:
            if entry is not None:
                entry.fused = f
        return f

    def _note_fusion(self, res: ExecutionResult) -> None:
        """Roll one serve's fusion outcome into the lifetime counters
        (caller does NOT hold the stats lock)."""
        if not res.fused_segments and not res.fusion_fallbacks:
            return
        with self._stats_lock:
            if res.fused_segments:
                self.fused_serves += 1
                self.fusion_segments += len(res.fused_segments)
            self.fusion_fallbacks += res.fusion_fallbacks

    def _production(self, query: PolyOp, sig: str) -> Report:
        usage = usage_snapshot()
        plan_key, stats, drifted = self.monitor.best(sig, usage)
        if plan_key is None:
            return self._train(query, sig)
        if drifted:
            # usage changed too much since training — re-train now, queue the
            # DP's true runner-up plans for background exploration (not the
            # monitor's historical leftovers, which may never have been
            # planner candidates under the current sizes)
            with self._cache_lock:
                self.plan_cache.pop(sig, None)
            rep = self._train(query, sig)
            for alt in self.plan_cache[sig].alternates:
                self.monitor.queue_background(sig, alt.key)
            rep.drifted = True
            return rep
        with self._cache_lock:
            entry = self.plan_cache.get(sig)
            if entry is not None and entry.pinned:
                # freshly re-planned entry: serve the DP's new choice once
                # ahead of monitor history so its measured seconds enter the
                # comparison
                plan, plan_key, hit = entry.plan, entry.plan.key, True
                entry.pinned = False
            else:
                hit = entry is not None and entry.plan.key == plan_key
                if hit:
                    plan = entry.plan
                else:
                    try:
                        plan = _plan_from_key(plan_key)
                    except ValueError as exc:    # corrupted monitor history
                        warnings.warn(f"monitor best for {sig!r} unusable "
                                      f"({exc}); retraining")
                        # retrain OUTSIDE the cache lock: training runs every
                        # candidate plan — holding the global lock that long
                        # would stall every other signature's serve
                        plan = None
                    if plan is not None:
                        # measured history as the baseline (stats exist:
                        # best() just picked this plan by mean seconds) —
                        # model predictions are only baselines when no
                        # measurement is available.  An exploration win lands
                        # here: the promoted alternate keeps the old entry's
                        # alternate pool (incumbent included) so exploration
                        # continues to challenge it
                        alts = ()
                        if entry is not None:
                            alts = tuple(
                                p for p in (entry.plan,) + entry.alternates
                                if p.key != plan_key)[:self.MAX_ALTERNATES]
                        entry = CachedPlan(plan,
                                           stats.mean_seconds if stats.n
                                           else self._predict(query, plan,
                                                              sig),
                                           alternates=alts)
                        self.plan_cache[sig] = entry
        if plan is None:
            return self._train(query, sig)
        if len(plan.assignment) != len(query.nodes()):
            # a persisted entry (or hand-edited history) for a different
            # query shape under this signature: unusable, retrain
            warnings.warn(f"plan for {sig!r} covers {len(plan.assignment)} "
                          f"positions, query has {len(query.nodes())}; "
                          f"retraining")
            with self._cache_lock:
                self.plan_cache.pop(sig, None)
            return self._train(query, sig)
        res = execute_plan(query, plan, self.catalog, concurrent=True,
                           cost_model=self.cost_model, health=self.health,
                           fused=self._fused_for(query, plan, entry))
        self._note_fusion(res)
        if res.fusion_cold_compiles:
            # first serve of a fused segment signature at these shapes: the
            # wall time includes trace+compile, a one-off.  Treat the serve
            # as a warm-up — neither the plan's measured mean nor the
            # divergence re-plan trigger may see the compile spike (sizes/
            # shapes were already learned from the unfused training serves)
            replanned = False
        else:
            self.monitor.record(sig, plan_key, res.seconds,
                                cast_bytes=res.cast_bytes, usage=usage,
                                sizes=res.size_obs, shapes=res.shape_obs)
            after = self.monitor.known_plans(sig).get(plan_key)
            measured = after.mean_seconds if after is not None and after.n \
                else res.seconds
            replanned = self._maybe_replan(query, sig, measured, entry)
        with self._stats_lock:
            self.serve_seconds += res.seconds
        explored_key = self._maybe_explore(query, sig, usage)
        return Report(res.value, plan_key, "production", res.seconds,
                      res.cast_bytes, sig, cache_hit=hit, replanned=replanned,
                      predicted_s=entry.predicted_s,
                      explored=bool(explored_key), explored_key=explored_key,
                      per_node_seconds=_pos_seconds(query, res),
                      fused_segments=res.fused_segments,
                      fusion_fallbacks=res.fusion_fallbacks)

    def _maybe_explore(self, query: PolyOp, sig: str,
                       usage: Dict[str, float]) -> str:
        """Budgeted alternate exploration (paper: the monitor "continuously"
        tries alternate plans), OFF the request path: pick the next DP
        runner-up in rotation and schedule it as a background task on the
        executor's host pool.  The serve returns immediately; the task feeds
        its measured seconds/sizes/shapes to the monitor's batched record
        queue (which the planner and cost model consume on every later
        planning pass).  Scheduling happens only while cumulative
        exploration time stays within ``explore_budget`` x cumulative serve
        time (at most one in-flight trial per signature, so the overshoot is
        bounded by one trial).  Returns the scheduled plan key, or '' when
        nothing was scheduled."""
        if self.explore_budget <= 0.0:
            return ""
        with self._stats_lock:
            over = self.explore_seconds > \
                self.explore_budget * self.serve_seconds
        if over:
            return ""
        with self._explore_guard:
            if sig in self._explore_inflight:    # one trial per sig at a time
                return ""                        # (before burning a rotation
        n_pos = len(query.nodes())               # slot on a skipped serve)
        with self._cache_lock:               # alternate rotation is shared
            entry = self.plan_cache.get(sig)
            if entry is None or not entry.alternates:
                return ""
            for _ in range(len(entry.alternates)):
                alt = entry.alternates[entry.next_alt % len(entry.alternates)]
                entry.next_alt += 1
                if len(alt.assignment) == n_pos and alt.key != entry.plan.key:
                    break
            else:
                return ""
        with self._explore_guard:
            # same-signature callers hold the signature lock, so the
            # inflight check above cannot race another scheduler for sig
            self._explore_inflight.add(sig)
            self._explore_futures = [f for f in self._explore_futures
                                     if not f.done()]
            self._explore_futures.append(host_pool().submit(
                self._explore_task, query, sig, alt, dict(usage)))
        return alt.key

    def _explore_task(self, query: PolyOp, sig: str, alt: Plan,
                      usage: Dict[str, float]) -> None:
        """One background alternate trial (runs on a host-pool worker).

        Level dispatch is concurrent-but-inline (``host_workers=1``): a pool
        worker must never submit to its own pool (a saturated pool would
        deadlock on the level barrier).  The auto gate keeps serve-path
        levels inline for sub-threshold tasks anyway, so the alternate's
        measured mean stays comparable to the incumbent's for exactly the
        levels where threading could have diverged them.  The COST MODEL is
        deliberately NOT fed here: background-mode cast hops time worker
        contention, and folding them into cast_rate would corrupt the
        calibration that training keeps sequential-only.  The model still
        benefits through the monitor channel (sizes/shapes sharpen its size
        inputs)."""
        try:
            res = execute_plan(query, alt, self.catalog, concurrent=True,
                               host_workers=1, cost_model=self.cost_model)
            with self._stats_lock:
                self.explore_seconds += res.seconds
                self.explorations += 1
            self.monitor.record(sig, alt.key, res.seconds,
                                cast_bytes=res.cast_bytes, usage=usage,
                                sizes=res.size_obs, shapes=res.shape_obs)
        except Exception as exc:     # an alternate that fails must not take
            warnings.warn(           # down the worker or block the drain
                f"background exploration of {alt.key!r} for {sig!r} "
                f"failed: {exc}")
            # evict it from the rotation: a doomed alternate charges no
            # explore_seconds, so the budget would never stop the serve path
            # from rescheduling it on every request
            with self._cache_lock:
                entry = self.plan_cache.get(sig)
                if entry is not None:
                    entry.alternates = tuple(p for p in entry.alternates
                                             if p.key != alt.key)
        finally:
            with self._explore_guard:
                self._explore_inflight.discard(sig)

    def reset_exploration_budget(self) -> None:
        """Zero the exploration-budget accounting (``explore_seconds`` and
        ``serve_seconds``).  The budget check compares *cumulative* totals,
        so a long stretch of cheap trials banks credit that a later busy
        phase can burn in a burst; epoch-style callers (benchmarks, load
        phases) re-anchor here so every phase sees the same steady-state
        ``explore_budget`` fraction."""
        with self._stats_lock:
            self.explore_seconds = 0.0
            self.serve_seconds = 0.0

    def persist(self) -> None:
        """Flush all persistent state — monitor DB, cost-model calibration
        and plan cache — to their side-by-side files, waiting for in-flight
        background explorations first so their measurements are included
        (no-ops for components constructed without a path).  The one flush
        sequence `Session.persist` and `QueryServer.persist` both call."""
        self.drain_explorations()
        self.monitor.save()
        self.cost_model.save()
        self.save_plan_cache()

    def drain_explorations(self, timeout: Optional[float] = None) -> int:
        """Block until all in-flight background exploration trials finish
        (their measurements are then in the monitor's pending queue).
        Returns how many finished futures were retired.  With a ``timeout``
        (per future, seconds), ``concurrent.futures.TimeoutError``
        propagates and the unfinished trials STAY tracked — a later drain
        (or ``QueryServer.persist()``) still waits for them."""
        with self._explore_guard:
            futures = list(self._explore_futures)
        try:
            for f in futures:
                f.exception(timeout=timeout)   # surface nothing, just wait
        finally:
            with self._explore_guard:          # retire only what finished;
                done = sum(1 for f in futures if f.done())
                self._explore_futures = [f for f in self._explore_futures
                                         if not f.done()]
        return done

    # -- resilient serving ---------------------------------------------------
    def _serve_masked(self, query: PolyOp, sig: str,
                      mask: FrozenSet[str]) -> Report:
        """Failover/degraded serve: plan and execute with ``mask`` engines
        excluded.  The plan comes from a mask-keyed cache entry (first
        request under a given mask pays one cheap k=1 DP; the rest of the
        outage serves cached) and the measurement is recorded under the
        mask-keyed monitor signature — the unmasked signature's history
        never sees degraded runs, so when the breaker closes,
        ``monitor.best(sig)`` still names the pre-failure incumbent and the
        half-open probe restores it verbatim.  Raises ``PlanInfeasible``
        when the mask leaves some op with no engine."""
        mkey = masked_sig(sig, mask)
        with self._cache_lock:
            entry = self.plan_cache.get(mkey)
            hit = entry is not None
        if entry is None:
            ranked = dp_plans(query, self.catalog, max_plans=1,
                              cost_model=self.cost_model,
                              measured_sizes=self.monitor.measured_sizes(sig),
                              measured_shapes=self.monitor.measured_shapes(
                                  sig),
                              mask=mask)
            cost, plan = ranked[0]
            entry = CachedPlan(plan, cost)
            with self._cache_lock:
                entry = self.plan_cache.setdefault(mkey, entry)
        res = execute_plan(query, entry.plan, self.catalog, concurrent=True,
                           cost_model=self.cost_model, health=self.health,
                           fused=self._fused_for(query, entry.plan, entry))
        self._note_fusion(res)
        if not res.fusion_cold_compiles:   # compile spikes stay out of the
            self.monitor.record(mkey, entry.plan.key, res.seconds,
                                cast_bytes=res.cast_bytes,
                                usage=usage_snapshot(),   # masked mean too
                                sizes=res.size_obs, shapes=res.shape_obs)
        with self._stats_lock:
            self.serve_seconds += res.seconds
        return Report(res.value, entry.plan.key, "production", res.seconds,
                      res.cast_bytes, sig, cache_hit=hit,
                      predicted_s=entry.predicted_s,
                      per_node_seconds=_pos_seconds(query, res),
                      fused_segments=res.fused_segments,
                      fusion_fallbacks=res.fusion_fallbacks)

    def _feed_health(self, rep: Report) -> None:
        """Feed one successful serve to the health registry: the executed
        plan's per-node (engine, seconds) pairs drive the per-engine
        straggler detectors and reset/close the breakers."""
        try:
            pairs = _plan_from_key(rep.plan_key).assignment
        except ValueError:
            return
        self.health.after_plan(
            (eng, rep.per_node_seconds.get(pos, 0.0)) for pos, eng in pairs)

    def _execute_resilient(self, query: PolyOp, sig: str, mode: str,
                           degrade: bool) -> Report:
        """The failover driver (requires ``self.health``): plan under the
        current breaker mask, execute, and on ``EngineDown`` retry — the
        failed attempt fed the engine's breaker, so retries first burn the
        breaker's failure threshold on the incumbent path and then (breaker
        open, engine masked) re-plan around the dead engine.  Bounded: once
        every breaker could have tripped, the last ``EngineDown`` is
        surfaced (everything is down).  ``degrade`` additionally masks every
        non-always-up engine — the server's graceful-degradation path under
        overload."""
        health = self.health
        limit = 1 + sum(br.failure_threshold
                        for br in health.breakers.values())
        failovers = 0
        while True:
            mask, probes = health.mask_for_request()
            if degrade:
                mask = frozenset(mask | health.degrade_mask())
            try:
                rep = self._serve_masked(query, sig, mask) if mask \
                    else self._dispatch(query, sig, mode)
            except EngineDown:
                failovers += 1
                with self._stats_lock:
                    self.failovers += 1
                if failovers >= limit:
                    raise
                continue
            except PlanInfeasible:
                if degrade:
                    # the degrade mask (on top of tripped breakers) left
                    # some op with no engine — degrading was too aggressive
                    # for this query; retry with the breaker mask alone
                    degrade = False
                    continue
                raise
            finally:
                health.release_probes(probes)
            self._feed_health(rep)
            rep.failovers = failovers
            rep.degraded = bool(mask)
            rep.status = "degraded" if mask else "ok"
            return rep

    @property
    def breaker_trips(self) -> int:
        """Lifetime circuit-breaker trips across engines (0 without a
        health registry) — surfaced as ``QueryServer.stats["breaker_trips"]``."""
        return self.health.trips() if self.health is not None else 0

    # -- public API ----------------------------------------------------------
    def _dispatch(self, query: PolyOp, sig: str, mode: str) -> Report:
        """The paper's phase protocol (caller holds the signature lock)."""
        if mode == "training":
            return self._train(query, sig)
        if mode == "production":
            return self._production(query, sig)
        if mode == "auto":
            known, _, _ = self.monitor.best(sig)
            return self._production(query, sig) if known else \
                self._train(query, sig)
        raise ValueError(mode)

    def execute(self, query: PolyOp, mode: str = "auto", *,
                degrade: bool = False) -> Report:
        """Thread-safe entry point.  Requests for the SAME signature are
        serialized on a per-signature lock — two cold requests racing in
        ``auto`` mode train exactly once: the loser blocks, then re-checks
        the monitor inside the lock and serves the winner's fresh plan.
        Requests for different signatures hold different locks and
        train/serve fully in parallel.

        With a health registry (``BigDAWG(health=...)``) the request runs
        through the failover driver: tripped engines are masked out of
        planning, ``EngineDown`` mid-plan retries (re-planning around the
        dead engine once its breaker opens), and the Report carries
        ``status``/``degraded``/``failovers``.  ``degrade=True`` (the
        server's overload path) plans on the always-up engine set only."""
        sig = signature(query, self.catalog)
        with self._sig_lock(sig):
            if self.health is not None:
                return self._execute_resilient(query, sig, mode, degrade)
            return self._dispatch(query, sig, mode)

    def run_background_queue(self, query_by_sig: Dict[str, PolyOp]):
        """Re-explore queued alternate plans 'when the system is
        underutilized' (paper §III-C-3)."""
        done = 0
        while True:
            item = self.monitor.pop_background()     # atomic: two drainers
            if item is None:                         # cannot double-pop
                break
            sig, plan_key = item
            if sig not in query_by_sig:
                continue
            query = query_by_sig[sig]
            try:
                plan = _plan_from_key(plan_key)
                if len(plan.assignment) != len(query.nodes()):
                    raise ValueError(f"plan covers {len(plan.assignment)} "
                                     f"positions, query has "
                                     f"{len(query.nodes())}")
            except ValueError as exc:    # corrupted history: skip, keep
                warnings.warn(f"background queue: skipping bad plan for "
                              f"{sig!r}: {exc}")       # draining the rest
                continue
            # concurrent, like production: exploration exists to challenge the
            # incumbent's production-mode mean, so its seconds must be
            # measured under the same dispatch mode or the comparison is
            # structurally biased toward whichever plan won training
            res = execute_plan(query, plan,
                               self.catalog, concurrent=True,
                               cost_model=self.cost_model)
            self.monitor.record(sig, plan_key, res.seconds,
                                cast_bytes=res.cast_bytes, sizes=res.size_obs,
                                shapes=res.shape_obs)
            done += 1
        return done
