"""Tensor plans — BigDAWG's planner/monitor protocol applied to compiled SPMD
steps (DESIGN.md §2, "second-level integration").

A PlanConfig is an *engine choice* for a (architecture × input-shape × mesh)
cell: sharding regime, remat policy, accumulation depth, attention layout.
``default_plan`` is the a-priori candidate (the paper's island preference
order); ``enumerate_variants`` is the training-phase plan space; the dry-run's
roofline terms are the stats the monitor records; production picks the plan
with the lowest dominant roofline term.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ArchConfig, PlanConfig, ShapeConfig
from repro.core.monitor import Monitor

# accumulation depth needed to fit 16 GiB/chip activations at train_4k
# (boundary-activation napkin math in DESIGN.md §5)
_TRAIN_ACCUM = {
    "qwen2-72b": 8, "grok-1-314b": 16, "internvl2-26b": 4,
    "codeqwen1.5-7b": 2, "glm4-9b": 2, "zamba2-7b": 4,
    "deepseek-v2-lite-16b": 2,
}


def default_plan(cfg: ArchConfig, shape: ShapeConfig) -> PlanConfig:
    plan = PlanConfig(name="baseline")
    if shape.mode == "train":
        plan = plan.with_(accum=_TRAIN_ACCUM.get(cfg.name, 1))
    if cfg.name == "grok-1-314b":
        plan = plan.with_(moment_dtype="bfloat16")   # 10 B/param, fits v5e
    if shape.mode != "train":
        plan = plan.with_(remat="none")
    return plan


def enumerate_variants(cfg: ArchConfig, shape: ShapeConfig) -> List[PlanConfig]:
    """Training-phase plan space for hillclimbing (§Perf)."""
    base = default_plan(cfg, shape)
    variants = [base]
    if shape.mode == "train":
        for a in (1, 2, 4, 8, 16):
            if a != base.accum and shape.global_batch % a == 0:
                variants.append(base.with_(name=f"accum{a}", accum=a))
        variants.append(base.with_(name="no_sp", sp_boundary=False))
        variants.append(base.with_(name="no_fsdp", fsdp=False))
        variants.append(base.with_(name="remat_none", remat="none"))
    if shape.mode == "prefill":
        for c in (512, 2048, 4096):
            variants.append(base.with_(name=f"chunk{c}", attn_chunk=c))
    if shape.mode == "decode":
        variants.append(base.with_(name="cache_replicated",
                                   cache_seq_shard=False))
    if cfg.moe is not None:
        variants.append(base.with_(name="no_ep", moe_ep=False))
    variants.append(base.with_(name="no_tp", tp=False))
    return variants


def cell_signature(cfg: ArchConfig, shape: ShapeConfig, mesh_kind: str) -> str:
    """The monitor key for a compiled-step cell — structure+objects+constants,
    like the query signatures in core/signature.py."""
    return f"cell:{cfg.name}|{shape.name}|{mesh_kind}"


class TensorPlanSelector:
    """Production-phase plan pick from recorded roofline stats."""

    def __init__(self, monitor: Monitor):
        self.monitor = monitor

    def record(self, cfg, shape, mesh_kind, plan: PlanConfig,
               terms: Dict[str, float]):
        sig = cell_signature(cfg, shape, mesh_kind)
        dominant = max(terms["t_compute"], terms["t_memory"],
                       terms["t_collective"])
        self.monitor.record(sig, plan.name, dominant, extra=dict(terms))

    def best(self, cfg, shape, mesh_kind):
        sig = cell_signature(cfg, shape, mesh_kind)
        key, stats, _ = self.monitor.best(sig)
        return key, stats
