"""Incremental update plans for streaming appends (DBSP-style IVM).

The DBSP framework (SNIPPETS.md §2) treats tables as Z-sets and a query as
a circuit: for LINEAR operators the circuit lifted to change streams is the
operator itself (Q(a + Δa) = Q(a) + Q(Δa)), bilinear operators obey the
chain rule (Δ(a⋈b) = Δa⋈b + a⋈Δb + Δa⋈Δb), and everything else needs
either a folding rule into materialized state or a full recompute.  This
module is that derivation for the PolyOp IR, specialized to the one change
class the STREAM island produces: **rows appended to the end of a table**.

An append is exactly a 2-shard contiguous row-range decomposition —
``[old prefix, appended suffix]`` — so incremental eligibility is the
scatter–gather algebra of ``core/shardplan.py`` re-read vertically: the
``_ROWWISE`` table lists the linear ops (select, project, scale, add,
matmul/spmm/join with replicated right operands, haar, bin_hist,
window_agg) whose output rows for the suffix ARE the suffix of the full
output, and ``_AGG`` lists the decomposable aggregates whose delta
contributions FOLD into the materialized state (count and groupby_sum by
position-wise sum, sort by ordered 2-way merge).  Two append-specific
rules extend the shard algebra:

* ``concat(a, b)`` with the delta on the LAST input collapses to the delta
  subtree itself — concatenation is append composition, the purest linear
  op of the family.
* ``join`` keeps only the Δa⋈b chain-rule term (delta on the LEFT, right
  replicated): the sort-merge join orders output by left row index, so a
  left append IS an output append.  The a⋈Δb and Δa⋈Δb terms interleave
  per-left-row and cannot be patched by concatenation, so a right-side
  delta falls back to recompute — slower, never wrong.

``derive`` returns ``None`` for anything unprovable (scope boundaries in
the delta lineage — casts like dense→columnar explode rows and are not
append-preserving; tfidf — global document frequencies and l2 norms;
distinct, knn, transpose — a row append becomes a column append).  The
caller then recomputes in full and re-materializes: a ``None`` is never
wrong, only slower.  The returned fragment re-binds every changed ref
``name`` to ``delta_name(name)`` — the caller registers the pending
suffix rows under that name in a temporary catalog and executes the
fragment through the ordinary planner/executor path, so health, monitor
and cost-model channels stay live for delta serves.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Set, Tuple

from repro.core import tables
from repro.core.islands import scope
from repro.core.ops import SCOPE_OP, PolyOp, Ref
from repro.core.shardplan import _AGG, _KIND_OUT, _ROWWISE

# suffix under which a changed table's pending delta rows are bound in the
# temporary execution catalog ("A" -> "A@delta"); '@' keeps the binding out
# of any namespace a user registration can occupy (register() names flow
# into qlang identifiers, which cannot contain '@')
DELTA_SUFFIX = "@delta"


def delta_name(name: str) -> str:
    """Temporary-catalog name of ``name``'s pending appended rows."""
    return name + DELTA_SUFFIX


@dataclass(frozen=True)
class UpdatePlan:
    """A validated incremental update: run ``fragment`` against the pending
    deltas (changed refs re-bound to their ``delta_name``), then patch the
    materialized view with ``apply_update`` — ``concat`` appends rows,
    ``sum`` folds aggregate contributions position-wise, ``kmerge`` merges
    two sorted runs on ``merge_by``."""
    fragment: PolyOp
    merge: str                    # concat | sum | kmerge
    merge_by: Optional[str]       # kmerge sort column
    changed: Tuple[str, ...]      # refs the fragment re-binds to deltas


class _NotIncremental(Exception):
    pass


def derive(query: PolyOp, changed: Set[str],
           kinds: Dict[str, str]) -> Optional[UpdatePlan]:
    """Derive the incremental update plan for ``query`` after appends to the
    tables in ``changed`` (``kinds`` maps table name -> container kind; row
    semantics follow the SOURCE container, like ``shardplan.analyze``).
    Returns ``None`` when any operator on the delta lineage is not
    provably append-preserving — the caller must then recompute in full."""
    names = tuple(sorted(n for n in changed
                         if any(r.name == n for r in query.refs())))
    if not names:
        return None
    hot = set(names)

    def visit(node, is_root):
        # -> (delta_lineage, lineage_kind, fragment_subtree)
        if isinstance(node, Ref):
            if node.name in hot:
                return True, kinds.get(node.name, "columnar"), \
                    Ref(delta_name(node.name))
            return False, kinds.get(node.name, "columnar"), node
        child = [visit(x, False) for x in node.inputs]
        if not any(s for s, _, _ in child):
            # untouched subtree: reused verbatim inside the fragment (it
            # recomputes against the replicated full tables, exactly like a
            # replicated operand in a scatter-gather fragment)
            return False, _KIND_OUT.get(node.op) or \
                (child[0][1] if child else "columnar"), node
        if node.op == SCOPE_OP:
            # an island boundary casts the payload; casts are not
            # append-preserving (dense->columnar explodes rows)
            raise _NotIncremental
        if node.op == "concat":
            # concat(a, b): appending rows to the LAST input appends the
            # same rows to the output, so the update fragment is just the
            # delta of that input.  A delta on any earlier input would land
            # mid-output — not patchable by concatenation
            if any(s for s, _, _ in child[:-1]) or not child[-1][0]:
                raise _NotIncremental
            _, k, sub = child[-1]
            return True, k, sub
        if node.op in _AGG:
            if not is_root:
                raise _NotIncremental    # aggregates only fold at the root
            _, allowed = _AGG[node.op]
            if child[0][1] not in allowed or not child[0][0] \
                    or any(s for s, _, _ in child[1:]):
                raise _NotIncremental
            frag = PolyOp(op=node.op, island=node.island,
                          inputs=tuple(sub for _, _, sub in child),
                          attrs=dict(node.attrs))
            return True, "dense" if node.op == "count" else "columnar", frag
        policy = _ROWWISE.get(node.op)
        if policy is None:
            raise _NotIncremental        # distinct/tfidf/knn/transpose/...
        positions, allowed = policy
        for pos, (s, _, _) in enumerate(child):
            if s and pos not in positions:
                raise _NotIncremental    # delta on a replicated slot (e.g.
                #                          the right side of a join/matmul)
            if pos in positions and not s and len(positions) > 1:
                # ops whose hot slots must change TOGETHER (add): one grown
                # and one unchanged operand cannot align row ranges
                raise _NotIncremental
        lineage = next(k for s, k, _ in child if s)
        if lineage not in allowed:
            raise _NotIncremental
        frag = PolyOp(op=node.op, island=node.island,
                      inputs=tuple(sub for _, _, sub in child),
                      attrs=dict(node.attrs))
        out = _KIND_OUT.get(node.op)
        return True, lineage if out is None else out, frag

    try:
        root_delta, _, frag = visit(query, True)
    except _NotIncremental:
        return None
    if not root_delta:
        return None
    if query.op in _AGG:
        merge, _ = _AGG[query.op]
        merge_by = query.attrs.get("by") if merge == "kmerge" else None
    else:
        # row-wise root: wrap the fragment in scope(root island) so the
        # delta result arrives in the island's data model no matter which
        # engine the fragment's own plan picked — the patch concatenates it
        # onto the view, which is ALSO delivered in that model
        merge, merge_by = "concat", None
        frag = scope(query.island, frag)
    return UpdatePlan(fragment=frag, merge=merge, merge_by=merge_by,
                      changed=names)


def apply_update(up: UpdatePlan, view_value, delta_value):
    """Patch a materialized view with one delta-fragment result.  The merge
    primitives are the scatter-gather ones (``core/tables.py``): the view is
    shard 0 (the old prefix's result), the delta result is shard 1."""
    if up.merge == "concat":
        return tables.concat_shards([view_value, delta_value])
    if up.merge == "sum":
        return tables.sum_shards([view_value, delta_value])
    if up.merge == "kmerge":
        return tables.kmerge_shards([view_value, delta_value],
                                    by=up.merge_by)
    raise ValueError(f"unknown merge {up.merge!r}")
