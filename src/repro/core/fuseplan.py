"""Plan-level kernel fusion: compile a cached plan's intra-engine chains
into single jitted callables (ROADMAP item 3; the runtime analogue of
gnitz's JIT-specialized kernels).

The executor dispatches cached plans node-by-node: every op pays a host
round trip (argument gather, engine shim call, async-dispatch bookkeeping)
even when the whole chain is pure device math.  The learned
``dispatch_overhead`` calibration from PR 4 says exactly how much time that
leaves on the table.  This module closes it for the dense/array family:

  segmentation  ``fuse_plan(query, plan)`` walks the post-order under the
      plan's engine assignment and groups maximal same-engine chains of
      *fusable* ops — ``matmul``, ``add``, ``scale``, ``transpose``,
      ``select``, ``haar``, ``tfidf``, ``knn``, ``count`` on the
      ``dense_array`` engine, whose implementations are pure jnp traces
      over ``DenseTensor.data`` (``count`` over its threaded valid-count —
      see below) — into ``FusedSegment``s.  A segment never
      crosses an engine boundary (members share one assignment) and never
      absorbs an island-boundary (``scope``) node: scope is not fusable, so
      every island seam breaks the chain and its cast stays an explicit,
      byte-accounted migrator edge.  Cast-in edges at a segment boundary
      (an external input homed on another data model) happen as part of the
      segment's single host task — the migrator casts them onto the engine
      before the compiled call, and everything between stays on device
      end-to-end.

  compilation  each segment lowers to one python function over raw jnp
      arrays — routing ``haar``/``knn`` through ``kernels.ops`` (so the
      Pallas kernels serve them on TPU, the jnp references elsewhere) and
      composing the rest as jnp — wrapped in a single ``jax.jit``.  The
      wrapped callable is cached process-wide under the segment's
      *structural key* (engine + per-member op/attrs + wiring); ``jax.jit``
      itself specializes per input (shapes, dtypes), so the full compile
      cache key is (segment signature, shapes, dtypes) and a warm serve of
      a previously-seen segment shape skips tracing entirely.  The
      middleware stores the ``FusedPlan`` on its ``CachedPlan`` entry
      (runtime-only — never persisted, like the alternate-rotation cursor).

  fallback  fusion must never change results or turn a servable query into
      an error.  Any failure of the fused call — trace, compile, or run —
      marks the segment key *broken* in a process-wide registry
      (``mark_broken``) and the executor re-runs the members node-by-node
      in the same host task; later serves see the sticky mark and skip the
      fused attempt for that signature.  ``ExecutionResult.fusion_fallbacks``
      counts transitions, and the middleware rolls them up into
      ``stats["fusion_fallbacks"]``.

Equivalence notes (what the ``tests/test_fusion.py`` property battery
pins): member semantics mirror ``engines._da_*`` exactly — intermediates
flow ``.data`` plus a threaded valid-count value (``count`` is the one
dense op that consumes metadata instead of data: external counts enter
the trace as scalars, a ``select`` narrows the threaded count with its
mask sum, ``count`` reads it), so composing data-level functions is
identical to chaining containers; a ``select`` at the segment root
additionally returns its mask sum so the output's ``valid_count`` matches
the eager engine's (engine-produced tensors carry the default fill, which
the lowering also uses for member-to-member edges).  Queries with
shared subtrees (one uid at several post-order positions) are not fused —
segmentation is position-keyed so a ``FusedPlan`` survives query rebuilds,
and sharing would break the one-position-per-uid mapping.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CostModel
from repro.core.ops import SCOPE_OP, PolyOp, Ref
from repro.core.planner import Plan, _work_elems, estimate_sizes_shapes
from repro.core.tables import DenseTensor

# the dense/array fusable family: every op here is a pure jnp trace over
# DenseTensor.data in engines.py.  ``count`` consumes valid_count METADATA
# rather than data, so the lowering threads a per-member valid-count value
# through the trace (external counts enter as traced scalars, a select's
# mask sum updates it, count reads it) — see ``_build_callable``.
# (distinct/bin_hist are still excluded; bin_hist is fusable in principle
# and a natural follow-on)
FUSABLE_OPS = frozenset({"matmul", "add", "scale", "transpose", "select",
                         "haar", "tfidf", "knn", "count"})

# engines whose fusable ops trace (dense/array family first — triple-format
# engines are numpy-eager in places and not jit-safe)
FUSABLE_ENGINES = frozenset({"dense_array"})

# a single-node "chain" gains nothing from fusion (one dispatch either way)
# and would pay a compile per attrs variant — segments need >= 2 members
MIN_SEGMENT_NODES = 2

# -- process-wide compiled-callable cache + sticky fallback registry --------
_COMPILED: Dict[str, Callable] = {}
_BROKEN: Dict[str, str] = {}        # segment key -> failure description
_WARM: set = set()                  # (key, ext shapes/dtypes) runs completed
_REGISTRY_LOCK = threading.Lock()


def reset_cache() -> None:
    """Drop all compiled segment callables AND sticky fallback marks
    (tests; a long-lived process never needs this — jit caches are the
    point)."""
    with _REGISTRY_LOCK:
        _COMPILED.clear()
        _BROKEN.clear()
        _WARM.clear()


def is_broken(key: str) -> bool:
    with _REGISTRY_LOCK:
        return key in _BROKEN


def mark_broken(key: str, reason: str) -> None:
    """Sticky per-signature fallback: once a segment key failed to
    trace/compile/run fused, no later serve retries it."""
    with _REGISTRY_LOCK:
        _BROKEN.setdefault(key, reason)
        _COMPILED.pop(key, None)


def broken_keys() -> Dict[str, str]:
    with _REGISTRY_LOCK:
        return dict(_BROKEN)


@dataclass(frozen=True)
class FusedSegment:
    """One maximal fusable chain of a plan, keyed by post-order position so
    it survives query rebuilds (uids do not).

    ``input_specs[j]`` describes member j's arguments: ``("mem", i)`` is the
    i-th member's output (stays on device inside the trace); ``("ext", s)``
    is the s-th external input.  ``ext_sources[s]`` locates it at execute
    time: ``("ref", name)`` from the catalog, ``("pos", p)`` from the value
    another unit produced at post-order position p."""
    engine: str
    positions: Tuple[int, ...]           # members, dependency (post) order
    ops: Tuple[str, ...]
    attrs_list: Tuple[Tuple[Tuple[str, Any], ...], ...]   # sorted attr items
    input_specs: Tuple[Tuple[Tuple[str, int], ...], ...]
    ext_sources: Tuple[Tuple[str, Any], ...]
    weights: Tuple[float, ...]           # pro-rata time attribution, sums to 1
    key: str                             # structural signature (cache key)

    @property
    def root_pos(self) -> int:
        return self.positions[-1]


@dataclass
class FusedPlan:
    """The fusion pass's output for one (query shape, plan): the segments
    plus the exact structural fingerprint of the query it was built from.
    Signatures bin constant attrs, so two queries can share a signature yet
    differ in exact attr values — the compiled callables close over the
    build query's attrs, and the middleware compares ``fingerprint`` before
    reusing a cached FusedPlan (mismatch -> rebuild, not wrong answers)."""
    plan_key: str
    fingerprint: str
    segments: Tuple[FusedSegment, ...] = ()
    # optional runtime.fault.FusionFaultInjector: its on_fuse(key) hook
    # fires just before every fused invocation (the compile-failure seam)
    injector: Any = None

    @property
    def n_fused_nodes(self) -> int:
        return sum(len(s.positions) for s in self.segments)


def query_fingerprint(query: PolyOp) -> str:
    """Exact structural identity of a query instance: islands, ops, EXACT
    attr values, and input wiring — everything a compiled segment closes
    over.  Cheaper than ``signature()`` (no hashing, no catalog) and
    stricter (signatures bin constants; this must not)."""
    parts: List[str] = []
    pos_of: Dict[int, int] = {}
    for pos, node in enumerate(query.nodes()):
        pos_of[node.uid] = pos
        ins = ",".join(f"r:{i.name}" if isinstance(i, Ref)
                       else f"n:{pos_of[i.uid]}" for i in node.inputs)
        attrs = ",".join(f"{k}={node.attrs[k]!r}"
                         for k in sorted(node.attrs))
        parts.append(f"{node.island}.{node.op}[{attrs}]({ins})")
    return ";".join(parts)


# ---------------------------------------------------------------------------
# segmentation
# ---------------------------------------------------------------------------

def fuse_plan(query: PolyOp, plan: Plan, catalog=None,
              cost_model: Optional[CostModel] = None,
              injector: Any = None,
              min_nodes: int = MIN_SEGMENT_NODES) -> FusedPlan:
    """Segment ``plan``'s post-order into maximal same-engine fusable
    chains.  Always returns a FusedPlan (possibly with no segments — the
    middleware caches it either way so unfusable shapes are analyzed
    once); never raises on an unfusable query."""
    nodes = query.nodes()
    fp = query_fingerprint(query)
    empty = FusedPlan(plan.key, fp, (), injector)
    if len(plan.assignment) != len(nodes):
        return empty
    uids = [n.uid for n in nodes]
    if len(set(uids)) != len(uids):
        # shared subtree: a uid at several positions breaks the positional
        # keying (and a member could gain consumers outside its segment)
        return empty
    pos_of = {uid: pos for pos, uid in enumerate(uids)}
    amap = dict(plan.assignment)         # position -> engine

    def fusable(pos: int, node: PolyOp) -> bool:
        return (node.op != SCOPE_OP and node.op in FUSABLE_OPS
                and amap[pos] in FUSABLE_ENGINES)

    # greedy bottom-up: a fusable node absorbs each fusable same-engine
    # input chain (post-order means input chains are complete when their
    # single consumer — this is a tree — arrives)
    seg_of: Dict[int, int] = {}          # position -> segment id
    members: Dict[int, List[int]] = {}   # segment id -> positions
    next_id = 0
    for pos, node in enumerate(nodes):
        if not fusable(pos, node):
            continue
        sid = next_id
        next_id += 1
        mine = [pos]
        for inp in node.inputs:
            if not isinstance(inp, PolyOp):
                continue
            ip = pos_of[inp.uid]
            isid = seg_of.get(ip)
            if isid is not None and amap[ip] == amap[pos]:
                mine = members.pop(isid) + mine
        members[sid] = mine
        for p in mine:
            seg_of[p] = sid

    segments: List[FusedSegment] = []
    for mine in members.values():
        if len(mine) < min_nodes:
            continue
        mine = sorted(mine)              # ascending post-order = topo order
        segments.append(_build_segment(nodes, pos_of, amap, mine,
                                       query, catalog, cost_model))
    segments.sort(key=lambda s: s.root_pos)
    return FusedPlan(plan.key, fp, tuple(segments), injector)


def _build_segment(nodes, pos_of, amap, mine: List[int], query: PolyOp,
                   catalog, cost_model) -> FusedSegment:
    midx = {p: j for j, p in enumerate(mine)}
    ext_sources: List[Tuple[str, Any]] = []
    ext_slot: Dict[Tuple[str, Any], int] = {}
    specs: List[Tuple[Tuple[str, int], ...]] = []
    for p in mine:
        spec: List[Tuple[str, int]] = []
        for inp in nodes[p].inputs:
            if isinstance(inp, PolyOp) and pos_of[inp.uid] in midx:
                spec.append(("mem", midx[pos_of[inp.uid]]))
                continue
            src = ("ref", inp.name) if isinstance(inp, Ref) \
                else ("pos", pos_of[inp.uid])
            slot = ext_slot.get(src)
            if slot is None:
                slot = ext_slot[src] = len(ext_sources)
                ext_sources.append(src)
            spec.append(("ext", slot))
        specs.append(tuple(spec))
    attrs_list = tuple(tuple(sorted(nodes[p].attrs.items())) for p in mine)
    ops = tuple(nodes[p].op for p in mine)
    engine = amap[mine[0]]
    key = _segment_key(engine, ops, attrs_list, specs, len(ext_sources))
    weights = _segment_weights(query, catalog, cost_model, nodes, mine,
                               engine)
    return FusedSegment(engine, tuple(mine), ops, attrs_list, tuple(specs),
                        tuple(ext_sources), weights, key)


def _segment_key(engine, ops, attrs_list, specs, n_ext) -> str:
    """Structural signature: everything the compiled callable's behavior
    depends on (engine, member ops, EXACT attrs, wiring, ext arity) and
    nothing it does not (shapes/dtypes — ``jax.jit`` specializes on those
    beneath this key, so structurally-identical segments across different
    queries share one callable)."""
    mem = ";".join(
        f"{op}[{','.join(f'{k}={v!r}' for k, v in attrs)}]"
        f"({','.join(f'{kind}{i}' for kind, i in spec)})"
        for op, attrs, spec in zip(ops, attrs_list, specs))
    return f"{engine}:{n_ext}:{mem}"


def _segment_weights(query, catalog, cost_model, nodes, mine,
                     engine) -> Tuple[float, ...]:
    """Pro-rata attribution weights: the executor splits a fused segment's
    measured seconds across member nodes by *predicted* cost, so
    ``per_node_seconds`` keeps feeding the monitor, drift re-planning and
    the per-engine straggler detectors exactly as unfused serves do."""
    if cost_model is None:
        return tuple([1.0 / len(mine)] * len(mine))
    try:
        sizes, _ = estimate_sizes_shapes(query, catalog)
        pred = [max(cost_model.op_seconds(
                    engine, nodes[p].op,
                    _work_elems(nodes[p], sizes, catalog)), 1e-12)
                for p in mine]
    except Exception:                     # never let sizing sink the fuse
        return tuple([1.0 / len(mine)] * len(mine))
    total = sum(pred)
    return tuple(w / total for w in pred)


# ---------------------------------------------------------------------------
# lowering + compilation
# ---------------------------------------------------------------------------

def _lower(op: str, attrs: Dict[str, Any], args, fills, vcs,
           want_aux: bool):
    """One member op as a pure function of jnp arrays — the trace-level
    mirror of ``engines._da_*`` (same math, minus the container wrappers).
    ``fills`` aligns with ``args``: the fill value each argument's
    container carries (select writes it into masked-out slots).  ``vcs``
    also aligns with ``args``: each argument's valid-count as a traced
    scalar, or ``None`` meaning *full* (every element valid — resolve with
    the static ``args[i].size``).  Returns ``(out, vc_out, aux)``:
    ``vc_out`` is the member's output valid-count under the same
    convention (only select narrows it; count's 0-d output is full), and
    ``aux`` is the select mask sum when ``want_aux`` (root selects must
    reproduce the eager engine's ``valid_count`` on the container)."""
    if op == "matmul":
        return jnp.dot(args[0], args[1]), None, None
    if op == "add":
        return args[0] + args[1], None, None
    if op == "scale":
        return args[0] * attrs["factor"], None, None
    if op == "transpose":
        return args[0].T, None, None
    if op == "select":
        lo = attrs.get("lo", -np.inf)
        hi = attrs.get("hi", np.inf)
        m = (args[0] >= lo) & (args[0] <= hi)
        out = jnp.where(m, args[0], fills[0])
        vc = jnp.sum(m)
        return out, vc, (vc if want_aux else None)
    if op == "count":
        # the eager op is O(1) metadata lookup; here the metadata is the
        # threaded valid-count value (traced for a select upstream or a
        # padded external, static size otherwise)
        vc = vcs[0] if vcs[0] is not None else args[0].size
        return jnp.asarray(vc, jnp.int32), None, None
    if op == "haar":
        from repro.kernels import ops as kops
        return kops.haar(args[0], attrs["levels"]), None, None
    if op == "tfidf":
        from repro.core.engines import tfidf_dense
        return tfidf_dense(args[0]), None, None
    if op == "knn":
        from repro.kernels import ops as kops
        idx, _score = kops.knn(args[0], jnp.atleast_2d(args[1]),
                               attrs["k"])
        return idx, None, None
    raise ValueError(f"op {op!r} is not fusable")


def _build_callable(seg: FusedSegment) -> Callable:
    """The segment as one function ``fn(ext_arrays, ext_fills, ext_vcs) ->
    (root_array, root_aux)``, jitted whole.  Intermediates never leave the
    trace; engine-produced containers carry the default fill (0.0), so
    member-to-member fills are the constant 0.0 while external inputs pass
    their container's real fill in as a traced scalar (no retrace when a
    catalog object's fill differs between serves).  ``ext_vcs`` are the
    external containers' valid-counts, likewise traced scalars: the loop
    threads a per-member valid-count alongside the data (select narrows
    it, count reads it) so metadata-consuming members fuse without
    retracing when only the count changes."""
    ops, attrs_list, specs = seg.ops, seg.attrs_list, seg.input_specs
    last = len(ops) - 1

    def fn(ext, fills, vcs):
        mem: List[Any] = []
        mem_vc: List[Any] = []
        aux = None
        for j, (op, attrs, spec) in enumerate(zip(ops, attrs_list, specs)):
            args, afills, avcs = [], [], []
            for kind, i in spec:
                if kind == "ext":
                    args.append(ext[i])
                    afills.append(fills[i])
                    avcs.append(vcs[i])
                else:
                    args.append(mem[i])
                    afills.append(0.0)
                    avcs.append(mem_vc[i])
            out, vc_out, a = _lower(op, dict(attrs), args, afills, avcs,
                                    want_aux=j == last)
            mem.append(out)
            mem_vc.append(vc_out)
            if j == last:
                aux = a
        return mem[-1], aux

    return jax.jit(fn)


def compiled_segment(seg: FusedSegment) -> Callable:
    """The process-wide compiled callable for a segment key (built once;
    ``jax.jit`` caches per shapes/dtypes beneath it)."""
    with _REGISTRY_LOCK:
        fn = _COMPILED.get(seg.key)
        if fn is None:
            fn = _COMPILED[seg.key] = _build_callable(seg)
        return fn


def run_fused_segment(seg: FusedSegment,
                      ext_objs) -> Tuple[DenseTensor, bool]:
    """Invoke the segment's compiled callable on already-migrated external
    inputs (containers of the engine's kind).  Raises whatever the trace or
    run raises — the executor owns the fallback.  Returns ``(out, cold)``:
    ``cold`` is True when this (key, ext shapes/dtypes) had never completed
    a run, i.e. the call paid trace+compile — the middleware treats such a
    serve as a warm-up and keeps its wall time out of the plan's measured
    mean (and the divergence re-plan trigger it feeds)."""
    fn = compiled_segment(seg)
    ext = tuple(jnp.asarray(o.data) for o in ext_objs)
    fills = tuple(float(getattr(o, "fill", 0.0)) for o in ext_objs)
    # valid-counts ride along as traced scalars (DenseTensor resolves the
    # "full" sentinel at construction, so this is always a real count)
    vcs = tuple(int(getattr(o, "valid_count", o.data.size))
                for o in ext_objs)
    stamp = (seg.key, tuple((a.shape, str(a.dtype)) for a in ext))
    with _REGISTRY_LOCK:
        cold = stamp not in _WARM
    out, aux = fn(ext, fills, vcs)
    with _REGISTRY_LOCK:
        _WARM.add(stamp)
    if aux is not None:
        # root select: adopt the traced mask sum as valid_count — the same
        # (blocking) int() the eager engine op performs
        return DenseTensor(out, valid_count=int(aux)), cold
    return DenseTensor(out), cold
