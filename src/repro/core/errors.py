"""The BigDAWG error taxonomy — one structured exception family for the
whole serving stack, so callers can react to *categories* of failure
instead of string-matching messages:

    BigDAWGError                 every error the polystore itself raises
     ├── QueryParseError         the textual qlang query did not parse
     ├── EngineDown              an engine op / cast failed or was tripped
     ├── PlanInfeasible          no engine assignment exists under the
     │                           current health mask (every candidate of
     │                           some op is on a tripped engine)
     └── Overloaded              admission control shed the request (also
                                 used as the in-order result slot for shed
                                 batch requests — never executed)

Anything NOT in this family (``KeyError`` on a bad column name, a
``TypeError`` from malformed attrs) is a *query* error: it propagates
unchanged and is never fed to the circuit breakers, because failing over a
buggy query to another engine would just fail there too.

``is_engine_failure`` draws that line for the executor: an exception
counts as an engine failure — breaker-feedable, failover-worthy — when it
is infrastructure-shaped (timeouts, connection loss) or explicitly marked
with an ``engine_failure = True`` class attribute (how
``runtime.fault.SimulatedFailure`` opts injected faults in without a
core -> runtime import).
"""
from __future__ import annotations

from typing import Optional, Tuple


class BigDAWGError(Exception):
    """Base of every error the polystore middleware raises on purpose."""


class QueryParseError(BigDAWGError, ValueError):
    """A qlang query failed to parse; the message carries the offset and a
    caret-annotated excerpt of the source text.  (Also a ``ValueError`` so
    pre-taxonomy ``except ValueError`` callers keep working.)"""


class EngineDown(BigDAWGError):
    """An engine op (or an input cast onto it) failed on ``engine`` —
    either a real exception classified as an engine failure, or the
    engine's circuit breaker rejecting work.  The middleware catches this
    and fails over: re-plans with the engine masked and retries."""

    def __init__(self, engine: str, op: str = "",
                 cause: Optional[BaseException] = None):
        self.engine = engine
        self.op = op
        self.cause = cause
        detail = f" running {op!r}" if op else ""
        tail = f": {cause!r}" if cause is not None else ""
        super().__init__(f"engine {engine!r} failed{detail}{tail}")


class PlanInfeasible(BigDAWGError):
    """No executable plan exists: some op's entire candidate engine set is
    masked (tripped breakers / a degrade mask).  Nothing was executed."""

    def __init__(self, op: str, island: str, masked: Tuple[str, ...] = ()):
        self.op = op
        self.island = island
        self.masked = tuple(masked)
        super().__init__(
            f"no engine can run {island}.{op}: every candidate is masked "
            f"({', '.join(self.masked) or 'none listed'})")


class Overloaded(BigDAWGError):
    """Admission control rejected the request without executing it — the
    bounded/adaptive shedding path.  Instances double as the in-order
    result slots ``QueryServer.submit_many`` returns for shed requests
    (the pre-taxonomy ``Shed`` sentinel, which remains importable as a
    deprecated alias), so ``query`` carries exactly what was dropped for
    the caller to retry."""

    # mirrors Report/Result.status so a mixed submit_many result list can be
    # partitioned on one attribute: r.status in ("ok", "degraded", "shed")
    status = "shed"

    def __init__(self, query=None, reason: str = "max_pending"):
        self.query = query
        self.reason = reason
        super().__init__(f"request shed ({reason})")


def is_engine_failure(exc: BaseException) -> bool:
    """Should this exception feed the engine's circuit breaker (True), or
    is it a query bug that would fail identically anywhere (False)?"""
    if getattr(exc, "engine_failure", False):
        return True
    return isinstance(exc, (TimeoutError, ConnectionError, BrokenPipeError))
