"""The shared request-pool pattern (PR 4): a grow-only
``ThreadPoolExecutor`` plus semaphore-gated batch submission, factored out so
``runtime.server.QueryServer``, ``runtime.server.BatchServer`` and
``core.api.Session`` all drive admission through one idiom instead of three
hand-rolled pools.

Two invariants every user relies on:

* **grow-only** — a superseded (smaller) pool is never shut down: an
  in-flight submit may still hold it, and ``shutdown`` would raise
  ``RuntimeError`` mid-request.  Idle threads of an old pool park until
  process exit; growth happens at most a handful of times.
* **submission-time gating** — when a batch asks for fewer workers than the
  pool has, the width limit is enforced with a semaphore taken by the
  SUBMITTING thread, not by parking excess tasks inside workers: parked
  tasks would occupy pool threads and FIFO-starve a concurrent caller's
  batch.

This module is stdlib-only so ``core`` can import it without touching
``runtime`` (which pulls in jax at import time).
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, List, Optional, Sequence


class RequestPool:
    """A lazily-built, grow-only thread pool for request admission."""

    DEFAULT_WORKERS = 4

    def __init__(self, thread_name_prefix: str = "bigdawg-request"):
        self._pool: Optional[ThreadPoolExecutor] = None
        self._size = 0
        self._lock = threading.Lock()
        self._prefix = thread_name_prefix

    def pool(self, workers: Optional[int] = None) -> ThreadPoolExecutor:
        want = workers or self.DEFAULT_WORKERS
        with self._lock:
            if self._pool is None or self._size < want:
                self._pool = ThreadPoolExecutor(
                    max_workers=want, thread_name_prefix=self._prefix)
                self._size = want
            return self._pool

    def submit(self, fn: Callable, *args, workers: Optional[int] = None,
               **kwargs) -> Future:
        """Submit one task (growing the pool to ``workers`` if asked)."""
        return self.pool(workers).submit(fn, *args, **kwargs)

    def map_ordered(self, fn: Callable[[Any], Any], items: Sequence,
                    workers: Optional[int] = None) -> List:
        """Run ``fn`` over ``items`` at most ``workers`` wide and return the
        results in input order.  ``workers<=1`` (or a single item) degrades
        to a plain sequential loop — no pool round-trips.  The width gate is
        taken at submission time (see module docstring); a task exception
        propagates out of the corresponding ``result()`` call, in input
        order."""
        items = list(items)
        w = workers if workers is not None else self.DEFAULT_WORKERS
        if w <= 1 or len(items) <= 1:
            return [fn(item) for item in items]
        pool = self.pool(w)
        gate = threading.Semaphore(w)
        futures: List[Future] = []
        for item in items:
            gate.acquire()
            fut = pool.submit(fn, item)
            fut.add_done_callback(lambda _f: gate.release())
            futures.append(fut)
        return [f.result() for f in futures]
