"""Request tracing: per-request trees of timed spans.

The paper's middleware carries a *monitor* that "collects performance
information about each query"; ``Monitor`` (monitor.py) keeps the
*aggregate* half of that story (per-signature engine rates that feed the
optimizer).  This module adds the *request-scoped* half: a ``Tracer``
produces one ``Trace`` per request — a tree of timed ``Span`` records
(``plan``, ``cache_hit``/``cache_miss``, ``train``, ``cast``,
``engine_op``, ``fused_segment``, ``ivm_patch``, ``failover``,
``queue_wait``, ``worker_dispatch``, ...) with ids, parent ids, and
attributes (signature, engine, plan key, bytes).

Design constraints, in order:

1. **Near-zero overhead when disabled.**  A disabled ``Tracer`` returns
   ``None`` from :meth:`Tracer.start`; every instrumentation site guards
   with ``if span is not None`` and makes *no* ``perf_counter`` calls and
   *no* allocations on the disabled path.
2. **Cross-process.**  A trace survives the procpool pipe RPC: the master
   ships ``(trace_id, parent_span_id)`` with the request, the worker roots
   its spans under that parent, and the master re-attaches the worker's
   serialized records into its own tree (:meth:`Trace.adopt`).  Span ids
   embed the pid so records from different processes never collide.
3. **Cheap when enabled.**  Spans are recorded as flat dicts appended
   under one lock; the tree is only materialized on demand
   (:meth:`Trace.tree`).  Hot paths that already measured a duration
   attach it via :meth:`Span.static_child` instead of re-timing.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["Span", "Trace", "Tracer", "NULL_TRACER"]

_IDS = itertools.count(1)


def _new_id() -> str:
    """Process-unique span id; pid prefix keeps ids unique across workers."""
    return "%x-%d" % (os.getpid(), next(_IDS))


class Span:
    """A live (in-progress) span.  Use as a context manager, or call
    :meth:`end` explicitly.  Finished spans live on as plain dicts inside
    the owning :class:`Trace`."""

    __slots__ = ("trace", "name", "sid", "parent", "attrs", "t0", "_done")

    def __init__(self, trace: "Trace", name: str, parent: Optional[str],
                 attrs: Dict[str, Any]):
        self.trace = trace
        self.name = name
        self.sid = _new_id()
        self.parent = parent
        self.attrs = attrs
        self.t0 = time.perf_counter()
        self._done = False

    # -- lifecycle ---------------------------------------------------------
    def end(self, seconds: Optional[float] = None) -> None:
        if self._done:
            return
        self._done = True
        dt = time.perf_counter() - self.t0 if seconds is None else seconds
        self.trace._append(self.name, self.sid, self.parent, dt, self.attrs)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.end()

    # -- children ----------------------------------------------------------
    def child(self, name: str, **attrs: Any) -> "Span":
        """Start a timed child span."""
        return Span(self.trace, name, self.sid, attrs)

    def static_child(self, name: str, seconds: float, **attrs: Any) -> str:
        """Record an already-measured child span; returns its span id so
        further static children can nest under it (pro-rata attribution)."""
        return self.trace._append(name, _new_id(), self.sid, seconds, attrs)

    def event(self, name: str, **attrs: Any) -> str:
        """Record a zero-duration child marker (e.g. ``cache_hit``)."""
        return self.trace._append(name, _new_id(), self.sid, 0.0, attrs)

    def annotate(self, **attrs: Any) -> None:
        self.attrs.update(attrs)


class Trace:
    """One request's span records.  Thread-safe appends; records from
    worker processes are merged in via :meth:`adopt`."""

    __slots__ = ("trace_id", "parent_sid", "spans", "_lock")

    def __init__(self, trace_id: Optional[str] = None,
                 parent_sid: Optional[str] = None):
        self.trace_id = trace_id or _new_id()
        self.parent_sid = parent_sid        # cross-process root attachment
        self.spans: List[Dict[str, Any]] = []
        self._lock = threading.Lock()

    # -- recording ---------------------------------------------------------
    def _append(self, name: str, sid: str, parent: Optional[str],
                seconds: float, attrs: Dict[str, Any]) -> str:
        rec = {"name": name, "sid": sid,
               "parent": parent if parent is not None else self.parent_sid,
               "seconds": float(seconds)}
        if attrs:
            rec["attrs"] = attrs
        with self._lock:
            self.spans.append(rec)
        return sid

    def static(self, name: str, parent: Optional[str], seconds: float,
               **attrs: Any) -> str:
        """Record an already-measured span under an arbitrary parent sid
        (e.g. per-member ``engine_op`` records nested under a
        ``fused_segment``'s id)."""
        return self._append(name, _new_id(), parent, seconds, attrs)

    def root(self, name: str, **attrs: Any) -> Span:
        """Start this trace's root span (parented across the process
        boundary when ``parent_sid`` was propagated)."""
        return Span(self, name, None, attrs)

    def adopt(self, blob: Optional[Dict[str, Any]]) -> None:
        """Merge serialized records from another process into this tree.
        Worker records arrive already parented (their root carries the
        ``parent_sid`` the master sent), so this is a plain extend."""
        if not blob:
            return
        recs = blob.get("spans", []) if isinstance(blob, dict) else list(blob)
        with self._lock:
            self.spans.extend(recs)

    # -- context propagation ----------------------------------------------
    def ctx(self, span: Optional[Span] = None) -> Tuple[str, Optional[str]]:
        """``(trace_id, parent_span_id)`` tuple to ship across an RPC."""
        return (self.trace_id, span.sid if span is not None else None)

    # -- export ------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        with self._lock:
            return {"trace_id": self.trace_id, "spans": list(self.spans)}

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=False)

    def tree(self) -> List[Dict[str, Any]]:
        """Materialize the nested tree: a list of root nodes, each
        ``{name, sid, seconds, attrs, children: [...]}`` in record order.
        Spans whose parent is unknown (e.g. a worker-side fragment whose
        master span was elided) surface as roots rather than vanishing."""
        with self._lock:
            recs = [dict(r) for r in self.spans]
        by_sid = {r["sid"]: r for r in recs}
        for r in recs:
            r["children"] = []
        roots: List[Dict[str, Any]] = []
        for r in recs:
            p = by_sid.get(r.get("parent"))
            if p is None:
                roots.append(r)
            else:
                p["children"].append(r)
        return roots

    def find(self, name: str) -> List[Dict[str, Any]]:
        """All span records with the given name, in record order."""
        with self._lock:
            return [r for r in self.spans if r["name"] == name]

    def __len__(self) -> int:
        with self._lock:
            return len(self.spans)


class Tracer:
    """Trace factory.  ``Tracer(enabled=False)`` (or the module-level
    :data:`NULL_TRACER`) never allocates a trace: :meth:`start` returns
    ``None`` unless the caller passes a propagated context, and every
    instrumentation site checks for ``None`` before touching the clock."""

    __slots__ = ("enabled",)

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)

    def start(self, ctx: Optional[Tuple[str, Optional[str]]] = None
              ) -> Optional[Trace]:
        """Begin a trace for one request.  ``ctx`` is a propagated
        ``(trace_id, parent_span_id)`` from an upstream process; when
        given, tracing is forced on for this request so the worker's
        spans can re-attach to the master's tree."""
        if ctx is not None:
            return Trace(trace_id=ctx[0], parent_sid=ctx[1])
        if not self.enabled:
            return None
        return Trace()

    def __bool__(self) -> bool:
        return self.enabled


NULL_TRACER = Tracer(enabled=False)


def portable(trace: Optional[Any]) -> Optional[Dict[str, Any]]:
    """Picklable form of a trace for the pipe RPC (Trace carries a lock)."""
    if trace is None:
        return None
    return trace.to_dict() if isinstance(trace, Trace) else trace
