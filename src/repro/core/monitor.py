"""Performance monitor (paper §III-C / [17]): a history DB keyed by query
signature, holding per-plan statistics and the system-usage snapshot at
measurement time.  Production-phase matching compares the current usage
snapshot against the recorded one; large drift triggers retraining advice
(paper: "the optimizer may ... recommend that the user rerun the query under
the training phase under the current usage").

Beyond per-plan timings, the monitor stores *measured intermediate sizes*
AND *measured dense-equivalent shapes*: the executor reports each node's
actual logical output bytes and output shape (keyed by post-order position,
which is stable across structurally-identical query rebuilds — the same
property plan keys rely on), and ``measured_sizes`` / ``measured_shapes``
hand them back to the planner so data-dependent ops (select, join,
distinct) are sized from observation instead of shape rules, and downstream
shape-driven estimates (matmul, transpose) build on observed geometry.

**History decay.**  All running means (per-plan seconds and cast bytes,
per-position sizes) are *exponentially decayed*: each new sample enters with
weight ``alpha = max(1 / (n + 1), decay)``, so the first few samples behave
exactly like a cumulative mean and, once ``n + 1 > 1 / decay``, the mean
becomes an EMA whose newest-sample weight floors at ``decay``.  A workload
shift (the same signature suddenly selecting 10x the rows, or a plan's
runtime regressing) therefore moves the mean within ~``1/decay`` runs
instead of being diluted by an unbounded tail of stale samples.  The knob is
``Monitor(path, decay=...)`` (default ``DECAY = 0.2``, i.e. full cumulative
averaging through the first 5 samples, then a 5-run effective window);
``decay=0.0`` restores pure cumulative means.

**Thread safety and batched flushing.**  The monitor is written to from
many threads at once — concurrent production serves, training runs on
different signatures, and background exploration tasks on the host pool.
``record`` therefore never mutates the history dicts directly: it appends
the raw observation to a pending queue (one lock-guarded list append, cheap
enough for the request path) and ``flush()`` drains that queue, applying the
decayed-mean updates in arrival order.  Every reader (``best``,
``known_plans``, ``measured_sizes``, ``measured_shapes``) and ``save()``
flushes first, so external behavior is exactly the per-record semantics —
batched only between a record and the next read.  All state is guarded by
one internal ``RLock``.

Persistence: one JSON file (``Monitor(path)``), written atomically through
``ioutil.atomic_json_dump`` — the blob is dumped to a same-directory temp
file and moved into place with ``os.replace``, so a crash mid-save can never
truncate or corrupt the DB (the previous version survives intact).  Format
(version 3 adds ``shapes``; version-2 files and version-1 files — a bare
``{sig: {plan_key: stats}}`` mapping — still load)::

    {"format": 3,
     "plans":  {sig: {plan_key: PlanStats-dict}},    # timings + usage
     "sizes":  {sig: {post_order_pos: [mean_bytes, n_samples]}},
     "shapes": {sig: {post_order_pos: [dim, ...]}}}  # last observed shape

Worked example (round-trips through one file)::

    >>> m = Monitor("/tmp/demo.monitor.json")
    >>> m.record("s1", "0:dense_array", 0.02, sizes={0: 4096.0},
    ...          shapes={0: (32, 32)})
    >>> m.save()                              # atomic write
    >>> m2 = Monitor("/tmp/demo.monitor.json")    # fresh process: warm start
    >>> m2.best("s1")[0]
    '0:dense_array'
    >>> m2.measured_sizes("s1")
    {0: 4096.0}
    >>> m2.measured_shapes("s1")
    {0: (32, 32)}
"""
from __future__ import annotations

import os
import resource
import threading
import time
from dataclasses import dataclass, field, asdict
from typing import Dict, Optional, Tuple

from repro.core.ioutil import (atomic_json_dump, file_version, load_json,
                               load_json_versioned)


def _ema_alpha(n: int, decay: float) -> float:
    """Weight of the newest sample: cumulative-mean behavior for the first
    ``1/decay`` samples, then an EMA floored at ``decay`` (see module
    docstring)."""
    return max(1.0 / (n + 1), decay)


@dataclass
class PlanStats:
    mean_seconds: float = 0.0
    n: int = 0
    last_seconds: float = 0.0
    cast_bytes: float = 0.0
    usage: Dict[str, float] = field(default_factory=dict)
    extra: Dict[str, float] = field(default_factory=dict)

    def record(self, seconds: float, usage: Dict[str, float],
               cast_bytes: float = 0.0, extra: Optional[Dict] = None,
               decay: float = 0.0):
        a = _ema_alpha(self.n, decay)
        self.mean_seconds = (1.0 - a) * self.mean_seconds + a * seconds
        # decayed like mean_seconds — a single light run must not overwrite
        # the history (cast traffic can vary with catalog state)
        self.cast_bytes = (1.0 - a) * self.cast_bytes + a * cast_bytes
        self.n += 1
        self.last_seconds = seconds
        self.usage = dict(usage)
        if extra:
            self.extra.update(extra)


def usage_snapshot() -> Dict[str, float]:
    import jax     # deferred: keeps Monitor importable/usable (with explicit
                   # usage=) in processes that never touch the device runtime
    ru = resource.getrusage(resource.RUSAGE_SELF)
    return {
        "devices": float(jax.device_count()),
        "rss_gb": ru.ru_maxrss / 1e6,
        "time": time.time(),
    }


def usage_drift(a: Dict[str, float], b: Dict[str, float]) -> float:
    """Relative drift between two snapshots (0 = identical environment)."""
    d = 0.0
    for k in ("devices", "rss_gb"):
        va, vb = a.get(k, 0.0), b.get(k, 0.0)
        if max(va, vb) > 0:
            d = max(d, abs(va - vb) / max(va, vb))
    return d


class Monitor:
    """signature -> {plan_key: PlanStats} (+ measured sizes/shapes);
    JSON-persistent, with exponentially-decayed means and thread-safe
    batched recording (see module docstring)."""

    DRIFT_THRESHOLD = 0.5
    DECAY = 0.2           # newest-sample floor weight for all running means

    def __init__(self, path: Optional[str] = None,
                 decay: Optional[float] = None, shared: bool = False):
        self.path = path
        self.decay = self.DECAY if decay is None else float(decay)
        # shared=True: this monitor's file is co-owned by other processes
        # (the procpool workers).  save() then MERGES into the current file
        # instead of overwriting it, and ``reload_if_changed`` adopts other
        # writers' signatures.  Ownership is per-signature: a signature this
        # process has recorded itself (``_local_sigs``) is ours — our stats
        # win on save and a reload never clobbers them; everything else is
        # adopted from the file (last writer wins per signature).
        self.shared = bool(shared)
        self._local_sigs: set = set()
        self._file_version = None
        self.db: Dict[str, Dict[str, PlanStats]] = {}
        # sig -> {post-order position: [mean logical bytes, n]} — actual
        # intermediate sizes, fed back into estimate_sizes on re-plans
        self.sizes: Dict[str, Dict[int, list]] = {}
        # sig -> {post-order position: (dim, ...)} — last observed
        # dense-equivalent shapes (shapes are discrete: the newest
        # observation replaces, it is not averaged)
        self.shapes: Dict[str, Dict[int, Tuple[int, ...]]] = {}
        self.background_queue: list = []     # plans to re-explore when idle
        # guards db/sizes/shapes/background_queue AND the pending-record
        # queue; re-entrant so flush() may run inside a locked reader
        self._lock = threading.RLock()
        # raw observations awaiting application — record() only appends
        # here, flush() drains in arrival order (see module docstring)
        self._pending: list = []
        if path and os.path.exists(path):
            self.load(path)

    # -- recording ---------------------------------------------------------
    def record(self, sig: str, plan_key: str, seconds: float,
               cast_bytes: float = 0.0, extra: Optional[Dict] = None,
               usage: Optional[Dict[str, float]] = None,
               sizes: Optional[Dict[int, float]] = None,
               shapes: Optional[Dict[int, Tuple[int, ...]]] = None):
        """Enqueue one observation (cheap; safe from any thread).  The
        decayed-mean updates happen at the next ``flush()`` — which every
        reader performs — so behavior is indistinguishable from immediate
        application unless you bypass the accessors and read ``db`` raw."""
        rec = (sig, plan_key, seconds, cast_bytes, extra,
               usage or usage_snapshot(), sizes, shapes)
        with self._lock:
            self._pending.append(rec)

    def _apply(self, rec) -> None:
        """Apply one queued observation to the history dicts (lock held)."""
        sig, plan_key, seconds, cast_bytes, extra, usage, sizes, shapes = rec
        self._local_sigs.add(sig)
        entry = self.db.setdefault(sig, {}).setdefault(plan_key, PlanStats())
        entry.record(seconds, usage, cast_bytes, extra, decay=self.decay)
        if sizes:
            store = self.sizes.setdefault(sig, {})
            for pos, nbytes in sizes.items():
                m = store.setdefault(int(pos), [0.0, 0])
                a = _ema_alpha(m[1], self.decay)
                m[0] = (1.0 - a) * m[0] + a * float(nbytes)
                m[1] += 1
        if shapes:
            store_s = self.shapes.setdefault(sig, {})
            for pos, shp in shapes.items():
                store_s[int(pos)] = tuple(int(d) for d in shp)

    def flush(self) -> int:
        """Drain the pending-record queue into the history dicts, in arrival
        order.  Returns the number of records applied.  Readers call this
        implicitly; call it directly after hammering ``record`` from worker
        threads if you are about to inspect ``db`` by hand."""
        with self._lock:
            pending, self._pending = self._pending, []
            for rec in pending:
                self._apply(rec)
            return len(pending)

    def pending_records(self) -> int:
        """Queued-but-unapplied observation count (diagnostics/tests)."""
        with self._lock:
            return len(self._pending)

    def measured_sizes(self, sig: str) -> Dict[int, float]:
        """Post-order position -> decayed-mean measured logical output bytes
        (empty dict when the signature has never been executed)."""
        with self._lock:
            self.flush()
            return {pos: m[0] for pos, m in self.sizes.get(sig, {}).items()}

    def measured_shapes(self, sig: str) -> Dict[int, Tuple[int, ...]]:
        """Post-order position -> last observed dense-equivalent output
        shape (only positions whose container format carries a cheap shape —
        dense/coo/stream; columnar outputs are absent)."""
        with self._lock:
            self.flush()
            return dict(self.shapes.get(sig, {}))

    # -- production-phase matching ------------------------------------------
    def best(self, sig: str, usage: Optional[Dict[str, float]] = None):
        """Returns (plan_key, stats, drifted).  (None, None, False) if the
        signature has never been trained."""
        with self._lock:
            self.flush()
            plans = self.db.get(sig)
            if not plans:
                return None, None, False
            key, stats = min(plans.items(), key=lambda kv: kv[1].mean_seconds)
        drifted = False
        if usage is not None and stats.usage:
            drifted = usage_drift(usage, stats.usage) > self.DRIFT_THRESHOLD
        return key, stats, drifted

    def known_plans(self, sig: str) -> Dict[str, PlanStats]:
        """Snapshot of the signature's stats dict (flushed first).  The
        dict is a copy — a concurrent flush adding a new plan key must not
        blow up a caller mid-iteration — but the PlanStats values are the
        live objects."""
        with self._lock:
            self.flush()
            return dict(self.db.get(sig, {}))

    def queue_background(self, sig: str, plan_key: str):
        with self._lock:
            self.background_queue.append((sig, plan_key))

    def pop_background(self):
        """Atomically pop one queued (sig, plan_key), or None when the queue
        is empty — the race-free consumer for ``run_background_queue`` (an
        unguarded check-then-pop can raise IndexError under two drainers)."""
        with self._lock:
            return self.background_queue.pop() if self.background_queue \
                else None

    # -- persistence ---------------------------------------------------------
    def save(self, path: Optional[str] = None, merge: Optional[bool] = None):
        """Persist atomically.  With ``merge`` (default: ``self.shared``) the
        current file is read first and signatures this process never recorded
        are carried through — concurrent writers only ever lose a signature
        race to a LATER writer of that same signature, never to an unrelated
        save (last-writer-wins per signature, no dropped entries)."""
        path = path or self.path
        if not path:
            return
        if merge is None:
            merge = self.shared
        with self._lock:
            self.flush()
            blob = {
                "format": 3,
                "plans": {sig: {pk: asdict(st) for pk, st in plans.items()}
                          for sig, plans in self.db.items()},
                "sizes": {sig: {str(pos): list(m) for pos, m in store.items()}
                          for sig, store in self.sizes.items()},
                "shapes": {sig: {str(pos): list(s) for pos, s in store.items()}
                           for sig, store in self.shapes.items()},
            }
            if merge:
                try:
                    cur = load_json(path)
                except (OSError, ValueError):
                    cur = None
                if isinstance(cur, dict) and "plans" in cur:
                    for section in ("plans", "sizes", "shapes"):
                        for sig, entry in cur.get(section, {}).items():
                            if sig not in self._local_sigs:
                                blob[section][sig] = entry
            atomic_json_dump(path, blob)
            self._file_version = file_version(path)

    def reload_if_changed(self, path: Optional[str] = None) -> bool:
        """Cross-process read path: if another process has replaced the file
        since we last read/wrote it, adopt its entries for every signature
        this process has not recorded itself.  One ``stat`` when nothing
        changed.  Returns True when new state was adopted."""
        path = path or self.path
        if not path:
            return False
        with self._lock:
            blob, ver = load_json_versioned(path, self._file_version)
            if blob is None:
                return False
            self._file_version = ver
            self.flush()
            db, sizes, shapes = self._parse_blob(blob)
            changed = False
            for src, dst in ((db, self.db), (sizes, self.sizes),
                             (shapes, self.shapes)):
                for sig, entry in src.items():
                    if sig not in self._local_sigs:
                        dst[sig] = entry
                        changed = True
            return changed

    @staticmethod
    def _parse_blob(blob):
        if isinstance(blob, dict) and "plans" in blob:      # format >= 2
            plans, sizes = blob["plans"], blob.get("sizes", {})
            shapes = blob.get("shapes", {})                 # format >= 3
        else:                       # format 1: bare {sig: {plan_key: stats}}
            plans, sizes, shapes = blob, {}, {}
        db = {sig: {pk: PlanStats(**st) for pk, st in pls.items()}
              for sig, pls in plans.items()}
        sizes = {sig: {int(pos): [float(m[0]), int(m[1])]
                       for pos, m in store.items()}
                 for sig, store in sizes.items()}
        shapes = {sig: {int(pos): tuple(int(d) for d in s)
                        for pos, s in store.items()}
                  for sig, store in shapes.items()}
        return db, sizes, shapes

    def load(self, path: str):
        blob = load_json(path)
        with self._lock:
            self.db, self.sizes, self.shapes = self._parse_blob(blob)
            self._file_version = file_version(path)
