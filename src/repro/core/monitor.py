"""Performance monitor (paper §III-C / [17]): a history DB keyed by query
signature, holding per-plan statistics and the system-usage snapshot at
measurement time.  Production-phase matching compares the current usage
snapshot against the recorded one; large drift triggers retraining advice
(paper: "the optimizer may ... recommend that the user rerun the query under
the training phase under the current usage").
"""
from __future__ import annotations

import json
import os
import resource
import time
from dataclasses import dataclass, field, asdict
from typing import Dict, Optional

import jax

from repro.core.ioutil import atomic_json_dump


@dataclass
class PlanStats:
    mean_seconds: float = 0.0
    n: int = 0
    last_seconds: float = 0.0
    cast_bytes: float = 0.0
    usage: Dict[str, float] = field(default_factory=dict)
    extra: Dict[str, float] = field(default_factory=dict)

    def record(self, seconds: float, usage: Dict[str, float],
               cast_bytes: float = 0.0, extra: Optional[Dict] = None):
        self.mean_seconds = (self.mean_seconds * self.n + seconds) / (self.n + 1)
        # running mean, like mean_seconds — a single light run must not
        # overwrite the history (cast traffic can vary with catalog state)
        self.cast_bytes = (self.cast_bytes * self.n + cast_bytes) / (self.n + 1)
        self.n += 1
        self.last_seconds = seconds
        self.usage = dict(usage)
        if extra:
            self.extra.update(extra)


def usage_snapshot() -> Dict[str, float]:
    ru = resource.getrusage(resource.RUSAGE_SELF)
    return {
        "devices": float(jax.device_count()),
        "rss_gb": ru.ru_maxrss / 1e6,
        "time": time.time(),
    }


def usage_drift(a: Dict[str, float], b: Dict[str, float]) -> float:
    """Relative drift between two snapshots (0 = identical environment)."""
    d = 0.0
    for k in ("devices", "rss_gb"):
        va, vb = a.get(k, 0.0), b.get(k, 0.0)
        if max(va, vb) > 0:
            d = max(d, abs(va - vb) / max(va, vb))
    return d


class Monitor:
    """signature -> {plan_key: PlanStats}; JSON-persistent."""

    DRIFT_THRESHOLD = 0.5

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.db: Dict[str, Dict[str, PlanStats]] = {}
        self.background_queue: list = []     # plans to re-explore when idle
        if path and os.path.exists(path):
            self.load(path)

    # -- recording ---------------------------------------------------------
    def record(self, sig: str, plan_key: str, seconds: float,
               cast_bytes: float = 0.0, extra: Optional[Dict] = None,
               usage: Optional[Dict[str, float]] = None):
        entry = self.db.setdefault(sig, {}).setdefault(plan_key, PlanStats())
        entry.record(seconds, usage or usage_snapshot(), cast_bytes, extra)

    # -- production-phase matching ------------------------------------------
    def best(self, sig: str, usage: Optional[Dict[str, float]] = None):
        """Returns (plan_key, stats, drifted).  (None, None, False) if the
        signature has never been trained."""
        plans = self.db.get(sig)
        if not plans:
            return None, None, False
        key, stats = min(plans.items(), key=lambda kv: kv[1].mean_seconds)
        drifted = False
        if usage is not None and stats.usage:
            drifted = usage_drift(usage, stats.usage) > self.DRIFT_THRESHOLD
        return key, stats, drifted

    def known_plans(self, sig: str) -> Dict[str, PlanStats]:
        return self.db.get(sig, {})

    def queue_background(self, sig: str, plan_key: str):
        self.background_queue.append((sig, plan_key))

    # -- persistence ---------------------------------------------------------
    def save(self, path: Optional[str] = None):
        path = path or self.path
        if not path:
            return
        blob = {sig: {pk: asdict(st) for pk, st in plans.items()}
                for sig, plans in self.db.items()}
        atomic_json_dump(path, blob)

    def load(self, path: str):
        with open(path) as f:
            blob = json.load(f)
        self.db = {sig: {pk: PlanStats(**st) for pk, st in plans.items()}
                   for sig, plans in blob.items()}
