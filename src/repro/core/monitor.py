"""Performance monitor (paper §III-C / [17]): a history DB keyed by query
signature, holding per-plan statistics and the system-usage snapshot at
measurement time.  Production-phase matching compares the current usage
snapshot against the recorded one; large drift triggers retraining advice
(paper: "the optimizer may ... recommend that the user rerun the query under
the training phase under the current usage").

Beyond per-plan timings, the monitor stores *measured intermediate sizes*:
the executor reports each node's actual logical output bytes (keyed by
post-order position, which is stable across structurally-identical query
rebuilds — the same property plan keys rely on), and ``measured_sizes``
hands them back to the planner so data-dependent ops (select, join,
distinct) are sized from observation instead of shape rules.

Persistence: one JSON file (``Monitor(path)``), written atomically through
``ioutil.atomic_json_dump`` — the blob is dumped to a same-directory temp
file and moved into place with ``os.replace``, so a crash mid-save can never
truncate or corrupt the DB (the previous version survives intact).  Format
(version 2; version-1 files, a bare ``{sig: {plan_key: stats}}`` mapping,
still load)::

    {"format": 2,
     "plans": {sig: {plan_key: PlanStats-dict}},     # timings + usage
     "sizes": {sig: {post_order_pos: [mean_bytes, n_samples]}}}

Worked example (round-trips through one file)::

    >>> m = Monitor("/tmp/demo.monitor.json")
    >>> m.record("s1", "0:dense_array", 0.02, sizes={0: 4096.0})
    >>> m.save()                              # atomic write
    >>> m2 = Monitor("/tmp/demo.monitor.json")    # fresh process: warm start
    >>> m2.best("s1")[0]
    '0:dense_array'
    >>> m2.measured_sizes("s1")
    {0: 4096.0}
"""
from __future__ import annotations

import os
import resource
import time
from dataclasses import dataclass, field, asdict
from typing import Dict, Optional

import jax

from repro.core.ioutil import atomic_json_dump, load_json


@dataclass
class PlanStats:
    mean_seconds: float = 0.0
    n: int = 0
    last_seconds: float = 0.0
    cast_bytes: float = 0.0
    usage: Dict[str, float] = field(default_factory=dict)
    extra: Dict[str, float] = field(default_factory=dict)

    def record(self, seconds: float, usage: Dict[str, float],
               cast_bytes: float = 0.0, extra: Optional[Dict] = None):
        self.mean_seconds = (self.mean_seconds * self.n + seconds) / (self.n + 1)
        # running mean, like mean_seconds — a single light run must not
        # overwrite the history (cast traffic can vary with catalog state)
        self.cast_bytes = (self.cast_bytes * self.n + cast_bytes) / (self.n + 1)
        self.n += 1
        self.last_seconds = seconds
        self.usage = dict(usage)
        if extra:
            self.extra.update(extra)


def usage_snapshot() -> Dict[str, float]:
    ru = resource.getrusage(resource.RUSAGE_SELF)
    return {
        "devices": float(jax.device_count()),
        "rss_gb": ru.ru_maxrss / 1e6,
        "time": time.time(),
    }


def usage_drift(a: Dict[str, float], b: Dict[str, float]) -> float:
    """Relative drift between two snapshots (0 = identical environment)."""
    d = 0.0
    for k in ("devices", "rss_gb"):
        va, vb = a.get(k, 0.0), b.get(k, 0.0)
        if max(va, vb) > 0:
            d = max(d, abs(va - vb) / max(va, vb))
    return d


class Monitor:
    """signature -> {plan_key: PlanStats} (+ measured sizes); JSON-persistent."""

    DRIFT_THRESHOLD = 0.5

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.db: Dict[str, Dict[str, PlanStats]] = {}
        # sig -> {post-order position: [mean logical bytes, n]} — actual
        # intermediate sizes, fed back into estimate_sizes on re-plans
        self.sizes: Dict[str, Dict[int, list]] = {}
        self.background_queue: list = []     # plans to re-explore when idle
        if path and os.path.exists(path):
            self.load(path)

    # -- recording ---------------------------------------------------------
    def record(self, sig: str, plan_key: str, seconds: float,
               cast_bytes: float = 0.0, extra: Optional[Dict] = None,
               usage: Optional[Dict[str, float]] = None,
               sizes: Optional[Dict[int, float]] = None):
        entry = self.db.setdefault(sig, {}).setdefault(plan_key, PlanStats())
        entry.record(seconds, usage or usage_snapshot(), cast_bytes, extra)
        if sizes:
            store = self.sizes.setdefault(sig, {})
            for pos, nbytes in sizes.items():
                m = store.setdefault(int(pos), [0.0, 0])
                m[0] = (m[0] * m[1] + float(nbytes)) / (m[1] + 1)
                m[1] += 1

    def measured_sizes(self, sig: str) -> Dict[int, float]:
        """Post-order position -> mean measured logical output bytes (empty
        dict when the signature has never been executed)."""
        return {pos: m[0] for pos, m in self.sizes.get(sig, {}).items()}

    # -- production-phase matching ------------------------------------------
    def best(self, sig: str, usage: Optional[Dict[str, float]] = None):
        """Returns (plan_key, stats, drifted).  (None, None, False) if the
        signature has never been trained."""
        plans = self.db.get(sig)
        if not plans:
            return None, None, False
        key, stats = min(plans.items(), key=lambda kv: kv[1].mean_seconds)
        drifted = False
        if usage is not None and stats.usage:
            drifted = usage_drift(usage, stats.usage) > self.DRIFT_THRESHOLD
        return key, stats, drifted

    def known_plans(self, sig: str) -> Dict[str, PlanStats]:
        return self.db.get(sig, {})

    def queue_background(self, sig: str, plan_key: str):
        self.background_queue.append((sig, plan_key))

    # -- persistence ---------------------------------------------------------
    def save(self, path: Optional[str] = None):
        path = path or self.path
        if not path:
            return
        blob = {
            "format": 2,
            "plans": {sig: {pk: asdict(st) for pk, st in plans.items()}
                      for sig, plans in self.db.items()},
            "sizes": {sig: {str(pos): list(m) for pos, m in store.items()}
                      for sig, store in self.sizes.items()},
        }
        atomic_json_dump(path, blob)

    def load(self, path: str):
        blob = load_json(path)
        if isinstance(blob, dict) and "plans" in blob:      # format 2
            plans, sizes = blob["plans"], blob.get("sizes", {})
        else:                       # format 1: bare {sig: {plan_key: stats}}
            plans, sizes = blob, {}
        self.db = {sig: {pk: PlanStats(**st) for pk, st in pls.items()}
                   for sig, pls in plans.items()}
        self.sizes = {sig: {int(pos): [float(m[0]), int(m[1])]
                            for pos, m in store.items()}
                      for sig, store in sizes.items()}
