"""Islands — the user-facing scope abstraction (paper §III-B).

Each island = (data model, operator set, member engines).  Users build
queries by calling island operators; the island tag on each node is its
*scope*, which tells the planner which shims (engine lowerings) are legal.
Degenerate islands expose a single engine's full op set (semantic
completeness at the price of location transparency).
"""
from __future__ import annotations

from typing import Dict, Sequence, Set, Union

from repro.core.engines import ENGINES
from repro.core.ops import PolyOp, Ref


def _as_input(x):
    if isinstance(x, (PolyOp, Ref)):
        return x
    if isinstance(x, str):
        return Ref(x)
    raise TypeError(f"query inputs must be PolyOp/Ref/str, got {type(x)}")


class Island:
    def __init__(self, name: str, ops: Dict[str, Sequence[str]]):
        self.name = name
        self.ops = {op: tuple(engines) for op, engines in ops.items()}

    def candidates(self, op: str) -> Sequence[str]:
        return self.ops[op]

    def _build(self, op: str, *inputs, **attrs) -> PolyOp:
        if op not in self.ops:
            raise ValueError(f"island {self.name!r} has no operator {op!r}")
        return PolyOp(op=op, island=self.name,
                      inputs=tuple(_as_input(i) for i in inputs), attrs=attrs)

    def __getattr__(self, op):
        if op.startswith("_"):
            raise AttributeError(op)
        if op not in self.__dict__.get("ops", {}):
            raise AttributeError(f"island {self.name!r}: no operator {op!r}")
        return lambda *inputs, **attrs: self._build(op, *inputs, **attrs)


# ---------------------------------------------------------------------------
# standard islands (engine lists are ordered by *a-priori* preference; the
# monitor's measured history overrides this ordering in production phase)
# ---------------------------------------------------------------------------

array = Island("array", {
    "matmul": ["dense_array", "columnar"],
    "haar": ["dense_array", "columnar", "stream"],
    "count": ["dense_array", "columnar", "kv_sparse"],
    "distinct": ["dense_array", "columnar", "kv_sparse"],
    "select": ["dense_array", "columnar"],
    "bin_hist": ["dense_array", "columnar"],
    "tfidf": ["dense_array", "columnar", "kv_sparse"],
    "knn": ["dense_array", "columnar", "kv_sparse"],
    "add": ["dense_array"],
    "scale": ["dense_array"],
    "transpose": ["dense_array"],
})

relational = Island("relational", {
    "select": ["columnar"],
    "project": ["columnar"],
    "count": ["columnar", "dense_array", "kv_sparse"],
    "distinct": ["columnar", "dense_array", "kv_sparse"],
    "groupby_sum": ["columnar"],
    "join": ["columnar"],
    "matmul": ["columnar", "dense_array"],
    "haar": ["columnar", "dense_array"],
    "bin_hist": ["columnar", "dense_array"],
    "tfidf": ["columnar", "dense_array", "kv_sparse"],
    "knn": ["columnar", "dense_array", "kv_sparse"],
})

text = Island("text", {
    "tfidf": ["kv_sparse"],
    "spmm": ["kv_sparse"],
    "knn": ["kv_sparse"],
    "count": ["kv_sparse"],
    "distinct": ["kv_sparse"],
    "degree": ["kv_sparse"],
})

stream = Island("stream", {
    "window_agg": ["stream"],
    "haar": ["stream"],
    "to_array": ["stream"],
    "ingest": ["stream"],
})


def degenerate(engine_name: str) -> Island:
    """Full power of one engine, zero location transparency (paper §III-B)."""
    eng = ENGINES[engine_name]
    return Island(f"degenerate:{engine_name}",
                  {op: [engine_name] for op in eng.ops})


ISLANDS: Dict[str, Island] = {
    "array": array, "relational": relational, "text": text, "stream": stream,
}
for _e in ENGINES:
    ISLANDS[f"degenerate:{_e}"] = degenerate(_e)


def island_of(node: PolyOp) -> Island:
    return ISLANDS[node.island]
