"""Islands — the user-facing scope abstraction (paper §III-B).

Each island = (data model, operator set, member engines).  Users build
queries by calling island operators; the island tag on each node is its
*scope*, which tells the planner which shims (engine lowerings) are legal.
Degenerate islands expose a single engine's full op set (semantic
completeness at the price of location transparency).

Cross-island queries are expressed with ``scope(island, subtree)`` (paper
§III: the SCOPE marker says which island's semantics govern a subtree, the
CAST moves data across the boundary): the returned boundary node delivers
``subtree``'s result in ``island``'s data model.  The planner prices the
boundary cast with the calibrated per-pair bandwidths and the executor runs
it through the migrator — see ``ops.SCOPE_OP``.
"""
from __future__ import annotations

from typing import Dict, Sequence, Set, Tuple, Union

from repro.core.engines import ENGINES
from repro.core.ops import SCOPE_OP, PolyOp, Ref

# the data model a query scoped to an island is delivered in (paper §III-B:
# each island presents one data model regardless of which member engine ran
# the fragment).  Degenerate islands resolve through ``island_kind``.
ISLAND_KIND = {"array": "dense", "relational": "columnar", "text": "coo",
               "stream": "stream"}


def _as_input(x):
    if isinstance(x, (PolyOp, Ref)):
        return x
    if isinstance(x, str):
        return Ref(x)
    raise TypeError(f"query inputs must be PolyOp/Ref/str, got {type(x)}")


class Island:
    def __init__(self, name: str, ops: Dict[str, Sequence[str]]):
        self.name = name
        self.ops = {op: tuple(engines) for op, engines in ops.items()}

    def candidates(self, op: str) -> Sequence[str]:
        return self.ops[op]

    def _no_such_op(self, op: str) -> str:
        avail = ", ".join(sorted(self.__dict__.get("ops", {})))
        return (f"island {self.name!r} has no operator {op!r}; "
                f"available operators: {avail}")

    def _build(self, op: str, *inputs, **attrs) -> PolyOp:
        if op not in self.ops:
            raise ValueError(self._no_such_op(op))
        return PolyOp(op=op, island=self.name,
                      inputs=tuple(_as_input(i) for i in inputs), attrs=attrs)

    def scope(self, subtree) -> PolyOp:
        """``scope(self.name, subtree)`` — deliver a (possibly foreign-island)
        subtree in this island's data model."""
        return scope(self, subtree)

    def __getattr__(self, op):
        if op.startswith("_"):
            raise AttributeError(op)
        if op not in self.__dict__.get("ops", {}):
            # list the vocabulary: the error is how users discover what an
            # island can do, so hiding the op set behind a bare name is cruel
            raise AttributeError(self._no_such_op(op))
        return lambda *inputs, **attrs: self._build(op, *inputs, **attrs)


# ---------------------------------------------------------------------------
# standard islands (engine lists are ordered by *a-priori* preference; the
# monitor's measured history overrides this ordering in production phase)
# ---------------------------------------------------------------------------

array = Island("array", {
    "matmul": ["dense_array", "columnar"],
    "haar": ["dense_array", "columnar", "stream"],
    "count": ["dense_array", "columnar", "kv_sparse"],
    "distinct": ["dense_array", "columnar", "kv_sparse"],
    "select": ["dense_array", "columnar"],
    "bin_hist": ["dense_array", "columnar"],
    "tfidf": ["dense_array", "columnar", "kv_sparse"],
    "knn": ["dense_array", "columnar", "kv_sparse"],
    "add": ["dense_array"],
    "scale": ["dense_array"],
    "transpose": ["dense_array"],
    "concat": ["dense_array"],
})

relational = Island("relational", {
    "select": ["columnar"],
    "project": ["columnar"],
    "count": ["columnar", "dense_array", "kv_sparse"],
    "distinct": ["columnar", "dense_array", "kv_sparse"],
    "groupby_sum": ["columnar"],
    "sort": ["columnar"],
    "join": ["columnar"],
    "matmul": ["columnar", "dense_array"],
    "haar": ["columnar", "dense_array"],
    "bin_hist": ["columnar", "dense_array"],
    "tfidf": ["columnar", "dense_array", "kv_sparse"],
    "knn": ["columnar", "dense_array", "kv_sparse"],
})

text = Island("text", {
    "tfidf": ["kv_sparse"],
    "spmm": ["kv_sparse"],
    "knn": ["kv_sparse"],
    "count": ["kv_sparse"],
    "distinct": ["kv_sparse"],
    "degree": ["kv_sparse"],
})

stream = Island("stream", {
    "window_agg": ["stream"],
    "haar": ["stream"],
    "to_array": ["stream"],
    "ingest": ["stream"],
})


def degenerate(engine_name: str) -> Island:
    """Full power of one engine, zero location transparency (paper §III-B)."""
    eng = ENGINES[engine_name]
    return Island(f"degenerate:{engine_name}",
                  {op: [engine_name] for op in eng.ops})


ISLANDS: Dict[str, Island] = {
    "array": array, "relational": relational, "text": text, "stream": stream,
}
for _e in ENGINES:
    ISLANDS[f"degenerate:{_e}"] = degenerate(_e)


def island_of(node: PolyOp) -> Island:
    return ISLANDS[node.island]


# ---------------------------------------------------------------------------
# island boundaries (paper §III: SCOPE marks the governing island, CAST moves
# the data) — the cross-island half of the IR
# ---------------------------------------------------------------------------

def island_kind(island_name: str) -> str:
    """The data model an island delivers results in (degenerate islands
    deliver their engine's native kind)."""
    if island_name in ISLAND_KIND:
        return ISLAND_KIND[island_name]
    if island_name.startswith("degenerate:"):
        return ENGINES[island_name.split(":", 1)[1]].kind
    raise ValueError(f"unknown island {island_name!r}; available: "
                     f"{', '.join(sorted(ISLANDS))}")


def scope_candidates(island_name: str) -> Tuple[str, ...]:
    """Engines a boundary node may materialize on: the target island's
    data-model-native members (a degenerate island's single engine).  The
    planner restricts scope nodes to these, so the DP's cast edge into the
    boundary IS the inter-island cast."""
    if island_name.startswith("degenerate:"):
        return (island_name.split(":", 1)[1],)
    kind = island_kind(island_name)
    return tuple(e.name for e in ENGINES.values() if e.kind == kind)


def scope(island: Union[Island, str], subtree) -> PolyOp:
    """Explicit island boundary: deliver ``subtree``'s result in ``island``'s
    data model (paper §III's SCOPE/CAST seam).

    The returned node is the identity on logical content; the planner prices
    the boundary cast from the subtree's engine kind to the island's model
    (multi-hop routed over the calibrated bandwidths, charged per hop) and
    the executor performs it through the migrator.  ``island`` may be an
    ``Island`` or its name (``"array"``, ``"degenerate:dense_array"``, ...).
    """
    name = island.name if isinstance(island, Island) else str(island)
    if name not in ISLANDS:
        raise ValueError(f"unknown island {name!r}; available: "
                         f"{', '.join(sorted(ISLANDS))}")
    return PolyOp(op=SCOPE_OP, island=name, inputs=(_as_input(subtree),))
