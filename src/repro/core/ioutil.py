"""Small shared I/O helpers for the middleware's persistent state."""
from __future__ import annotations

import json
import os
import tempfile


def load_json(path: str):
    """Read a JSON blob written by ``atomic_json_dump`` (or by hand)."""
    with open(path) as f:
        return json.load(f)


def atomic_json_dump(path: str, blob) -> None:
    """Write JSON via a same-directory temp file + ``os.replace`` so a crash
    mid-dump can never truncate the target (monitor DB, calibration file)."""
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(blob, f, indent=1)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
