"""Small shared I/O helpers for the middleware's persistent state.

``atomic_json_dump`` (temp file + ``os.replace``) is the cross-process
protocol: a reader either sees the old blob or the new one, never a torn
write.  ``load_json_versioned`` / ``file_version`` add the reload-on-change
read path the multi-process serving stack uses — each worker remembers the
``(mtime_ns, size, ino)`` stamp of the blob it last loaded and re-reads only
when the stamp moves, so polling shared state costs one ``stat`` per check.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Optional, Tuple

# (mtime_ns, size, inode) — inode included because os.replace swaps the file
# in, so every atomic dump lands on a fresh inode even if mtime granularity
# or an equal size would otherwise hide the change
FileVersion = Tuple[int, int, int]


def load_json(path: str):
    """Read a JSON blob written by ``atomic_json_dump`` (or by hand)."""
    with open(path) as f:
        return json.load(f)


def file_version(path: str) -> Optional[FileVersion]:
    """Change stamp for ``path`` (None when the file does not exist)."""
    try:
        st = os.stat(path)
    except OSError:
        return None
    return (st.st_mtime_ns, st.st_size, st.st_ino)


def load_json_versioned(path: str, seen: Optional[FileVersion]):
    """Reload-on-change read: returns ``(blob, version)`` when the file's
    stamp differs from ``seen``, else ``(None, seen)``.

    Readers race with ``os.replace`` writers: the file can be swapped between
    the ``stat`` and the ``open``.  The open then reads the NEWER complete
    blob (replace is atomic — there is no torn state), so we re-stat after a
    successful read and keep the post-read stamp; at worst the next check
    reloads once more.  A reader can also lose the race terminally (file
    momentarily gone under exotic filesystems) — surfaced as "no change".
    """
    ver = file_version(path)
    if ver is None or ver == seen:
        return None, seen
    try:
        blob = load_json(path)
    except (OSError, json.JSONDecodeError):
        # swapped mid-read or not yet visible — report no change; the next
        # poll sees the settled file
        return None, seen
    after = file_version(path)
    return blob, (after if after is not None else ver)


def atomic_json_dump(path: str, blob) -> None:
    """Write JSON via a same-directory temp file + ``os.replace`` so a crash
    mid-dump can never truncate the target (monitor DB, calibration file)."""
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(blob, f, indent=1)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
