"""Polystore data containers — one native format per engine family.

These mirror the paper's data models: SciDB arrays -> DenseTensor, relational
rows -> ColumnarTable, Accumulo/D4M associative arrays -> COOMatrix, S-Store
windows -> StreamBuffer.  ``nbytes``/``describe`` feed the cast cost model.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np


@dataclass
class DenseTensor:
    """Array-engine native: a dense (possibly padded) tensor.

    ``valid_count`` is container metadata (SciDB-style): count() is O(1) here
    but a full scan in the columnar engine — the Fig.1 crossover.
    """
    data: jnp.ndarray
    valid_count: int = -1
    fill: float = 0.0

    def __post_init__(self):
        if self.valid_count < 0:
            self.valid_count = int(np.prod(self.data.shape))

    @property
    def nbytes(self) -> int:
        return self.data.size * self.data.dtype.itemsize

    kind = "dense"


@dataclass
class ColumnarTable:
    """Relational-engine native: named columns + validity mask (lazy deletes)."""
    columns: Dict[str, jnp.ndarray]
    valid: jnp.ndarray = None    # (N,) bool

    def __post_init__(self):
        n = self.nrows
        if self.valid is None:
            self.valid = jnp.ones((n,), bool)

    @property
    def nrows(self) -> int:
        return next(iter(self.columns.values())).shape[0]

    @property
    def nbytes(self) -> int:
        return sum(c.size * c.dtype.itemsize for c in self.columns.values())

    kind = "columnar"


@dataclass
class COOMatrix:
    """KV/associative-array native (D4M style): (row, col, val) triples."""
    rows: jnp.ndarray
    cols: jnp.ndarray
    vals: jnp.ndarray
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return self.rows.shape[0]

    @property
    def nbytes(self) -> int:
        return (self.rows.size * self.rows.dtype.itemsize
                + self.cols.size * self.cols.dtype.itemsize
                + self.vals.size * self.vals.dtype.itemsize)

    kind = "coo"


@dataclass
class StreamBuffer:
    """Stream-engine native: window-major ring buffer of samples."""
    data: jnp.ndarray            # (n_windows, window_len, ...) newest last
    t0: int = 0                  # timestamp of the first window

    @property
    def nbytes(self) -> int:
        return self.data.size * self.data.dtype.itemsize

    kind = "stream"


FORMATS = {"dense": DenseTensor, "columnar": ColumnarTable, "coo": COOMatrix,
           "stream": StreamBuffer}
