"""Polystore data containers — one native format per engine family.

These mirror the paper's data models: SciDB arrays -> DenseTensor, relational
rows -> ColumnarTable, Accumulo/D4M associative arrays -> COOMatrix, S-Store
windows -> StreamBuffer.  ``nbytes``/``describe`` feed the cast cost model.

Triple-format containers (ColumnarTable, COOMatrix) accept **either** jnp or
numpy arrays for their columns/triples.  Eagerly-computed intermediates —
sort-merge join output, dense->triple casts — stay numpy until a dense
consumer actually needs the device: wrapping them in ``jnp.asarray`` at
creation would serialize every host-pool worker on the XLA transfer lock for
data the next op may never touch on-device (see ``device_ready`` for the
explicit homing used on long-lived catalog objects).
Row-range sharding (the multi-process scatter–gather substrate) also lives
here: ``shard_rows`` splits any container into N contiguous row-range parts,
and the three merge primitives — ``concat_shards`` (row-wise ops),
``sum_shards`` (decomposable aggregates: count, groupby_sum), and
``kmerge_shards`` (k-way ordered merge of per-shard sorted tables) —
reassemble per-shard results.  The merge helpers are deliberately
numpy-only: they run in the MASTER process, which must never initialize the
XLA backend (workers own the device; see ``core/procpool.py``).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


@dataclass
class DenseTensor:
    """Array-engine native: a dense (possibly padded) tensor.

    ``valid_count`` is container metadata (SciDB-style): count() is O(1) here
    but a full scan in the columnar engine — the Fig.1 crossover.
    """
    data: jnp.ndarray
    valid_count: int = -1
    fill: float = 0.0

    def __post_init__(self):
        if self.valid_count < 0:
            self.valid_count = int(np.prod(self.data.shape))

    @property
    def nbytes(self) -> int:
        return self.data.size * self.data.dtype.itemsize

    kind = "dense"


@dataclass
class ColumnarTable:
    """Relational-engine native: named columns + validity mask (lazy deletes)."""
    columns: Dict[str, jnp.ndarray]
    valid: jnp.ndarray = None    # (N,) bool

    def __post_init__(self):
        n = self.nrows
        if self.valid is None:
            # follow the columns' residency: numpy columns get a numpy mask
            # (building a device mask for a host-side intermediate would
            # trigger exactly the transfer this layout avoids)
            first = next(iter(self.columns.values()))
            ones = np.ones if isinstance(first, np.ndarray) else jnp.ones
            self.valid = ones((n,), bool)

    @property
    def nrows(self) -> int:
        return next(iter(self.columns.values())).shape[0]

    @property
    def nbytes(self) -> int:
        return sum(c.size * c.dtype.itemsize for c in self.columns.values())

    kind = "columnar"


@dataclass
class COOMatrix:
    """KV/associative-array native (D4M style): (row, col, val) triples."""
    rows: jnp.ndarray
    cols: jnp.ndarray
    vals: jnp.ndarray
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return self.rows.shape[0]

    @property
    def nbytes(self) -> int:
        return (self.rows.size * self.rows.dtype.itemsize
                + self.cols.size * self.cols.dtype.itemsize
                + self.vals.size * self.vals.dtype.itemsize)

    kind = "coo"


@dataclass
class StreamBuffer:
    """Stream-engine native: window-major ring buffer of samples."""
    data: jnp.ndarray            # (n_windows, window_len, ...) newest last
    t0: int = 0                  # timestamp of the first window

    @property
    def nbytes(self) -> int:
        return self.data.size * self.data.dtype.itemsize

    kind = "stream"


FORMATS = {"dense": DenseTensor, "columnar": ColumnarTable, "coo": COOMatrix,
           "stream": StreamBuffer}


def device_ready(obj):
    """Home a container's array leaves on the device (``jnp.asarray``).

    For LONG-LIVED objects — catalog registrations — that will be consumed
    by device ops many times: paying the transfer once at registration beats
    re-transferring on every query.  Eager intermediates deliberately skip
    this (see module docstring)."""
    if isinstance(obj, ColumnarTable):
        return ColumnarTable({c: jnp.asarray(v)
                              for c, v in obj.columns.items()},
                             valid=jnp.asarray(obj.valid))
    if isinstance(obj, COOMatrix):
        return COOMatrix(jnp.asarray(obj.rows), jnp.asarray(obj.cols),
                         jnp.asarray(obj.vals), obj.shape)
    if isinstance(obj, DenseTensor):
        return DenseTensor(jnp.asarray(obj.data),
                           valid_count=obj.valid_count, fill=obj.fill)
    if isinstance(obj, StreamBuffer):
        return StreamBuffer(jnp.asarray(obj.data), obj.t0)
    return obj


def host_copy(obj):
    """Numpy-leafed clone of a container — what the procpool master pickles
    over the worker pipe (device arrays must not cross a process boundary,
    and the master side stays off the XLA runtime entirely)."""
    if isinstance(obj, ColumnarTable):
        return ColumnarTable({c: np.asarray(v) for c, v in obj.columns.items()},
                             valid=np.asarray(obj.valid))
    if isinstance(obj, COOMatrix):
        return COOMatrix(np.asarray(obj.rows), np.asarray(obj.cols),
                         np.asarray(obj.vals), tuple(obj.shape))
    if isinstance(obj, DenseTensor):
        return DenseTensor(np.asarray(obj.data),
                           valid_count=obj.valid_count, fill=obj.fill)
    if isinstance(obj, StreamBuffer):
        return StreamBuffer(np.asarray(obj.data), obj.t0)
    return obj


# -- row-range sharding -------------------------------------------------------

def shard_bounds(nrows: int, n_shards: int) -> List[Tuple[int, int]]:
    """N contiguous ``[lo, hi)`` row ranges covering ``nrows`` (remainder
    spread over the leading shards, every shard non-degenerate when
    ``nrows >= n_shards``)."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    base, rem = divmod(nrows, n_shards)
    bounds, lo = [], 0
    for i in range(n_shards):
        hi = lo + base + (1 if i < rem else 0)
        bounds.append((lo, hi))
        lo = hi
    return bounds


def shard_rows(obj, n_shards: int) -> list:
    """Split a container into ``n_shards`` contiguous row-range parts.

    Dense tensors and columnar tables shard on the leading axis, COO on the
    row coordinate (rows re-based to each shard's origin), streams on the
    window axis.  Concatenating the parts back (``concat_shards``) is the
    identity.
    """
    if isinstance(obj, DenseTensor):
        a = np.asarray(obj.data)
        if a.ndim < 1:
            raise ValueError("cannot row-shard a 0-d tensor")
        if obj.valid_count not in (-1, a.size):
            # a padded tensor's valid elements are not row-attributable, so
            # per-shard counts could not reassemble to the true total
            raise ValueError("cannot row-shard a padded DenseTensor")
        return [DenseTensor(a[lo:hi], fill=obj.fill)
                for lo, hi in shard_bounds(a.shape[0], n_shards)]
    if isinstance(obj, ColumnarTable):
        cols = {c: np.asarray(v) for c, v in obj.columns.items()}
        valid = np.asarray(obj.valid)
        return [ColumnarTable({c: v[lo:hi] for c, v in cols.items()},
                              valid=valid[lo:hi])
                for lo, hi in shard_bounds(obj.nrows, n_shards)]
    if isinstance(obj, COOMatrix):
        rows = np.asarray(obj.rows)
        cols = np.asarray(obj.cols)
        vals = np.asarray(obj.vals)
        parts = []
        for lo, hi in shard_bounds(obj.shape[0], n_shards):
            m = (rows >= lo) & (rows < hi)
            parts.append(COOMatrix((rows[m] - lo).astype(rows.dtype),
                                   cols[m], vals[m],
                                   (hi - lo, obj.shape[1])))
        return parts
    if isinstance(obj, StreamBuffer):
        a = np.asarray(obj.data)
        return [StreamBuffer(a[lo:hi], t0=obj.t0 + lo)
                for lo, hi in shard_bounds(a.shape[0], n_shards)]
    raise TypeError(f"cannot shard {type(obj).__name__}")


# -- shard merges -------------------------------------------------------------

def concat_shards(parts: Sequence):
    """Reassemble row-wise per-shard results: row concatenation in shard
    order (the inverse of ``shard_rows`` for every row-preserving op)."""
    if not parts:
        raise ValueError("no shard results to merge")
    first = parts[0]
    if isinstance(first, DenseTensor):
        data = np.concatenate([np.asarray(p.data) for p in parts], axis=0)
        vc = sum(p.valid_count for p in parts)
        return DenseTensor(data, valid_count=vc, fill=first.fill)
    if isinstance(first, ColumnarTable):
        return ColumnarTable(
            {c: np.concatenate([np.asarray(p.columns[c]) for p in parts])
             for c in first.columns},
            valid=np.concatenate([np.asarray(p.valid) for p in parts]))
    if isinstance(first, COOMatrix):
        rows, off = [], 0
        for p in parts:
            rows.append(np.asarray(p.rows) + off)
            off += p.shape[0]
        return COOMatrix(np.concatenate(rows).astype(np.asarray(first.rows).dtype),
                         np.concatenate([np.asarray(p.cols) for p in parts]),
                         np.concatenate([np.asarray(p.vals) for p in parts]),
                         (off, max(p.shape[1] for p in parts)))
    if isinstance(first, StreamBuffer):
        return StreamBuffer(
            np.concatenate([np.asarray(p.data) for p in parts], axis=0),
            t0=first.t0)
    raise TypeError(f"cannot concat-merge {type(first).__name__}")


def sum_shards(parts: Sequence):
    """Merge decomposable aggregates: element-wise sum over aligned shard
    results.  Covers ``count`` (0-d DenseTensor per shard -> grand total) and
    ``groupby_sum`` (every shard emits the full aligned key range
    ``0..num_groups``, so group partial sums add position-wise)."""
    if not parts:
        raise ValueError("no shard results to merge")
    first = parts[0]
    if isinstance(first, DenseTensor):
        data = np.asarray(parts[0].data)
        for p in parts[1:]:
            data = data + np.asarray(p.data)
        return DenseTensor(data, valid_count=first.valid_count,
                           fill=first.fill)
    if isinstance(first, ColumnarTable):
        key = np.asarray(first.columns["key"])
        for p in parts[1:]:
            if not np.array_equal(np.asarray(p.columns["key"]), key):
                raise ValueError("sum-merge requires aligned group keys")
        out = {"key": key}
        for c in first.columns:
            if c == "key":
                continue
            acc = np.asarray(first.columns[c])
            for p in parts[1:]:
                acc = acc + np.asarray(p.columns[c])
            out[c] = acc
        return ColumnarTable(out)
    raise TypeError(f"cannot sum-merge {type(first).__name__}")


def kmerge_shards(parts: Sequence, by: str):
    """K-way ordered merge of per-shard SORTED columnar tables on column
    ``by`` (classic heap merge: O(total rows * log k)).  Invalid rows are
    compacted away first; ties preserve shard order (stable)."""
    if not parts:
        raise ValueError("no shard results to merge")
    compact = []
    for p in parts:
        valid = np.asarray(p.valid)
        cols = {c: np.asarray(v) for c, v in p.columns.items()}
        if not valid.all():
            cols = {c: v[valid] for c, v in cols.items()}
        compact.append(cols)
    names = list(compact[0])
    offsets = np.cumsum([0] + [c[names[0]].shape[0] for c in compact])
    def stream(cols, si):
        # bound per shard (a bare genexp in the comprehension would
        # late-bind si/cols to the last shard)
        key = cols[by]
        return ((key[i], si, offsets[si] + i) for i in range(key.shape[0]))

    streams = [stream(cols, si) for si, cols in enumerate(compact)]
    order = np.fromiter((flat for _, _, flat in heapq.merge(*streams)),
                        dtype=np.int64)
    merged = {c: np.concatenate([cols[c] for cols in compact])[order]
              for c in names}
    return ColumnarTable(merged)
