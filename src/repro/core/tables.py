"""Polystore data containers — one native format per engine family.

These mirror the paper's data models: SciDB arrays -> DenseTensor, relational
rows -> ColumnarTable, Accumulo/D4M associative arrays -> COOMatrix, S-Store
windows -> StreamBuffer.  ``nbytes``/``describe`` feed the cast cost model.

Triple-format containers (ColumnarTable, COOMatrix) accept **either** jnp or
numpy arrays for their columns/triples.  Eagerly-computed intermediates —
sort-merge join output, dense->triple casts — stay numpy until a dense
consumer actually needs the device: wrapping them in ``jnp.asarray`` at
creation would serialize every host-pool worker on the XLA transfer lock for
data the next op may never touch on-device (see ``device_ready`` for the
explicit homing used on long-lived catalog objects).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np


@dataclass
class DenseTensor:
    """Array-engine native: a dense (possibly padded) tensor.

    ``valid_count`` is container metadata (SciDB-style): count() is O(1) here
    but a full scan in the columnar engine — the Fig.1 crossover.
    """
    data: jnp.ndarray
    valid_count: int = -1
    fill: float = 0.0

    def __post_init__(self):
        if self.valid_count < 0:
            self.valid_count = int(np.prod(self.data.shape))

    @property
    def nbytes(self) -> int:
        return self.data.size * self.data.dtype.itemsize

    kind = "dense"


@dataclass
class ColumnarTable:
    """Relational-engine native: named columns + validity mask (lazy deletes)."""
    columns: Dict[str, jnp.ndarray]
    valid: jnp.ndarray = None    # (N,) bool

    def __post_init__(self):
        n = self.nrows
        if self.valid is None:
            # follow the columns' residency: numpy columns get a numpy mask
            # (building a device mask for a host-side intermediate would
            # trigger exactly the transfer this layout avoids)
            first = next(iter(self.columns.values()))
            ones = np.ones if isinstance(first, np.ndarray) else jnp.ones
            self.valid = ones((n,), bool)

    @property
    def nrows(self) -> int:
        return next(iter(self.columns.values())).shape[0]

    @property
    def nbytes(self) -> int:
        return sum(c.size * c.dtype.itemsize for c in self.columns.values())

    kind = "columnar"


@dataclass
class COOMatrix:
    """KV/associative-array native (D4M style): (row, col, val) triples."""
    rows: jnp.ndarray
    cols: jnp.ndarray
    vals: jnp.ndarray
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return self.rows.shape[0]

    @property
    def nbytes(self) -> int:
        return (self.rows.size * self.rows.dtype.itemsize
                + self.cols.size * self.cols.dtype.itemsize
                + self.vals.size * self.vals.dtype.itemsize)

    kind = "coo"


@dataclass
class StreamBuffer:
    """Stream-engine native: window-major ring buffer of samples."""
    data: jnp.ndarray            # (n_windows, window_len, ...) newest last
    t0: int = 0                  # timestamp of the first window

    @property
    def nbytes(self) -> int:
        return self.data.size * self.data.dtype.itemsize

    kind = "stream"


FORMATS = {"dense": DenseTensor, "columnar": ColumnarTable, "coo": COOMatrix,
           "stream": StreamBuffer}


def device_ready(obj):
    """Home a container's array leaves on the device (``jnp.asarray``).

    For LONG-LIVED objects — catalog registrations — that will be consumed
    by device ops many times: paying the transfer once at registration beats
    re-transferring on every query.  Eager intermediates deliberately skip
    this (see module docstring)."""
    if isinstance(obj, ColumnarTable):
        return ColumnarTable({c: jnp.asarray(v)
                              for c, v in obj.columns.items()},
                             valid=jnp.asarray(obj.valid))
    if isinstance(obj, COOMatrix):
        return COOMatrix(jnp.asarray(obj.rows), jnp.asarray(obj.cols),
                         jnp.asarray(obj.vals), obj.shape)
    if isinstance(obj, DenseTensor):
        return DenseTensor(jnp.asarray(obj.data),
                           valid_count=obj.valid_count, fill=obj.fill)
    if isinstance(obj, StreamBuffer):
        return StreamBuffer(jnp.asarray(obj.data), obj.t0)
    return obj
