"""Polystore data containers — one native format per engine family.

These mirror the paper's data models: SciDB arrays -> DenseTensor, relational
rows -> ColumnarTable, Accumulo/D4M associative arrays -> COOMatrix, S-Store
windows -> StreamBuffer.  ``nbytes``/``describe`` feed the cast cost model.

Triple-format containers (ColumnarTable, COOMatrix) accept **either** jnp or
numpy arrays for their columns/triples.  Eagerly-computed intermediates —
sort-merge join output, dense->triple casts — stay numpy until a dense
consumer actually needs the device: wrapping them in ``jnp.asarray`` at
creation would serialize every host-pool worker on the XLA transfer lock for
data the next op may never touch on-device (see ``device_ready`` for the
explicit homing used on long-lived catalog objects).
Row-range sharding (the multi-process scatter–gather substrate) also lives
here: ``shard_rows`` splits any container into N contiguous row-range parts,
and the three merge primitives — ``concat_shards`` (row-wise ops),
``sum_shards`` (decomposable aggregates: count, groupby_sum), and
``kmerge_shards`` (k-way ordered merge of per-shard sorted tables) —
reassemble per-shard results.  The merge helpers are deliberately
numpy-only: they run in the MASTER process, which must never initialize the
XLA backend (workers own the device; see ``core/procpool.py``).

The streaming/IVM substrate (``core/deltaplan.py``) builds on the same
row-range algebra: a table that grew by appended rows is exactly a 2-shard
decomposition ``[old prefix, appended suffix]``, so ``append_rows`` /
``suffix_rows`` here are the base+delta halves of ``shard_rows`` /
``concat_shards``.  Both follow their input's residency (numpy in, numpy
out) so the procpool master can maintain its catalog mirror without
touching the XLA runtime.  ``container_to_jsonable`` /
``container_from_jsonable`` round-trip small containers through JSON — the
materialized-view persistence format that rides beside the plan cache.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


@dataclass
class DenseTensor:
    """Array-engine native: a dense (possibly padded) tensor.

    ``valid_count`` is container metadata (SciDB-style): count() is O(1) here
    but a full scan in the columnar engine — the Fig.1 crossover.
    """
    data: jnp.ndarray
    valid_count: int = -1
    fill: float = 0.0

    def __post_init__(self):
        if self.valid_count < 0:
            self.valid_count = int(np.prod(self.data.shape))

    @property
    def nbytes(self) -> int:
        return self.data.size * self.data.dtype.itemsize

    kind = "dense"


@dataclass
class ColumnarTable:
    """Relational-engine native: named columns + validity mask (lazy deletes)."""
    columns: Dict[str, jnp.ndarray]
    valid: jnp.ndarray = None    # (N,) bool

    def __post_init__(self):
        n = self.nrows
        if self.valid is None:
            # follow the columns' residency: numpy columns get a numpy mask
            # (building a device mask for a host-side intermediate would
            # trigger exactly the transfer this layout avoids)
            first = next(iter(self.columns.values()))
            ones = np.ones if isinstance(first, np.ndarray) else jnp.ones
            self.valid = ones((n,), bool)

    @property
    def nrows(self) -> int:
        return next(iter(self.columns.values())).shape[0]

    @property
    def nbytes(self) -> int:
        return sum(c.size * c.dtype.itemsize for c in self.columns.values())

    kind = "columnar"


@dataclass
class COOMatrix:
    """KV/associative-array native (D4M style): (row, col, val) triples."""
    rows: jnp.ndarray
    cols: jnp.ndarray
    vals: jnp.ndarray
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return self.rows.shape[0]

    @property
    def nbytes(self) -> int:
        return (self.rows.size * self.rows.dtype.itemsize
                + self.cols.size * self.cols.dtype.itemsize
                + self.vals.size * self.vals.dtype.itemsize)

    kind = "coo"


@dataclass
class StreamBuffer:
    """Stream-engine native: window-major ring buffer of samples."""
    data: jnp.ndarray            # (n_windows, window_len, ...) newest last
    t0: int = 0                  # timestamp of the first window

    @property
    def nbytes(self) -> int:
        return self.data.size * self.data.dtype.itemsize

    kind = "stream"


FORMATS = {"dense": DenseTensor, "columnar": ColumnarTable, "coo": COOMatrix,
           "stream": StreamBuffer}


def device_ready(obj):
    """Home a container's array leaves on the device (``jnp.asarray``).

    For LONG-LIVED objects — catalog registrations — that will be consumed
    by device ops many times: paying the transfer once at registration beats
    re-transferring on every query.  Eager intermediates deliberately skip
    this (see module docstring)."""
    if isinstance(obj, ColumnarTable):
        return ColumnarTable({c: jnp.asarray(v)
                              for c, v in obj.columns.items()},
                             valid=jnp.asarray(obj.valid))
    if isinstance(obj, COOMatrix):
        return COOMatrix(jnp.asarray(obj.rows), jnp.asarray(obj.cols),
                         jnp.asarray(obj.vals), obj.shape)
    if isinstance(obj, DenseTensor):
        return DenseTensor(jnp.asarray(obj.data),
                           valid_count=obj.valid_count, fill=obj.fill)
    if isinstance(obj, StreamBuffer):
        return StreamBuffer(jnp.asarray(obj.data), obj.t0)
    return obj


def host_copy(obj):
    """Numpy-leafed clone of a container — what the procpool master pickles
    over the worker pipe (device arrays must not cross a process boundary,
    and the master side stays off the XLA runtime entirely)."""
    if isinstance(obj, ColumnarTable):
        return ColumnarTable({c: np.asarray(v) for c, v in obj.columns.items()},
                             valid=np.asarray(obj.valid))
    if isinstance(obj, COOMatrix):
        return COOMatrix(np.asarray(obj.rows), np.asarray(obj.cols),
                         np.asarray(obj.vals), tuple(obj.shape))
    if isinstance(obj, DenseTensor):
        return DenseTensor(np.asarray(obj.data),
                           valid_count=obj.valid_count, fill=obj.fill)
    if isinstance(obj, StreamBuffer):
        return StreamBuffer(np.asarray(obj.data), obj.t0)
    return obj


# -- row-range sharding -------------------------------------------------------

def shard_bounds(nrows: int, n_shards: int) -> List[Tuple[int, int]]:
    """N contiguous ``[lo, hi)`` row ranges covering ``nrows`` (remainder
    spread over the leading shards, every shard non-degenerate when
    ``nrows >= n_shards``)."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    base, rem = divmod(nrows, n_shards)
    bounds, lo = [], 0
    for i in range(n_shards):
        hi = lo + base + (1 if i < rem else 0)
        bounds.append((lo, hi))
        lo = hi
    return bounds


def shard_rows(obj, n_shards: int) -> list:
    """Split a container into ``n_shards`` contiguous row-range parts.

    Dense tensors and columnar tables shard on the leading axis, COO on the
    row coordinate (rows re-based to each shard's origin), streams on the
    window axis.  Concatenating the parts back (``concat_shards``) is the
    identity.
    """
    if isinstance(obj, DenseTensor):
        a = np.asarray(obj.data)
        if a.ndim < 1:
            raise ValueError("cannot row-shard a 0-d tensor")
        if obj.valid_count not in (-1, a.size):
            # a padded tensor's valid elements are not row-attributable, so
            # per-shard counts could not reassemble to the true total
            raise ValueError("cannot row-shard a padded DenseTensor")
        return [DenseTensor(a[lo:hi], fill=obj.fill)
                for lo, hi in shard_bounds(a.shape[0], n_shards)]
    if isinstance(obj, ColumnarTable):
        cols = {c: np.asarray(v) for c, v in obj.columns.items()}
        valid = np.asarray(obj.valid)
        return [ColumnarTable({c: v[lo:hi] for c, v in cols.items()},
                              valid=valid[lo:hi])
                for lo, hi in shard_bounds(obj.nrows, n_shards)]
    if isinstance(obj, COOMatrix):
        rows = np.asarray(obj.rows)
        cols = np.asarray(obj.cols)
        vals = np.asarray(obj.vals)
        parts = []
        for lo, hi in shard_bounds(obj.shape[0], n_shards):
            m = (rows >= lo) & (rows < hi)
            parts.append(COOMatrix((rows[m] - lo).astype(rows.dtype),
                                   cols[m], vals[m],
                                   (hi - lo, obj.shape[1])))
        return parts
    if isinstance(obj, StreamBuffer):
        a = np.asarray(obj.data)
        return [StreamBuffer(a[lo:hi], t0=obj.t0 + lo)
                for lo, hi in shard_bounds(a.shape[0], n_shards)]
    raise TypeError(f"cannot shard {type(obj).__name__}")


# -- shard merges -------------------------------------------------------------

def concat_shards(parts: Sequence):
    """Reassemble row-wise per-shard results: row concatenation in shard
    order (the inverse of ``shard_rows`` for every row-preserving op)."""
    if not parts:
        raise ValueError("no shard results to merge")
    first = parts[0]
    if isinstance(first, DenseTensor):
        data = np.concatenate([np.asarray(p.data) for p in parts], axis=0)
        vc = sum(p.valid_count for p in parts)
        return DenseTensor(data, valid_count=vc, fill=first.fill)
    if isinstance(first, ColumnarTable):
        return ColumnarTable(
            {c: np.concatenate([np.asarray(p.columns[c]) for p in parts])
             for c in first.columns},
            valid=np.concatenate([np.asarray(p.valid) for p in parts]))
    if isinstance(first, COOMatrix):
        rows, off = [], 0
        for p in parts:
            rows.append(np.asarray(p.rows) + off)
            off += p.shape[0]
        return COOMatrix(np.concatenate(rows).astype(np.asarray(first.rows).dtype),
                         np.concatenate([np.asarray(p.cols) for p in parts]),
                         np.concatenate([np.asarray(p.vals) for p in parts]),
                         (off, max(p.shape[1] for p in parts)))
    if isinstance(first, StreamBuffer):
        return StreamBuffer(
            np.concatenate([np.asarray(p.data) for p in parts], axis=0),
            t0=first.t0)
    raise TypeError(f"cannot concat-merge {type(first).__name__}")


def sum_shards(parts: Sequence):
    """Merge decomposable aggregates: element-wise sum over aligned shard
    results.  Covers ``count`` (0-d DenseTensor per shard -> grand total) and
    ``groupby_sum`` (every shard emits the full aligned key range
    ``0..num_groups``, so group partial sums add position-wise)."""
    if not parts:
        raise ValueError("no shard results to merge")
    first = parts[0]
    if isinstance(first, DenseTensor):
        data = np.asarray(parts[0].data)
        for p in parts[1:]:
            data = data + np.asarray(p.data)
        return DenseTensor(data, valid_count=first.valid_count,
                           fill=first.fill)
    if isinstance(first, ColumnarTable):
        key = np.asarray(first.columns["key"])
        for p in parts[1:]:
            if not np.array_equal(np.asarray(p.columns["key"]), key):
                raise ValueError("sum-merge requires aligned group keys")
        out = {"key": key}
        for c in first.columns:
            if c == "key":
                continue
            acc = np.asarray(first.columns[c])
            for p in parts[1:]:
                acc = acc + np.asarray(p.columns[c])
            out[c] = acc
        return ColumnarTable(out)
    raise TypeError(f"cannot sum-merge {type(first).__name__}")


def kmerge_shards(parts: Sequence, by: str):
    """K-way ordered merge of per-shard SORTED columnar tables on column
    ``by`` (classic heap merge: O(total rows * log k)).  Invalid rows are
    compacted away first; ties preserve shard order (stable)."""
    if not parts:
        raise ValueError("no shard results to merge")
    compact = []
    for p in parts:
        valid = np.asarray(p.valid)
        cols = {c: np.asarray(v) for c, v in p.columns.items()}
        if not valid.all():
            cols = {c: v[valid] for c, v in cols.items()}
        compact.append(cols)
    names = list(compact[0])
    offsets = np.cumsum([0] + [c[names[0]].shape[0] for c in compact])
    def stream(cols, si):
        # bound per shard (a bare genexp in the comprehension would
        # late-bind si/cols to the last shard)
        key = cols[by]
        return ((key[i], si, offsets[si] + i) for i in range(key.shape[0]))

    streams = [stream(cols, si) for si, cols in enumerate(compact)]
    order = np.fromiter((flat for _, _, flat in heapq.merge(*streams)),
                        dtype=np.int64)
    merged = {c: np.concatenate([cols[c] for cols in compact])[order]
              for c in names}
    return ColumnarTable(merged)


# -- streaming append / delta slicing ----------------------------------------

def _xp_of(a):
    """The array module matching ``a``'s residency: numpy leaves stay numpy
    (procpool-master safe), device leaves stay on the device."""
    return np if isinstance(a, np.ndarray) else jnp


def leading_rows(obj) -> int:
    """Leading-dimension row count of a container — the quantity appends
    grow and the materialized-view freshness stamps record.  Raises
    ``TypeError`` for containers with no row dimension (0-d tensors)."""
    if isinstance(obj, ColumnarTable):
        return obj.nrows
    if isinstance(obj, COOMatrix):
        return int(obj.shape[0])
    data = getattr(obj, "data", None)
    if data is not None and getattr(data, "ndim", 0) >= 1:
        return int(data.shape[0])
    raise TypeError(f"no row dimension on {type(obj).__name__}")


def append_rows(base, delta):
    """``base`` grown by ``delta``'s rows along the leading dimension — the
    STREAM island's append semantics.  The result's old-row prefix is
    bit-identical to ``base`` (``suffix_rows(result, leading_rows(base)) ==
    delta``), which is what lets the IVM path reconstruct the pending delta
    from the current table without keeping an append log.  Containers must
    be the same kind with matching trailing geometry; padded dense tensors
    are refused for the same reason ``shard_rows`` refuses them (their
    valid elements are not row-attributable)."""
    if type(base) is not type(delta):
        raise TypeError(f"cannot append {type(delta).__name__} rows to "
                        f"{type(base).__name__}")
    if isinstance(base, DenseTensor):
        a, d = base.data, delta.data
        if getattr(a, "ndim", 0) < 1:
            raise ValueError("cannot append rows to a 0-d tensor")
        if a.shape[1:] != d.shape[1:]:
            raise ValueError(f"append shape mismatch: base rows are "
                             f"{a.shape[1:]}, delta rows are {d.shape[1:]}")
        for t in (base, delta):
            if t.valid_count not in (-1, int(np.prod(t.data.shape))):
                raise ValueError("cannot append to/with a padded DenseTensor")
        xp = _xp_of(a)
        return DenseTensor(xp.concatenate([a, xp.asarray(d)], axis=0),
                           fill=base.fill)
    if isinstance(base, ColumnarTable):
        if set(base.columns) != set(delta.columns):
            raise ValueError(f"append column mismatch: "
                             f"{sorted(base.columns)} vs "
                             f"{sorted(delta.columns)}")
        first = next(iter(base.columns.values()))
        xp = _xp_of(first)
        cols = {c: xp.concatenate([v, xp.asarray(delta.columns[c])])
                for c, v in base.columns.items()}
        valid = xp.concatenate([xp.asarray(base.valid),
                                xp.asarray(delta.valid)])
        return ColumnarTable(cols, valid=valid)
    if isinstance(base, COOMatrix):
        xp = _xp_of(base.rows)
        off = int(base.shape[0])
        rows = xp.concatenate([base.rows,
                               (xp.asarray(delta.rows) + off).astype(
                                   base.rows.dtype)])
        return COOMatrix(rows,
                         xp.concatenate([base.cols, xp.asarray(delta.cols)]),
                         xp.concatenate([base.vals, xp.asarray(delta.vals)]),
                         (off + int(delta.shape[0]),
                          max(int(base.shape[1]), int(delta.shape[1]))))
    if isinstance(base, StreamBuffer):
        if base.data.shape[1:] != delta.data.shape[1:]:
            raise ValueError("append window-shape mismatch")
        xp = _xp_of(base.data)
        return StreamBuffer(xp.concatenate([base.data,
                                            xp.asarray(delta.data)], axis=0),
                            t0=base.t0)
    raise TypeError(f"cannot append rows to {type(base).__name__}")


def suffix_rows(obj, start: int):
    """Rows ``[start:]`` of a container as a same-kind container — the
    pending delta of a streaming table whose materialized view was taken at
    ``start`` rows (the inverse of ``append_rows``)."""
    n = leading_rows(obj)
    if not 0 <= start <= n:
        raise ValueError(f"suffix start {start} outside [0, {n}]")
    if isinstance(obj, DenseTensor):
        if obj.valid_count not in (-1, int(np.prod(obj.data.shape))):
            raise ValueError("cannot row-slice a padded DenseTensor")
        return DenseTensor(obj.data[start:], fill=obj.fill)
    if isinstance(obj, ColumnarTable):
        return ColumnarTable({c: v[start:] for c, v in obj.columns.items()},
                             valid=obj.valid[start:])
    if isinstance(obj, COOMatrix):
        xp = _xp_of(obj.rows)
        m = obj.rows >= start
        return COOMatrix((obj.rows[m] - start).astype(obj.rows.dtype),
                         obj.cols[m], obj.vals[m],
                         (n - start, int(obj.shape[1])))
    if isinstance(obj, StreamBuffer):
        return StreamBuffer(obj.data[start:], t0=obj.t0 + start)
    raise TypeError(f"cannot row-slice {type(obj).__name__}")


# -- JSON round-trip (materialized-view persistence) --------------------------

def _arr_to_json(a) -> Dict:
    a = np.asarray(a)
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "data": a.ravel().tolist()}


def _arr_from_json(blob) -> np.ndarray:
    return np.asarray(blob["data"], dtype=np.dtype(blob["dtype"])).reshape(
        tuple(blob["shape"]))


def container_to_jsonable(obj):
    """A pure-JSON encoding of a container (numpy-leafed values; call
    ``host_copy`` first for device objects), or ``None`` for types this
    codec does not cover.  Sized for SMALL payloads — materialized views
    under the persistence cap — not as a general serialization format."""
    if isinstance(obj, DenseTensor):
        return {"kind": "dense", "array": _arr_to_json(obj.data),
                "valid_count": int(obj.valid_count), "fill": float(obj.fill)}
    if isinstance(obj, ColumnarTable):
        return {"kind": "columnar",
                "columns": {c: _arr_to_json(v)
                            for c, v in obj.columns.items()},
                "valid": np.asarray(obj.valid).tolist()}
    if isinstance(obj, COOMatrix):
        return {"kind": "coo", "rows": _arr_to_json(obj.rows),
                "cols": _arr_to_json(obj.cols),
                "vals": _arr_to_json(obj.vals),
                "shape": [int(obj.shape[0]), int(obj.shape[1])]}
    if isinstance(obj, StreamBuffer):
        return {"kind": "stream", "array": _arr_to_json(obj.data),
                "t0": int(obj.t0)}
    return None


def container_from_jsonable(blob):
    """Inverse of ``container_to_jsonable`` (numpy-leafed result).  Raises
    ``ValueError`` on unknown kinds; key/shape errors propagate as the
    usual ``KeyError``/``TypeError`` for the caller's skip-with-warning
    policy."""
    kind = blob.get("kind") if isinstance(blob, dict) else None
    if kind == "dense":
        return DenseTensor(_arr_from_json(blob["array"]),
                           valid_count=int(blob["valid_count"]),
                           fill=float(blob["fill"]))
    if kind == "columnar":
        return ColumnarTable({c: _arr_from_json(v)
                              for c, v in blob["columns"].items()},
                             valid=np.asarray(blob["valid"], bool))
    if kind == "coo":
        return COOMatrix(_arr_from_json(blob["rows"]),
                         _arr_from_json(blob["cols"]),
                         _arr_from_json(blob["vals"]),
                         (int(blob["shape"][0]), int(blob["shape"][1])))
    if kind == "stream":
        return StreamBuffer(_arr_from_json(blob["array"]),
                            t0=int(blob.get("t0", 0)))
    raise ValueError(f"unknown container kind {kind!r}")
