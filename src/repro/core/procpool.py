"""Multi-process serving: a master/worker pool that breaks the GIL ceiling.

BigDAWG's middleware is itself a *process* architecture — each engine is an
independent server process and the middleware "dispatches query fragments to
[them] and reassembles results".  Our in-process serving stack (PR 4/6) gets
real concurrency only where engine ops release the GIL; every pure-Python
planning or merge step still serializes request threads.  ``ProcPool`` is
the process-level answer: a master that owns NO engine state fans requests
out to N worker processes, each a full ``BigDAWG`` middleware stack with its
own XLA runtime and host pool.

Design points:

* **spawn, not fork.**  The XLA runtime is not fork-safe; every worker is a
  fresh interpreter that builds its own middleware from a picklable spec.
  The master never initializes the backend at all — its merge/gather path is
  numpy-only (``tables.concat_shards``/``sum_shards``/``kmerge_shards``).
* **pickle-framed pipe RPC.**  One duplex ``multiprocessing.Pipe`` per
  worker; messages are ``(kind, rid, *payload)`` tuples and replies are
  ``("ok"|"err", rid, payload)``.  Replies are matched on ``rid`` — a stale
  reply from a timed-out predecessor request is discarded, never mis-
  delivered.  Per-worker locks serialize each pipe; different workers serve
  concurrently, so ``QueryServer.submit_many`` admission fans across
  processes.
* **shared persistence, not shared memory.**  Workers converge through the
  monitor DB / plan-cache files: every worker opens the monitor with
  ``shared=True`` (merge-on-save: last-writer-wins *per signature*, no
  dropped entries) and polls ``reload_shared()`` before each request (one
  ``stat`` when nothing changed), so a signature trained by worker 0 is
  served warm by worker 1 without any master-side plan state.
* **worker death is an engine failure one level up.**  The master tracks
  workers through the same ``EngineHealth`` breaker registry engines use,
  on channels ``worker:<i>``.  A dead/hung worker records a breaker failure
  (threshold 1 — process death is conclusive), is respawned with its full
  registration log replayed, and the breaker is force-``reset`` (the
  replacement is healthy; re-earning trust through a half-open probe would
  shed requests at a recovered worker).  The in-flight request is retried
  on the replacement; exhaustion surfaces a clean ``EngineDown`` — never a
  hang, never a lost request.
* **sharded scatter–gather.**  ``register(..., shards=N)`` row-range splits
  a table; part ``i`` is homed ONLY on worker ``i % processes`` (the full
  table goes everywhere).  A query whose ``shardplan.analyze`` decomposition
  exists — and which ``planner.price_scatter_gather`` prices as worthwhile —
  runs as per-shard fragments on the owning workers in parallel and is
  reassembled by the decomposition's merge (concat / sum / k-way ordered
  merge) in the master.  A shard fragment retries on the SAME worker index
  after a respawn: only that worker holds the shard's rows.  The gather is
  *incremental* (``IncrementalGather``): frames fold into the accumulator
  as workers reply — sum in arrival order, concat/kmerge over the
  contiguous ready prefix — so per-shard payloads are freed immediately
  instead of piling up until the slowest worker answers.
* **streaming appends fan out.**  ``register(..., streaming=True)`` mirrors
  the STREAM-island append contract across the pool: ``append(name, rows)``
  grows the table on every worker (each keeps its own materialized views
  patchable), in the master's catalog, and in the respawn replay log — a
  replacement worker replays the CURRENT rows, never a pre-append state.

``ProcPool`` duck-types the middleware surface the serving stack consumes —
``execute(query, mode, degrade=)`` returning a ``Report``, ``register``,
``persist``, ``health``, ``breaker_trips``, ``catalog`` — so
``QueryServer(bd, processes=N)`` and ``connect(processes=N)`` drop it in
without touching the admission logic.
"""
from __future__ import annotations

import itertools
import os
import pickle
import threading
import time
from dataclasses import replace
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import shardplan, tables, tracing
from repro.core.engines import ENGINES
from repro.core.errors import (BigDAWGError, EngineDown, Overloaded,
                               PlanInfeasible, QueryParseError)
from repro.core.health import EngineHealth
from repro.core.ops import PolyOp
from repro.core.shardplan import ShardInfo, shard_name
from repro.core.signature import signature


def worker_channel(idx: int) -> str:
    """Breaker-registry channel name for worker ``idx``."""
    return f"worker:{idx}"


class _WorkerDied(Exception):
    """Internal: the pipe/process under an RPC went away (EOF, broken pipe,
    dead process, or a hung request past its timeout)."""

    def __init__(self, idx: int):
        super().__init__(f"worker {idx} died")
        self.idx = idx


# -- worker side --------------------------------------------------------------

def _portable_exc(exc: BaseException) -> BaseException:
    """An exception safe to pickle back over the pipe.

    The structured taxonomy is rebuilt field-by-field (BigDAWGError
    subclasses format their message from attributes, so default pickling
    by ``args`` would misconstruct them; an ``EngineDown.cause`` may not
    pickle at all).  Anything else round-trips as-is when picklable, else
    degrades to a ``RuntimeError`` carrying the repr."""
    if isinstance(exc, EngineDown):
        return EngineDown(exc.engine, exc.op)
    if isinstance(exc, PlanInfeasible):
        return PlanInfeasible(exc.op, exc.island, exc.masked)
    if isinstance(exc, Overloaded):
        return Overloaded(exc.query, exc.reason)
    if isinstance(exc, QueryParseError):
        return QueryParseError(str(exc))
    try:
        pickle.loads(pickle.dumps(exc))
        return exc
    except Exception:
        return RuntimeError(f"{type(exc).__name__}: {exc}")


def _portable_report(rep) -> Any:
    """Report with its result's array leaves rebased to numpy — device
    buffers must not cross the process boundary — and its trace converted
    to a plain dict (a live Trace carries a threading.Lock)."""
    return replace(rep, result=tables.host_copy(rep.result),
                   trace=tracing.portable(getattr(rep, "trace", None)))


def _worker_main(widx: int, conn, spec: Dict[str, Any]) -> None:
    """Worker process entry point: build a full middleware stack from the
    picklable ``spec`` and serve the RPC loop until ``stop``/EOF.

    The monitor is opened ``shared=True`` so saves merge (per-signature
    last-writer-wins) instead of clobbering sibling workers, and
    ``reload_shared()`` runs before every execute so plans trained by
    siblings are served warm here.  A training serve persists immediately —
    that is the publication step of the cross-process warm path."""
    # deferred so the spawn bootstrap stays import-light until we commit
    from repro.core.middleware import BigDAWG
    from repro.core.monitor import Monitor

    state_path = spec.get("state_path")
    kwargs = dict(spec.get("bigdawg_kwargs") or {})
    if spec.get("resilient"):
        kwargs.setdefault("health", EngineHealth())
    bd = BigDAWG(monitor=Monitor(state_path, shared=bool(state_path)),
                 **kwargs)
    shared = bool(state_path)
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            break
        kind, rid = msg[0], msg[1]
        try:
            if kind == "execute":
                query, mode, degrade = msg[2], msg[3], msg[4]
                # older masters frame execute without the trace context —
                # length-check like the register streaming flag below
                tctx = msg[5] if len(msg) > 5 else None
                if shared:
                    bd.reload_shared()
                rep = bd.execute(query, mode, degrade=degrade,
                                 trace_ctx=tctx)
                if shared and rep.mode == "training":
                    bd.monitor.save()
                    bd.save_plan_cache()
                conn.send(("ok", rid, _portable_report(rep)))
            elif kind == "register":
                name, obj, engine = msg[2], msg[3], msg[4]
                # older masters frame register without the streaming flag —
                # length-check instead of unpacking so both framings work
                streaming = bool(msg[5]) if len(msg) > 5 else False
                bd.register(name, obj, engine, streaming=streaming)
                conn.send(("ok", rid, None))
            elif kind == "append":
                name, rows = msg[2], msg[3]
                conn.send(("ok", rid, bd.append(name, rows)))
            elif kind == "persist":
                bd.persist()
                conn.send(("ok", rid, None))
            elif kind == "ping":
                conn.send(("ok", rid, os.getpid()))
            elif kind == "stop":
                conn.send(("ok", rid, None))
                break
            else:
                conn.send(("err", rid,
                           RuntimeError(f"unknown message kind {kind!r}")))
        except BaseException as exc:          # noqa: BLE001 — RPC boundary
            try:
                conn.send(("err", rid, _portable_exc(exc)))
            except (OSError, BrokenPipeError):
                break
    conn.close()


def _monitor_hammer(path: str, private_sig: str, shared_sig: str,
                    rounds: int, seed: int) -> None:
    """Spawn target for the persistence-contention test: hammer one shared
    monitor DB with interleaved merge-saves and reloads.  Lives here (not in
    the test module) because spawn pickles targets by import path.

    Each process records ``rounds`` observations under its OWN signature
    plus the contended ``shared_sig``, saving after every record — the
    merge-on-save protocol must keep every private signature intact and
    resolve the shared one last-writer-wins, with zero torn reads."""
    from repro.core.monitor import Monitor

    m = Monitor(path, shared=True)
    usage = {"cpu": 0.5, "mem_frac": 0.1}
    for r in range(rounds):
        m.reload_if_changed()
        m.record(private_sig, f"0:plan{seed}", 0.001 * (r + 1), usage=usage)
        m.record(shared_sig, f"0:writer{seed}", 0.001 * (seed + 1),
                 usage=usage)
        m.save()
        time.sleep(0.001 * ((seed + r) % 3))


def _plan_cache_hammer(state_path: str, private_sig: str, bad_sig: str,
                       rounds: int, seed: int) -> None:
    """Spawn target for the masked-signature purity test: hammer one shared
    plan-cache file with interleaved merge-saves and reloads while a
    ``@!``-masked entry keeps being re-injected underneath.  Lives here (not
    in the test module) because spawn pickles targets by import path.

    Each process holds ONE private unmasked signature plus a live masked
    entry in its in-memory cache, and every other round writes the masked
    signature straight into the shared file (simulating a sibling that
    crashed mid-outage with degraded state persisted).  The merge-on-save
    protocol must carry every private signature forever while NEVER writing,
    re-adopting, or resurrecting the masked one."""
    from repro.core.ioutil import atomic_json_dump, load_json
    from repro.core.middleware import (BigDAWG, CachedPlan, MASK_SEP,
                                       _plan_from_key)
    from repro.core.monitor import Monitor

    assert MASK_SEP in bad_sig
    bd = BigDAWG(monitor=Monitor(state_path, shared=True))
    bd.plan_cache[private_sig] = CachedPlan(_plan_from_key("0:dense_array"))
    bd.plan_cache[bad_sig] = CachedPlan(_plan_from_key("0:columnar"))
    for r in range(rounds):
        bd.reload_plan_cache_if_changed()
        bd.save_plan_cache()
        if (r + seed) % 2 == 0:
            # adversarial sibling: masked entry lands in the file between
            # this process's save and everyone else's next merge
            try:
                blob = load_json(bd.plan_cache_path)
            except (OSError, ValueError):
                blob = None
            if isinstance(blob, dict):
                blob.setdefault("entries", {})[bad_sig] = {
                    "plan": "0:kv_sparse", "predicted_s": 0.0,
                    "alternates": []}
                atomic_json_dump(bd.plan_cache_path, blob)
        time.sleep(0.001 * ((seed + r) % 3))


# -- master side --------------------------------------------------------------

class IncrementalGather:
    """Fold-on-arrival gather accumulator for the sharded scatter path.

    The master used to hold every shard's full result frame until the LAST
    worker answered, then merge once — peak memory was the sum of all shard
    results, and the whole merge cost landed after the slowest worker.
    This accumulator merges frames as they ARRIVE instead: ``sum`` folds
    pairwise in any order (element-wise addition commutes and the group
    keys are aligned by construction); ``concat`` and ``kmerge`` are
    order-sensitive, so they fold the contiguous ready prefix in shard
    order — both are associative over a prefix, and ``kmerge`` ties stay
    stable because already-folded earlier shards always sit on the left.
    A folded frame's payload is dropped immediately; the master holds at
    most the running accumulator plus whatever out-of-order frames are
    still waiting on a predecessor.  Thread-safe: worker gather threads
    call ``add`` concurrently."""

    __slots__ = ("merge", "by", "n", "folds", "span", "_lock", "_acc",
                 "_next", "_pending")

    def __init__(self, merge: str, n_shards: int, by: Optional[str] = None,
                 span=None):
        if merge not in ("concat", "sum", "kmerge"):
            raise ValueError(f"unknown merge kind {merge!r}")
        self.merge = merge
        self.by = by
        self.n = n_shards
        self.folds = 0                 # pairwise merges performed (testing)
        self.span = span               # parent tracing.Span: gather_fold spans
        self._lock = threading.Lock()
        self._acc: Any = None
        self._next = 0                 # next shard index the prefix fold needs
        self._pending: Dict[int, Any] = {}

    def _fold(self, fn, shard: int):
        """One pairwise merge, counted and (when tracing) span-recorded —
        no clock reads on the untraced path."""
        if self.span is None:
            out = fn()
        else:
            t0 = time.perf_counter()
            out = fn()
            self.span.static_child("gather_fold",
                                   time.perf_counter() - t0,
                                   shard=shard, merge=self.merge)
        self.folds += 1
        return out

    def add(self, i: int, part) -> None:
        """Absorb shard ``i``'s result frame, folding whatever became
        contiguous (everything, for ``sum``) into the accumulator."""
        with self._lock:
            if self.merge == "sum":
                if self._acc is None:
                    self._acc = part
                else:
                    self._acc = self._fold(
                        lambda: tables.sum_shards([self._acc, part]), i)
                self._next += 1
                return
            self._pending[i] = part
            while self._next in self._pending:
                part = self._pending.pop(self._next)
                if self._acc is None:
                    self._acc = part
                elif self.merge == "concat":
                    self._acc = self._fold(
                        lambda: tables.concat_shards([self._acc, part]),
                        self._next)
                else:
                    self._acc = self._fold(
                        lambda: tables.kmerge_shards([self._acc, part],
                                                     self.by), self._next)
                self._next += 1

    def result(self):
        with self._lock:
            if self._next != self.n or self._pending:
                raise RuntimeError(
                    f"gather incomplete: {self._next}/{self.n} shards folded,"
                    f" {sorted(self._pending)} awaiting predecessors")
            return self._acc


class _Worker:
    """Master-side handle: process + pipe + the lock serializing its RPCs."""

    __slots__ = ("idx", "proc", "conn", "lock")

    def __init__(self, idx, proc, conn):
        self.idx = idx
        self.proc = proc
        self.conn = conn
        self.lock = threading.Lock()


class ProcPool:
    """Master of N worker processes — see the module docstring.

    ``scatter`` controls the sharded path: ``"auto"`` (default) asks
    ``planner.price_scatter_gather`` per signature, ``"always"``/``"never"``
    force it.  ``retries`` bounds how many replacement workers one request
    may try after deaths before surfacing ``EngineDown``.
    ``kill_injector`` (``runtime.fault.WorkerKillInjector``) is the fault
    seam: fired after every execute dispatch with the target's pid.
    """

    def __init__(self, processes: int = 2,
                 state_path: Optional[str] = None, *,
                 resilient: bool = False,
                 request_timeout_s: float = 120.0,
                 start_timeout_s: float = 300.0,
                 retries: int = 1,
                 scatter: str = "auto",
                 health: Optional[EngineHealth] = None,
                 kill_injector=None,
                 **bigdawg_kwargs):
        if processes < 1:
            raise ValueError(f"processes must be >= 1, got {processes}")
        if scatter not in ("auto", "always", "never"):
            raise ValueError(f"scatter must be auto|always|never, "
                             f"got {scatter!r}")
        import multiprocessing
        self._ctx = multiprocessing.get_context("spawn")
        self.n = processes
        self.state_path = state_path
        self._spec = {"state_path": state_path, "resilient": resilient,
                      "bigdawg_kwargs": dict(bigdawg_kwargs)}
        # tracing: the master mirrors the workers' trace= knob (it rides
        # bigdawg_kwargs into each worker's BigDAWG).  With it on, execute()
        # roots a master-side request span and ships (trace_id, span_id)
        # with every dispatch so worker spans re-attach under it
        self.tracer = tracing.Tracer(
            enabled=bool(bigdawg_kwargs.get("trace", False)))
        from repro.runtime.telemetry import Metrics, default_metrics_path
        self.metrics = Metrics(
            default_metrics_path(state_path) if state_path else None,
            shared=bool(state_path))
        self.request_timeout_s = request_timeout_s
        self.start_timeout_s = start_timeout_s
        self.retries = retries
        self.scatter = scatter
        self.kill_injector = kill_injector
        # worker-death breakers: threshold 1 — a dead process is conclusive
        self.health = health or EngineHealth(
            failure_threshold=1,
            channels=[worker_channel(i) for i in range(processes)])
        # master-side registry: the replay log (respawn re-registers; an
        # append rewrites the logged table in place so replacements replay
        # the CURRENT rows), the catalog mirror (signatures + scatter
        # pricing), the shard registry
        self._registrations: List[
            Tuple[str, Any, str, Optional[int], bool]] = []
        self.catalog: Dict[str, Any] = {}
        self.sharded: Dict[str, ShardInfo] = {}
        self._scatter_cache: Dict[str, bool] = {}
        self._cost_model = None            # built lazily for pricing
        self._rid = itertools.count(1)
        self._rr = itertools.count()
        self._lock = threading.Lock()      # guards workers[] swaps
        self._closed = False
        self.workers: List[_Worker] = [self._spawn(i)
                                       for i in range(processes)]

    # lifetime counters, backed by the metrics registry (``respawns`` etc.
    # stay readable/assignable attributes for existing callers and tests)
    def _metric_prop(name: str) -> property:      # noqa: N805 — factory
        def _get(self):
            return int(self.metrics.value(name))

        def _set(self, v):
            self.metrics.set_counter(name, float(v))
        return property(_get, _set)

    respawns = _metric_prop("pool.respawns")
    dispatches = _metric_prop("pool.dispatches")
    scatter_serves = _metric_prop("pool.scatter_serves")
    del _metric_prop

    # -- lifecycle -----------------------------------------------------------
    def _spawn(self, idx: int) -> _Worker:
        parent, child = self._ctx.Pipe()
        proc = self._ctx.Process(target=_worker_main,
                                 args=(idx, child, self._spec),
                                 daemon=True, name=f"bigdawg-worker-{idx}")
        proc.start()
        child.close()
        return _Worker(idx, proc, parent)

    def _respawn(self, idx: int, dead: _Worker) -> None:
        """Replace a dead worker: breaker failure -> fresh process -> replay
        the registration log -> breaker reset.  Guarded so concurrent
        requests that watched the same death respawn exactly once — the
        loser finds ``workers[idx]`` already replaced and just retries."""
        with self._lock:
            if self.workers[idx] is not dead:
                return                     # another thread already replaced it
            ch = worker_channel(idx)
            self.health.ensure_channel(ch)
            self.health.record_failure(ch)
            try:
                dead.conn.close()
            except OSError:
                pass
            if dead.proc.is_alive():
                dead.proc.terminate()
            dead.proc.join(timeout=10)
            h = self._spawn(idx)
            # replay BEFORE publishing the handle: no request may overtake
            # the catalog rebuild on the fresh process
            for name, obj, engine, target, streaming in self._registrations:
                if target is None or target == idx:
                    self._rpc(h, "register", name, obj, engine, streaming,
                              timeout=self.start_timeout_s)
            self.workers[idx] = h
            self.metrics.counter("pool.respawns")
            # the replacement is healthy — don't make it re-earn trust
            # through a half-open probe
            self.health.reset(ch)

    def close(self) -> None:
        """Stop every worker (idempotent; also runs via ``atexit`` through
        ``QueryServer``/``Session`` owners calling it explicitly)."""
        if self._closed:
            return
        self._closed = True
        for h in self.workers:
            try:
                self._rpc(h, "stop", timeout=5.0)
            except (_WorkerDied, Exception):   # noqa: BLE001 — best effort
                pass
            try:
                h.conn.close()
            except OSError:
                pass
        for h in self.workers:
            h.proc.join(timeout=5)
            if h.proc.is_alive():
                h.proc.terminate()
                h.proc.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- RPC core ------------------------------------------------------------
    def _rpc(self, h: _Worker, kind: str, *payload,
             timeout: Optional[float] = None, span=None):
        """One framed request/reply on a worker's pipe.  Raises
        ``_WorkerDied`` on EOF/broken pipe/dead process/timeout; re-raises
        the worker's transported exception on an ``err`` reply.  Replies are
        rid-matched: a buffered reply to an earlier timed-out request is
        discarded here rather than mis-delivered.

        With a ``span``, the wait for the worker's pipe lock is recorded as
        a ``queue_wait`` child and the in-flight RPC as ``worker_dispatch``."""
        rid = next(self._rid)
        timeout = self.request_timeout_s if timeout is None else timeout
        qspan = span.child("queue_wait", worker=h.idx) \
            if span is not None else None
        with h.lock:
            if qspan is not None:
                qspan.end()
                dspan = span.child("worker_dispatch", worker=h.idx, kind=kind)
            else:
                dspan = None
            try:
                try:
                    h.conn.send((kind, rid) + payload)
                except (OSError, BrokenPipeError, ValueError):
                    raise _WorkerDied(h.idx) from None
                if self.kill_injector is not None and kind == "execute":
                    # fault seam: the request is now in flight on that process
                    self.kill_injector.on_dispatch(h.idx, h.proc.pid)
                deadline = time.monotonic() + timeout
                while True:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        # hung worker: indistinguishable from dead at this
                        # layer — kill it so the respawn starts clean
                        if h.proc.is_alive():
                            h.proc.terminate()
                        raise _WorkerDied(h.idx)
                    if h.conn.poll(min(0.1, remaining)):
                        try:
                            status, r_rid, out = h.conn.recv()
                        except (EOFError, OSError):
                            raise _WorkerDied(h.idx) from None
                        if r_rid != rid:
                            continue       # stale reply — discard, keep waiting
                        if status == "ok":
                            return out
                        raise out
                    if not h.proc.is_alive():
                        # one last poll: a reply can be buffered past death
                        if not h.conn.poll(0.2):
                            raise _WorkerDied(h.idx)
            finally:
                if dspan is not None:
                    dspan.end()

    # -- registration --------------------------------------------------------
    def register(self, name: str, obj, engine: str,
                 shards: Optional[int] = None,
                 streaming: bool = False) -> None:
        """Mirror of ``BigDAWG.register`` across the pool.  The full table
        goes to every worker; with ``shards=N`` part ``i`` additionally goes
        ONLY to worker ``i % processes`` under ``name#i`` — the placement
        the scatter path dispatches against.  ``streaming=True`` declares an
        append-able STREAM-island table (``append`` grows it on every
        worker); streaming tables cannot be sharded — appends would have to
        re-balance the row-range parts."""
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine}")
        if streaming and shards is not None:
            raise ValueError("a streaming registration cannot be sharded")
        obj = tables.host_copy(obj)
        if shards is not None:
            if shards < 1:
                raise ValueError(f"shards must be >= 1, got {shards}")
            parts = tables.shard_rows(obj, shards)
            self.sharded[name] = ShardInfo(shards, obj.kind,
                                           shardplan.nrows_of(obj))
            self._scatter_cache.clear()
            for i, part in enumerate(parts):
                self._register_one(shard_name(name, i), part, engine,
                                   target=i % self.n)
        self._register_one(name, obj, engine, target=None,
                           streaming=streaming)

    def _register_one(self, name: str, obj, engine: str,
                      target: Optional[int],
                      streaming: bool = False) -> None:
        from repro.core.middleware import CatalogEntry
        # log first: any respawn from here on replays this entry itself
        self._registrations.append((name, obj, engine, target, streaming))
        self.catalog[name] = CatalogEntry(name, obj, engine,
                                          streaming=streaming)
        for idx in range(self.n):
            if target is not None and target != idx:
                continue
            h = self.workers[idx]
            try:
                self._rpc(h, "register", name, obj, engine, streaming,
                          timeout=self.start_timeout_s)
            except _WorkerDied:
                self._respawn(idx, h)      # replay delivers this entry too

    def append(self, name: str, rows) -> int:
        """Mirror of ``BigDAWG.append`` across the pool: grow a streaming
        registration on every worker and in the master's catalog/replay log.
        The replay log is rewritten IN PLACE first, so a worker that dies
        mid-broadcast respawns with the grown table already replayed — no
        worker can serve pre-append rows after this returns.  Returns the
        master's new version for the table."""
        entry = self.catalog.get(name)
        if entry is None:
            raise KeyError(f"no registration named {name!r}")
        if not entry.streaming:
            raise ValueError(f"{name!r} is not a streaming registration "
                             f"(register with streaming=True)")
        rows = tables.host_copy(rows)
        with self._lock:
            for j, reg in enumerate(self._registrations):
                if reg[0] == name and reg[4]:
                    self._registrations[j] = (
                        reg[0], tables.append_rows(reg[1], rows), reg[2],
                        reg[3], True)
            entry.obj = tables.append_rows(entry.obj, rows)
            entry.version += 1
        for idx in range(self.n):
            h = self.workers[idx]
            try:
                self._rpc(h, "append", name, rows)
            except _WorkerDied:
                self._respawn(idx, h)  # replay log already holds the grown
                #                        table — nothing left to deliver
        return entry.version

    @classmethod
    def from_bigdawg(cls, bd, processes: int, **kwargs) -> "ProcPool":
        """Lift an in-process middleware into a pool: same state paths (so
        the workers inherit its persisted monitor/plan-cache warmth), same
        catalog (shard placements preserved), same resilience posture."""
        pool = cls(processes=processes, state_path=bd.monitor.path,
                   resilient=bd.health is not None,
                   train_plans=bd.train_plans,
                   explore_budget=bd.explore_budget, **kwargs)
        part_target: Dict[str, int] = {}
        for name, info in bd.sharded.items():
            pool.sharded[name] = info
            for i in range(info.n_shards):
                part_target[shard_name(name, i)] = i % processes
        for name, entry in bd.catalog.items():
            pool._register_one(name, tables.host_copy(entry.obj),
                               entry.engine, part_target.get(name),
                               streaming=getattr(entry, "streaming", False))
        return pool

    # -- serving -------------------------------------------------------------
    @property
    def breaker_trips(self) -> int:
        return self.health.trips()

    def persist(self) -> None:
        """Ask every worker to flush its monitor/calibration/plan-cache —
        the merge-on-save protocol interleaves them safely."""
        for idx in range(self.n):
            h = self.workers[idx]
            try:
                self._rpc(h, "persist")
            except _WorkerDied:
                self._respawn(idx, h)      # nothing to retry: a dead worker's
                #                            unflushed deltas died with it
        self.metrics.save()

    def ping(self) -> List[Optional[int]]:
        """Liveness probe: worker pids (None where a worker had to be
        respawned to answer)."""
        out: List[Optional[int]] = []
        for idx in range(self.n):
            h = self.workers[idx]
            try:
                out.append(self._rpc(h, "ping", timeout=self.start_timeout_s))
            except _WorkerDied:
                self._respawn(idx, h)
                out.append(None)
        return out

    def execute(self, query: PolyOp, mode: str = "auto", *,
                degrade: bool = False,
                trace_ctx: Optional[Tuple[str, Optional[str]]] = None):
        """The serving entry point ``QueryServer``/``Session`` call.
        Scatter–gather when the query decomposes over sharded registrations
        and the pricing says it pays; otherwise round-robin to one worker.
        Worker death is retried on a respawned replacement up to
        ``retries`` times, then surfaces as ``EngineDown`` — requests are
        never lost to a crash and never hang past the timeout.

        With tracing on (``trace=True`` in the pool's bigdawg kwargs) —
        or a propagated ``trace_ctx`` — the Report carries ONE connected
        trace: the master's request/queue_wait/worker_dispatch (and
        gather_fold / respawn) spans plus every worker-side span, all
        under the same trace id."""
        if self._closed:
            raise RuntimeError("ProcPool is closed")
        trace = self.tracer.start(trace_ctx)
        span = trace.root("request", mode=mode, pool=self.n) \
            if trace is not None else None
        try:
            sg = shardplan.analyze_catalog(query, self.sharded)
            if sg is not None and self._scatter_worthwhile(query, sg):
                rep = self._execute_scatter(sg, mode, degrade, span=span)
            else:
                rep = self._execute_one(query, mode, degrade, span=span)
        finally:
            if span is not None:
                span.end()
        if trace is not None:
            rep.trace = trace
        return rep

    def _execute_one(self, query: PolyOp, mode: str, degrade: bool,
                     span=None):
        idx = next(self._rr) % self.n
        tctx = span.trace.ctx(span) if span is not None else None
        for _attempt in range(self.retries + 1):
            h = self.workers[idx]
            try:
                self.metrics.counter("pool.dispatches")
                rep = self._rpc(h, "execute", query, mode, degrade, tctx,
                                span=span)
            except _WorkerDied:
                if span is not None:
                    span.event("respawn", worker=idx)
                self._respawn(idx, h)
                continue
            self.health.record_success(worker_channel(idx))
            if span is not None:
                # re-attach the worker's serialized spans (the retry serve
                # after a respawn lands here too — same trace id)
                span.trace.adopt(rep.trace)
                rep.trace = None
            return rep
        raise EngineDown(worker_channel(idx), "execute")

    def _execute_scatter(self, sg, mode: str, degrade: bool, span=None):
        """Fan the decomposition's fragments to their owning workers in
        parallel, merge in the master (numpy-only).  Fragment ``i`` is
        pinned to worker ``i % n`` — the only process holding shard ``i``'s
        rows — so a death retries the SAME index after respawn.

        The gather is incremental: each frame folds into an
        ``IncrementalGather`` accumulator the moment its worker replies and
        the per-shard payload is dropped, so the master's peak memory is
        the running accumulator (plus out-of-order stragglers), not the sum
        of every shard frame — and by the time the slowest worker answers,
        every other frame's merge work is already done."""
        t0 = time.perf_counter()
        gather = IncrementalGather(sg.merge, sg.n_shards, by=sg.merge_by,
                                   span=span)
        # Report metadata survives the payload drop: (cast_bytes, mode,
        # cache_hit, failovers, degraded) per shard, plus shard 0's Report
        # (payload stripped) as the roll-up base
        metas: List[Optional[Tuple]] = [None] * sg.n_shards
        first_rep: List[Any] = [None]
        errs: List[Optional[BaseException]] = [None] * sg.n_shards
        tctx = span.trace.ctx(span) if span is not None else None

        def run(i: int) -> None:
            frag = sg.fragment(i)
            idx = i % self.n
            for _attempt in range(self.retries + 1):
                h = self.workers[idx]
                try:
                    self.metrics.counter("pool.dispatches")
                    rep = self._rpc(h, "execute", frag, mode, degrade, tctx,
                                    span=span)
                except _WorkerDied:
                    if span is not None:
                        span.event("respawn", worker=idx, shard=i)
                    self._respawn(idx, h)
                    continue
                except BaseException as exc:   # noqa: BLE001 — worker error
                    errs[i] = exc
                    return
                self.health.record_success(worker_channel(idx))
                if span is not None:
                    span.trace.adopt(rep.trace)
                metas[i] = (rep.cast_bytes, rep.mode, rep.cache_hit,
                            getattr(rep, "failovers", 0),
                            getattr(rep, "degraded", False))
                if i == 0:
                    first_rep[0] = replace(rep, result=None, trace=None)
                gather.add(i, rep.result)     # frees the frame once folded
                return
            errs[i] = EngineDown(worker_channel(idx), f"shard {i}")

        if self.n == 1:
            for i in range(sg.n_shards):
                run(i)
        else:
            threads = [threading.Thread(target=run, args=(i,), daemon=True)
                       for i in range(sg.n_shards)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        err = next((e for e in errs if e is not None), None)
        if err is not None:
            raise err
        merged = gather.result()
        self.metrics.counter("pool.scatter_serves")
        first = first_rep[0]
        return replace(
            first, result=merged,
            seconds=time.perf_counter() - t0,
            cast_bytes=float(sum(m[0] for m in metas)),
            mode="training" if any(m[1] == "training" for m in metas)
            else "production",
            cache_hit=all(m[2] for m in metas),
            per_node_seconds=dict(first.per_node_seconds),
            failovers=sum(m[3] for m in metas),
            degraded=any(m[4] for m in metas),
            shards=sg.n_shards)

    def _scatter_worthwhile(self, query: PolyOp, sg) -> bool:
        """Gate the scatter path on the planner's price (cached per
        signature).  Pricing is advisory: any modeling failure falls back
        to scattering — the decomposition is already proven valid."""
        if self.scatter == "always":
            return True
        if self.scatter == "never":
            return False
        sig = signature(query, self.catalog)
        cached = self._scatter_cache.get(sig)
        if cached is not None:
            return cached
        try:
            from repro.core import planner
            if self._cost_model is None:
                from repro.core.costmodel import (CostModel,
                                                  default_calibration_path)
                self._cost_model = CostModel(
                    default_calibration_path(self.state_path))
            price = planner.price_scatter_gather(
                query, sg.fragment(0), catalog=self.catalog,
                n_shards=sg.n_shards, workers=self.n,
                cost_model=self._cost_model)
            ok = bool(price.worthwhile)
        except Exception:                  # noqa: BLE001 — advisory only
            ok = True
        self._scatter_cache[sig] = ok
        return ok
