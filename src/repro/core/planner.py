"""Query planner (paper §III-C-1): parse the PolyOp DAG into *containers*
(maximal subtrees executable on one engine) plus the cross-engine *remainder*,
then enumerate candidate plan trees (engine assignments per container).

Candidate ordering: fewest casts first, then data-home affinity.  The monitor
re-orders these with measured history in production phase.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

from repro.core import cast as castmod
from repro.core.islands import ISLANDS
from repro.core.engines import ENGINES
from repro.core.ops import PolyOp, Ref


@dataclass(frozen=True)
class Plan:
    """Engine assignment per op node, keyed by *post-order position* — stable
    across structurally-identical query rebuilds (unlike object identity), so
    monitor-stored plan keys apply to re-issued queries (paper §III-C-3)."""
    assignment: Tuple[Tuple[int, str], ...]

    @property
    def key(self) -> str:
        return "|".join(f"{u}:{e}" for u, e in self.assignment)

    def engine_map(self, query: PolyOp) -> Dict[int, str]:
        """node uid -> engine, for this specific query instance."""
        amap = dict(self.assignment)
        return {n.uid: amap[i] for i, n in enumerate(query.nodes())}

    def describe(self, query: PolyOp) -> str:
        amap = dict(self.assignment)
        return " ".join(f"{n.op}@{amap[i]}"
                        for i, n in enumerate(query.nodes()))


def node_candidates(node: PolyOp) -> Sequence[str]:
    return ISLANDS[node.island].candidates(node.op)


@dataclass
class ContainerInfo:
    nodes: List[PolyOp] = field(default_factory=list)
    candidates: Tuple[str, ...] = ()


def find_containers(query: PolyOp) -> List[ContainerInfo]:
    """Greedy bottom-up grouping: merge a node into its child's container when
    they share a candidate engine; otherwise start a new container (a cast
    edge — part of the remainder)."""
    containers: List[ContainerInfo] = []
    owner: Dict[int, int] = {}            # node uid -> container index

    for node in query.nodes():            # post-order
        cands = tuple(node_candidates(node))
        merged = False
        for inp in node.inputs:
            if isinstance(inp, PolyOp):
                ci = owner[inp.uid]
                shared = tuple(e for e in containers[ci].candidates
                               if e in cands)
                if shared and not merged:
                    containers[ci].nodes.append(node)
                    containers[ci].candidates = shared
                    owner[node.uid] = ci
                    merged = True
        if not merged:
            containers.append(ContainerInfo([node], cands))
            owner[node.uid] = len(containers) - 1
    return containers


def _home_affinity(container: ContainerInfo, engine: str, catalog) -> int:
    """Number of referenced objects already resident on `engine`."""
    n = 0
    for node in container.nodes:
        for inp in node.inputs:
            if isinstance(inp, Ref) and catalog is not None \
                    and inp.name in catalog:
                if catalog[inp.name].engine == engine:
                    n += 1
    return n


def enumerate_plans(query: PolyOp, catalog=None, max_plans: int = 16) -> List[Plan]:
    """Per-node engine assignment product (capped).  Containers (single-engine
    runs) emerge from the assignment; keeping the product at node granularity
    preserves hybrid plans that container-first merging would lose."""
    nodes = query.nodes()
    per_node: List[List[str]] = []
    for n in nodes:
        cands = list(node_candidates(n))
        c = ContainerInfo([n], tuple(cands))
        cands.sort(key=lambda e: -_home_affinity(c, e, catalog))
        per_node.append(cands)

    plans = []
    for combo in itertools.product(*per_node):
        plans.append(Plan(tuple((i, e) for i, e in enumerate(combo))))
        if len(plans) >= max_plans:
            break

    # fewest-cast plans first
    plans.sort(key=lambda p: estimate_casts(query, p, catalog))
    return plans


def estimate_casts(query: PolyOp, plan: Plan, catalog=None) -> float:
    """Planner-side cost: seconds of cast traffic a plan implies."""
    amap = plan.engine_map(query)
    cost = 0.0
    for node in query.nodes():
        eng = ENGINES[amap[node.uid]]
        for inp in node.inputs:
            if isinstance(inp, PolyOp):
                src = ENGINES[amap[inp.uid]]
                if src.kind != eng.kind:
                    cost += 1e-6  # structural penalty; real bytes unknown pre-run
            elif catalog is not None and inp.name in catalog:
                entry = catalog[inp.name]
                src_kind = ENGINES[entry.engine].kind
                cost += castmod.cast_cost_seconds(entry.obj, eng.kind) \
                    if src_kind != eng.kind else 0.0
    return cost
