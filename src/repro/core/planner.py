"""Query planner (paper §III-C-1): collapse the PolyOp DAG into *containers*
(maximal runs executable on one engine) plus the cross-engine *remainder*,
then run a k-best dynamic program over the cast edges with a calibrated cost
model (predicted op seconds + predicted cast seconds from estimated container
sizes).

The DP considers the FULL container-assignment space — unlike the seed's
``itertools.product`` prefix, which was biased toward the first node's
candidates and truncated anything past 16 combos.  Containers are formed
*losslessly* (nodes merge only when their candidate engine sets are equal), so
every hybrid plan a node-granularity product could express at container
boundaries survives; splitting an equal-candidate run across engines is the
one shape dropped, and it always pays an extra cast for zero coverage gain.
The monitor still re-orders the survivors with measured history in production
phase (paper §III-C-3).
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.core.costmodel import (CostModel, container_elems,
                                  container_kind_nbytes,
                                  kind_nbytes_from_logical)
from repro.core.errors import PlanInfeasible
from repro.core.islands import ISLANDS, scope_candidates
from repro.core.engines import ENGINES
from repro.core.ops import SCOPE_OP, PolyOp, Ref

# the empty engine mask (planning with every engine available)
NO_MASK: FrozenSet[str] = frozenset()

_DEFAULT_COST_MODEL: Optional[CostModel] = None


def default_cost_model() -> CostModel:
    """Process-wide fallback model (uncalibrated defaults) for callers that
    plan outside a BigDAWG instance."""
    global _DEFAULT_COST_MODEL
    if _DEFAULT_COST_MODEL is None:
        _DEFAULT_COST_MODEL = CostModel()
    return _DEFAULT_COST_MODEL


@dataclass(frozen=True)
class Plan:
    """Engine assignment per op node, keyed by *post-order position* — stable
    across structurally-identical query rebuilds (unlike object identity), so
    monitor-stored plan keys apply to re-issued queries (paper §III-C-3)."""
    assignment: Tuple[Tuple[int, str], ...]

    @property
    def key(self) -> str:
        return "|".join(f"{u}:{e}" for u, e in self.assignment)

    def engine_map(self, query: PolyOp) -> Dict[int, str]:
        """node uid -> engine, for this specific query instance."""
        amap = dict(self.assignment)
        return {n.uid: amap[i] for i, n in enumerate(query.nodes())}

    def describe(self, query: PolyOp) -> str:
        amap = dict(self.assignment)
        return " ".join(f"{n.op}@{amap[i]}"
                        for i, n in enumerate(query.nodes()))


def node_candidates(node: PolyOp,
                    mask: FrozenSet[str] = NO_MASK) -> Sequence[str]:
    """Engines that can run ``node``, minus any in ``mask`` (tripped
    breakers / a degrade mask — see ``core.health``).  Raises
    ``PlanInfeasible`` when the mask eats the whole candidate set: no
    engine assignment containing this node can exist."""
    if node.op == SCOPE_OP:
        # an island boundary materializes on the target island's model-native
        # engines only — the DP's cast edge into this node is therefore the
        # inter-island cast, priced like any other edge (multi-hop routed,
        # sized per hop) by cast_seconds
        cands = scope_candidates(node.island)
    else:
        cands = ISLANDS[node.island].candidates(node.op)
    if not mask:
        return cands
    alive = [e for e in cands if e not in mask]
    if not alive:
        raise PlanInfeasible(node.op, node.island, masked=tuple(cands))
    return alive


@dataclass
class ContainerInfo:
    nodes: List[PolyOp] = field(default_factory=list)
    candidates: Tuple[str, ...] = ()


def find_containers(query: PolyOp) -> List[ContainerInfo]:
    """Greedy bottom-up grouping: merge a node into its child's container when
    they share a candidate engine; otherwise start a new container (a cast
    edge — part of the remainder).  Used for remainder analysis; the planner's
    DP uses the lossless ``plan_containers`` grouping instead."""
    containers: List[ContainerInfo] = []
    owner: Dict[int, int] = {}            # node uid -> container index

    for node in query.nodes():            # post-order
        cands = tuple(node_candidates(node))
        merged = False
        for inp in node.inputs:
            if isinstance(inp, PolyOp):
                ci = owner[inp.uid]
                shared = tuple(e for e in containers[ci].candidates
                               if e in cands)
                if shared and not merged:
                    containers[ci].nodes.append(node)
                    containers[ci].candidates = shared
                    owner[node.uid] = ci
                    merged = True
        if not merged:
            containers.append(ContainerInfo([node], cands))
            owner[node.uid] = len(containers) - 1
    return containers


# ---------------------------------------------------------------------------
# size estimation — predicted output bytes per node, from catalog shapes
# ---------------------------------------------------------------------------

_SCALAR_OPS = {"count", "distinct"}


def _ref_size(ref: Ref, catalog) -> Tuple[float, Optional[Tuple[int, ...]]]:
    """(logical bytes, shape) of a catalog object.  LOGICAL: 4 bytes per
    container_elems unit, the same unit op rates are observed in — a columnar
    home's 3x physical triples blow-up must not inflate predicted op work
    (cast costs use physical nbytes separately)."""
    if catalog is not None and ref.name in catalog:
        obj = catalog[ref.name].obj
        data = getattr(obj, "data", None)
        shape = tuple(data.shape) if data is not None else \
            tuple(getattr(obj, "shape", ()) or ()) or None
        return 4.0 * container_elems(obj), shape
    return 4096.0, None                   # unknown object: assume a small page


def estimate_sizes_shapes(query: PolyOp, catalog=None,
                          measured: Optional[Dict[int, float]] = None,
                          measured_shapes: Optional[Dict[int, Tuple[int, ...]]]
                          = None
                          ) -> Tuple[Dict[int, float],
                                     Dict[int, Optional[Tuple[int, ...]]]]:
    """(uid -> predicted output bytes, uid -> predicted output shape),
    propagated bottom-up with per-op rules (shape-aware where the catalog
    gives real shapes).

    ``measured`` — actual logical output bytes per post-order position, from
    ``Monitor.measured_sizes`` — overrides the bytes rule for any node it
    covers; downstream propagation then builds on the observed value.  This
    is the size-feedback half of the §III-C monitor loop: ops whose output is
    data-dependent (select, join, distinct) get real sizes on re-plans.

    ``measured_shapes`` — actual dense-equivalent output shapes per
    post-order position, from ``Monitor.measured_shapes`` — overrides the
    propagated shape the same way, so downstream shape-driven rules (matmul,
    transpose, bin_hist) build on observed geometry, not just observed
    bytes."""
    nbytes: Dict[int, float] = {}
    shapes: Dict[int, Optional[Tuple[int, ...]]] = {}

    for pos, node in enumerate(query.nodes()):   # post-order: inputs done
        ins: List[Tuple[float, Optional[Tuple[int, ...]]]] = []
        for inp in node.inputs:
            if isinstance(inp, Ref):
                ins.append(_ref_size(inp, catalog))
            else:
                ins.append((nbytes[inp.uid], shapes.get(inp.uid)))
        in_bytes = [b for b, _ in ins] or [4096.0]
        out_b, out_s = max(in_bytes), (ins[0][1] if ins else None)

        op = node.op
        if op in _SCALAR_OPS:
            out_b, out_s = 8.0, ()
        elif op == "matmul" and len(ins) == 2:
            s1, s2 = ins[0][1], ins[1][1]
            if s1 and s2 and len(s1) == 2 and len(s2) == 2:
                out_s = (s1[0], s2[1])
                out_b = 4.0 * s1[0] * s2[1]
        elif op in ("spmm",) and len(ins) == 2:
            out_b, out_s = ins[1][0], ins[1][1]
        elif op == "transpose":
            if out_s and len(out_s) == 2:
                out_s = (out_s[1], out_s[0])
        elif op == "knn":
            out_b, out_s = 4.0 * node.attrs.get("k", 8), None
        elif op == "window_agg":
            s = ins[0][1]
            out_b = 4.0 * s[0] if s else in_bytes[0] / 16.0
            out_s = (s[0],) if s else None
        elif op == "bin_hist":
            s = ins[0][1]
            width = node.attrs.get("nbins", 16) * (node.attrs.get("levels", 1) + 1)
            if s:
                out_s = (s[0], width)
                out_b = 4.0 * s[0] * width
        elif op == "project":
            out_b = in_bytes[0] * 0.5
        elif op == "concat":
            out_b = float(sum(in_bytes))
            s1, s2 = (ins[0][1], ins[1][1]) if len(ins) == 2 else (None, None)
            out_s = (s1[0] + s2[0],) + tuple(s1[1:]) \
                if s1 and s2 and len(s1) == len(s2) and s1[1:] == s2[1:] \
                else None
        # select/haar/tfidf/scale/add/join/groupby_sum/ingest/to_array:
        # output ~ input size (the max-input default).  scope (island
        # boundary) is the identity on logical content — the single-input
        # default already passes bytes and shape through unchanged.

        if measured is not None and pos in measured:
            out_b = measured[pos]        # observation beats any bytes rule
        if measured_shapes is not None and pos in measured_shapes:
            out_s = tuple(measured_shapes[pos])   # ... and any shape rule
        nbytes[node.uid] = max(out_b, 4.0)
        shapes[node.uid] = out_s
    return nbytes, shapes


def estimate_sizes(query: PolyOp, catalog=None,
                   measured: Optional[Dict[int, float]] = None,
                   measured_shapes: Optional[Dict[int, Tuple[int, ...]]] = None
                   ) -> Dict[int, float]:
    """uid -> predicted output bytes (see ``estimate_sizes_shapes``, which
    also returns the propagated shapes the cast-edge sizing uses)."""
    return estimate_sizes_shapes(query, catalog, measured, measured_shapes)[0]


def _edge_kind_nbytes(logical_bytes: float,
                      shape: Optional[Tuple[int, ...]]) -> Dict[str, float]:
    """Per-kind physical bytes of a node-output payload crossing a cast edge
    (the planner-side analogue of ``container_kind_nbytes`` for objects that
    do not exist yet)."""
    return kind_nbytes_from_logical(logical_bytes, shape)


def _work_elems(node: PolyOp, sizes: Dict[int, float], catalog) -> float:
    """INPUT elements an op must touch, in float32 units — the same unit the
    executor and calibration observe rates in (elems of the args, before the
    op runs), so predicted seconds = elems / learned_rate is dimensionally
    honest."""
    total = 0.0
    for inp in node.inputs:
        if isinstance(inp, Ref):
            total += _ref_size(inp, catalog)[0]
        else:
            total += sizes[inp.uid]
    return total / 4.0


# ---------------------------------------------------------------------------
# lossless planning containers + cast-edge DP
# ---------------------------------------------------------------------------

@dataclass
class PlanContainer:
    """A maximal run of nodes with *identical* candidate sets (lossless:
    container-level assignment spans the same plan space as node-level at
    every cast boundary)."""
    positions: List[int]                       # post-order indices
    nodes: List[PolyOp]
    candidates: Tuple[str, ...]
    children: List[Tuple[int, float, Optional[Tuple[int, ...]]]] = \
        field(default_factory=list)
    # (child container index, predicted bytes over that cast edge, predicted
    #  dense-equivalent shape of the crossing payload — sizes the cast's
    #  per-format hops)


def plan_containers(query: PolyOp, catalog=None,
                    sizes: Optional[Dict[int, float]] = None,
                    shapes: Optional[Dict[int, Optional[Tuple[int, ...]]]]
                    = None,
                    mask: FrozenSet[str] = NO_MASK) -> List[PlanContainer]:
    """Containers over the query's TREE UNFOLDING: ownership is tracked per
    post-order *occurrence*, not per node uid, so shared subtrees (which the
    executor and ``plan_cost`` both account once per occurrence) contract to
    a tree of containers — no cycles, no double-visited children.  The owner
    of position ``p`` is the container whose ``positions`` include ``p``."""
    if sizes is None:
        sizes, shapes = estimate_sizes_shapes(query, catalog)
    shapes = shapes or {}
    containers: List[PlanContainer] = []
    owner_by_pos: Dict[int, int] = {}
    counter = itertools.count()

    def visit(node: PolyOp) -> int:
        child_pos = [(visit(i), i) for i in node.inputs
                     if isinstance(i, PolyOp)]
        pos = next(counter)                    # == post-order walk position
        cands = tuple(node_candidates(node, mask))
        ci_own = None
        edges: List[Tuple[int, float, Optional[Tuple[int, ...]]]] = []
        for p, inp in child_pos:
            ci = owner_by_pos[p]
            if ci_own is None and containers[ci].candidates == cands:
                containers[ci].positions.append(pos)
                containers[ci].nodes.append(node)
                ci_own = ci
            else:
                edges.append((ci, sizes[inp.uid], shapes.get(inp.uid)))
        if ci_own is None:
            containers.append(PlanContainer([pos], [node], cands))
            ci_own = len(containers) - 1
        owner_by_pos[pos] = ci_own
        containers[ci_own].children.extend(
            (d, b, s) for d, b, s in edges if d != ci_own)
        return pos

    visit(query)
    return containers


def _intra_cost(c: PlanContainer, engine: str, sizes, catalog,
                cm: CostModel) -> float:
    """Op seconds for the container's nodes on `engine`, plus casts pulling
    catalog refs homed on a different data model."""
    kind = ENGINES[engine].kind
    cost = 0.0
    for node in c.nodes:
        cost += cm.op_seconds(engine, node.op, _work_elems(node, sizes, catalog))
        for inp in node.inputs:
            if isinstance(inp, Ref) and catalog is not None \
                    and inp.name in catalog:
                entry = catalog[inp.name]
                src_kind = ENGINES[entry.engine].kind
                cost += cm.cast_seconds(src_kind, kind, entry.obj.nbytes,
                                        container_kind_nbytes(entry.obj))
    return cost


def dp_plans(query: PolyOp, catalog=None, max_plans: int = 16,
             cost_model: Optional[CostModel] = None,
             measured_sizes: Optional[Dict[int, float]] = None,
             measured_shapes: Optional[Dict[int, Tuple[int, ...]]] = None,
             mask: FrozenSet[str] = NO_MASK) -> List[Tuple[float, Plan]]:
    """Exact k-best DP over the container tree: for every container and engine
    choice, combine the k cheapest child subplans through the cast edge cost.
    Covers the full container-assignment product (no truncation bias).

    Cast edges are costed by ``CostModel.cast_seconds``, which routes
    multi-hop over the calibrated cast graph — a coo->dense->columnar detour
    beats a direct pair measured slow — with every hop sized from its
    intermediate format.  ``measured_sizes`` / ``measured_shapes`` (from
    ``Monitor.measured_sizes`` / ``measured_shapes``) replace rule-derived
    estimates with actual intermediate sizes and shapes wherever the
    signature has execution history.

    ``mask`` excludes engines from every candidate set (failover
    re-planning around tripped circuit breakers); a mask that leaves some
    op with no engine raises ``PlanInfeasible``."""
    cm = cost_model or default_cost_model()
    sizes, shapes = estimate_sizes_shapes(query, catalog,
                                          measured=measured_sizes,
                                          measured_shapes=measured_shapes)
    containers = plan_containers(query, catalog, sizes=sizes, shapes=shapes,
                                 mask=mask)
    k = max(1, max_plans)

    pos_owner: Dict[int, int] = {}
    for ci, c in enumerate(containers):
        for p in c.positions:
            pos_owner[p] = ci
    n_pos = len(query.nodes())
    root_ci = pos_owner[n_pos - 1]

    # merging a node into an *earlier* child's container can leave edges to
    # higher-indexed containers, so process the container tree bottom-up
    # explicitly rather than by list index
    order: List[int] = []
    seen_ci = set()

    def _order(ci: int):
        if ci in seen_ci:
            return
        seen_ci.add(ci)
        for di, _, _ in containers[ci].children:
            _order(di)
        order.append(ci)

    _order(root_ci)

    # kbest[ci] = sorted [(cost, {container_idx: engine})], child-closed
    kbest: Dict[int, List[Tuple[float, Dict[int, str]]]] = {}
    for ci in order:                           # children precede parents
        c = containers[ci]
        options: List[Tuple[float, Dict[int, str]]] = []
        for e in c.candidates:
            kind = ENGINES[e].kind
            combos = [(_intra_cost(c, e, sizes, catalog, cm), {ci: e})]
            for (di, edge_bytes, edge_shape) in c.children:
                edge_kn = _edge_kind_nbytes(edge_bytes, edge_shape)
                merged: List[Tuple[float, Dict[int, str]]] = []
                for cc, asg in combos:
                    for cd, asg_d in kbest[di]:
                        f = asg_d[di]
                        cast = cm.cast_seconds(ENGINES[f].kind, kind,
                                               edge_bytes, edge_kn)
                        merged.append((cc + cd + cast, {**asg, **asg_d}))
                merged.sort(key=lambda t: t[0])
                combos = merged[:k]
            options.extend(combos)
        # keep the top-k PER ENGINE (not a global cut): a parent's cast term
        # depends on this container's engine, so truncating away every plan
        # that ends on some engine could hide the global optimum behind an
        # expensive cast.  Per-engine fronts make the root's k-front exact.
        options.sort(key=lambda t: t[0])
        kbest[ci] = options

    # Execution collapses all occurrences of a shared node to ONE engine
    # (Plan.engine_map is uid-keyed, last occurrence wins), so on DAGs with
    # shared subtrees the per-occurrence DP is a candidate generator: collapse
    # each assignment to uid-consistent engines and re-cost under the executed
    # semantics.  For trees this whole step is the identity.
    nodes = query.nodes()
    has_shared = len({n.uid for n in nodes}) != len(nodes)
    out: List[Tuple[float, Plan]] = []
    seen = set()
    for cost, asg in kbest[root_ci]:
        plan = Plan(tuple((p, asg[pos_owner[p]]) for p in range(n_pos)))
        if has_shared:
            amap = plan.engine_map(query)
            plan = Plan(tuple((p, amap[nodes[p].uid]) for p in range(n_pos)))
            cost = plan_cost(query, plan, catalog, cm, sizes=sizes,
                             shapes=shapes)
        if plan.key not in seen:
            seen.add(plan.key)
            out.append((cost, plan))
    out.sort(key=lambda t: t[0])
    return out[:k]


def exhaustive_plans(query: PolyOp, catalog=None,
                     cost_model: Optional[CostModel] = None,
                     measured_sizes: Optional[Dict[int, float]] = None,
                     measured_shapes: Optional[Dict[int, Tuple[int, ...]]]
                     = None,
                     mask: FrozenSet[str] = NO_MASK
                     ) -> List[Tuple[float, Plan]]:
    """Brute-force reference over the container assignment product, costed
    with the same model — the DP must agree with this on small DAGs (masked
    or not)."""
    cm = cost_model or default_cost_model()
    sizes, shapes = estimate_sizes_shapes(query, catalog,
                                          measured=measured_sizes,
                                          measured_shapes=measured_shapes)
    containers = plan_containers(query, catalog, sizes=sizes, shapes=shapes,
                                 mask=mask)
    pos_owner = {p: ci for ci, c in enumerate(containers) for p in c.positions}
    nodes = query.nodes()
    out, seen = [], set()
    for combo in itertools.product(*(c.candidates for c in containers)):
        plan = Plan(tuple((p, combo[pos_owner[p]])
                          for p in range(len(nodes))))
        amap = plan.engine_map(query)            # collapse shared nodes, as
        plan = Plan(tuple((p, amap[nodes[p].uid])  # execution will
                          for p in range(len(nodes))))
        if plan.key in seen:
            continue
        seen.add(plan.key)
        out.append((plan_cost(query, plan, catalog, cm, sizes=sizes,
                              shapes=shapes), plan))
    out.sort(key=lambda t: t[0])
    return out


def plan_cost(query: PolyOp, plan: Plan, catalog=None,
              cost_model: Optional[CostModel] = None,
              sizes: Optional[Dict[int, float]] = None,
              shapes: Optional[Dict[int, Optional[Tuple[int, ...]]]] = None
              ) -> float:
    """Predicted seconds for an arbitrary assignment: per-node op seconds plus
    cast seconds on every model-crossing edge (node-node and ref-node), each
    cast's hops sized from the format the payload is in at that hop.
    ``sizes``/``shapes`` (from ``estimate_sizes_shapes``) are
    plan-independent — pass them in when costing many plans of one query."""
    cm = cost_model or default_cost_model()
    if sizes is None:
        sizes, shapes = estimate_sizes_shapes(query, catalog)
    shapes = shapes or {}
    amap = plan.engine_map(query)
    cost = 0.0
    for node in query.nodes():
        eng = ENGINES[amap[node.uid]]
        cost += cm.op_seconds(eng.name, node.op,
                              _work_elems(node, sizes, catalog))
        for inp in node.inputs:
            if isinstance(inp, PolyOp):
                src = ENGINES[amap[inp.uid]]
                cost += cm.cast_seconds(
                    src.kind, eng.kind, sizes[inp.uid],
                    _edge_kind_nbytes(sizes[inp.uid], shapes.get(inp.uid)))
            elif catalog is not None and inp.name in catalog:
                entry = catalog[inp.name]
                src_kind = ENGINES[entry.engine].kind
                cost += cm.cast_seconds(src_kind, eng.kind, entry.obj.nbytes,
                                        container_kind_nbytes(entry.obj))
    return cost


def enumerate_plans(query: PolyOp, catalog=None, max_plans: int = 16,
                    cost_model: Optional[CostModel] = None,
                    measured_sizes: Optional[Dict[int, float]] = None,
                    measured_shapes: Optional[Dict[int, Tuple[int, ...]]]
                    = None) -> List[Plan]:
    """Top-``max_plans`` candidate plans by predicted cost, from the k-best
    container DP (full assignment space, cheapest first)."""
    return [p for _, p in dp_plans(query, catalog, max_plans, cost_model,
                                   measured_sizes=measured_sizes,
                                   measured_shapes=measured_shapes)]


# ---------------------------------------------------------------------------
# scatter–gather pricing (partitioned execution over row-range shards)
# ---------------------------------------------------------------------------

# master-side merge throughput (numpy concat / sum / heap merge) and the
# per-fragment pickle+pipe round-trip floor — both deliberately coarse: the
# decision they gate (scatter vs single worker) only needs the right order
# of magnitude, and the procpool can override per deployment
MERGE_BYTES_PER_S = 2e9
IPC_OVERHEAD_S = 2e-3


@dataclass
class ScatterGatherPrice:
    """Predicted seconds for both execution shapes of one sharded query —
    what ``procpool`` compares to choose scatter–gather vs a single worker."""
    sharded_s: float
    unsharded_s: float
    fragment_s: float        # one fragment on one worker
    merge_s: float           # master-side gather
    ipc_s: float             # total dispatch round-trip overhead

    @property
    def worthwhile(self) -> bool:
        return self.sharded_s < self.unsharded_s


def price_scatter_gather(query: PolyOp, fragment: PolyOp, catalog=None,
                         n_shards: int = 1, workers: int = 1,
                         cost_model: Optional[CostModel] = None,
                         measured_sizes: Optional[Dict[int, float]] = None,
                         measured_shapes: Optional[Dict[int, Tuple[int, ...]]]
                         = None,
                         ipc_overhead_s: float = IPC_OVERHEAD_S,
                         merge_bytes_per_s: float = MERGE_BYTES_PER_S
                         ) -> ScatterGatherPrice:
    """Price the scatter–gather plan shape against the unsharded best plan.

    The fragment's cost comes from the same k-best DP, run against the
    per-shard catalog entries (``A#i`` is ~1/N the rows of ``A``, so the DP's
    size rules price the smaller operands naturally).  Fragments run on
    distinct workers, so wall-clock fragment time is one fragment per round
    of ``workers`` concurrent shards; the gather adds the merged payload over
    the master's merge throughput, and each dispatched fragment pays one IPC
    round-trip."""
    cm = cost_model or default_cost_model()
    unsharded = dp_plans(query, catalog, max_plans=1, cost_model=cm,
                         measured_sizes=measured_sizes,
                         measured_shapes=measured_shapes)[0][0]
    fragment_s = dp_plans(fragment, catalog, max_plans=1,
                          cost_model=cm)[0][0]
    sizes, _ = estimate_sizes_shapes(query, catalog, measured=measured_sizes,
                                     measured_shapes=measured_shapes)
    root_bytes = sizes[query.nodes()[-1].uid]
    rounds = math.ceil(n_shards / max(1, workers))
    merge_s = root_bytes / max(merge_bytes_per_s, 1.0)
    ipc_s = n_shards * ipc_overhead_s
    sharded = rounds * fragment_s + merge_s + ipc_s
    return ScatterGatherPrice(sharded_s=sharded, unsharded_s=unsharded,
                              fragment_s=fragment_s, merge_s=merge_s,
                              ipc_s=ipc_s)


@dataclass(frozen=True)
class IncrementalPrice:
    """Predicted seconds for both ways of serving a streaming signature
    after an append — what the middleware's IVM gate compares.  ``delta_s``
    is the update fragment against the pending delta rows, ``patch_s`` the
    view patch (concat/sum/kmerge over the materialized bytes), ``full_s``
    the cached plan's full recompute baseline (the plan-cache entry's
    prediction, which the replan loop keeps synced to measured serves)."""
    delta_s: float
    patch_s: float
    full_s: float

    @property
    def worthwhile(self) -> bool:
        return self.delta_s + self.patch_s < self.full_s


def price_incremental(fragment: PolyOp, catalog=None,
                      cost_model: Optional[CostModel] = None,
                      view_bytes: float = 0.0, full_s: float = 0.0,
                      merge_bytes_per_s: float = MERGE_BYTES_PER_S,
                      mask: FrozenSet[str] = NO_MASK
                      ) -> Tuple[IncrementalPrice, Plan]:
    """Price the incremental update path for one append and return the
    fragment's plan alongside.  The fragment is costed by the same k=1 DP
    every other cheap re-plan uses, against the TEMPORARY catalog whose
    ``@delta`` entries hold the pending suffix rows — the calibrated size
    rules price the small operands naturally, so a tiny delta prices tiny
    and a delta that dominates the base prices accordingly.  The patch is
    the materialized view plus the delta result through the master-side
    merge throughput (same coarse constant the scatter-gather gate uses:
    the decision only needs the right order of magnitude).

    ``mask`` restricts the fragment's engines: the middleware passes
    everything OUTSIDE the incumbent full plan's engine set, so the delta
    runs only through engine/cast paths the full serve already validated —
    delta operands are tiny, and unconstrained the DP happily flips to a
    cast-heavy placement the incumbent never exercised."""
    cm = cost_model or default_cost_model()
    ranked = dp_plans(fragment, catalog, max_plans=1, cost_model=cm,
                      mask=mask)
    delta_s, fplan = ranked[0]
    sizes, _ = estimate_sizes_shapes(fragment, catalog)
    delta_out = sizes[fragment.nodes()[-1].uid]
    patch_s = (float(view_bytes) + delta_out) / max(merge_bytes_per_s, 1.0)
    return IncrementalPrice(delta_s=delta_s, patch_s=patch_s,
                            full_s=float(full_s)), fplan


def estimate_casts(query: PolyOp, plan: Plan, catalog=None,
                   cost_model: Optional[CostModel] = None) -> float:
    """Planner-side cast cost: predicted seconds of cast traffic a plan
    implies (model-crossing edges only, sized from the catalog)."""
    cm = cost_model or default_cost_model()
    sizes, shapes = estimate_sizes_shapes(query, catalog)
    amap = plan.engine_map(query)
    cost = 0.0
    for node in query.nodes():
        eng = ENGINES[amap[node.uid]]
        for inp in node.inputs:
            if isinstance(inp, PolyOp):
                src = ENGINES[amap[inp.uid]]
                cost += cm.cast_seconds(
                    src.kind, eng.kind, sizes[inp.uid],
                    _edge_kind_nbytes(sizes[inp.uid], shapes.get(inp.uid)))
            elif catalog is not None and inp.name in catalog:
                entry = catalog[inp.name]
                src_kind = ENGINES[entry.engine].kind
                cost += cm.cast_seconds(src_kind, eng.kind, entry.obj.nbytes,
                                        container_kind_nbytes(entry.obj))
    return cost
