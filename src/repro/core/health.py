"""Per-engine health tracking: circuit breakers + straggler watch.

The paper's monitor exists because "the plan that was optimal under
training-time conditions" stops being optimal when an engine slows or dies;
this module is the serving stack's account of that state.  One
``CircuitBreaker`` per engine follows the classic three-state protocol:

    CLOSED ──(failure_threshold consecutive failures)──> OPEN
    OPEN ──(cooldown elapses)──> HALF_OPEN
    HALF_OPEN ──(probe succeeds)──> CLOSED
    HALF_OPEN ──(probe fails)──> OPEN            (cooldown restarts)

While a breaker is OPEN its engine is *masked*: ``mask_for_request`` returns
it in the excluded set and the middleware re-runs the cheap planning DP with
that engine removed (failover re-planning — see ``BigDAWG._serve_masked``).
In HALF_OPEN exactly one request at a time is granted a *probe*: the engine
is left OUT of that request's mask, so the request is planned as if the
engine recovered (normally the cached incumbent plan).  Success closes the
breaker — and because masked serves were recorded under a mask-suffixed
signature, ``monitor.best`` still names the incumbent, which is therefore
restored verbatim.  Failure re-opens the breaker and the cooldown restarts.

Failures reach the breaker through two channels:

* the executor: an engine op or an input cast that dies with an
  infrastructure-shaped exception (``errors.is_engine_failure``) calls
  ``record_failure`` and re-raises as ``EngineDown``;
* the straggler watch: after every successful plan the middleware feeds the
  per-node seconds to ``after_plan``; a per-engine ``StragglerDetector``
  (Welford z-score over that engine's node times) flags pathological
  slowness, which counts as a breaker failure — a silently-slow engine trips
  the same way a crashing one does (timeout-equivalent).  Unflagged nodes
  count as successes and reset the consecutive-failure run.

The registry takes one lock around all state; every operation is O(engines)
dict work, so contention on the serve path is negligible.  ``time_fn`` is
injectable so breaker tests can step a fake clock through the cooldown.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Optional, Tuple

from repro.core.engines import ENGINES

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"

# engines the degrade path may still use: the "always-up" pair every island
# can reach (dense_array is the device-native home, columnar the relational
# one) — ``EngineHealth(always_up=...)`` overrides
DEFAULT_ALWAYS_UP = ("dense_array", "columnar")


@dataclass
class CircuitBreaker:
    """One engine's breaker.  NOT internally locked — every mutation happens
    under the owning ``EngineHealth`` registry lock."""
    engine: str
    failure_threshold: int = 3
    cooldown_s: float = 5.0
    state: str = CLOSED
    consecutive_failures: int = 0
    opened_at: float = 0.0
    trips: int = 0                    # lifetime CLOSED/HALF_OPEN -> OPEN count
    probe_inflight: bool = False      # HALF_OPEN: one probe grant at a time

    def poll(self, now: float) -> str:
        """Advance time-driven transitions (OPEN -> HALF_OPEN after the
        cooldown) and return the current state."""
        if self.state == OPEN and now - self.opened_at >= self.cooldown_s:
            self.state = HALF_OPEN
            self.probe_inflight = False
        return self.state

    def on_failure(self, now: float) -> bool:
        """Record one failure; returns True when this failure tripped the
        breaker open.  A HALF_OPEN probe failure re-opens immediately —
        the engine just proved it is still down."""
        self.consecutive_failures += 1
        if self.state == HALF_OPEN or (
                self.state == CLOSED
                and self.consecutive_failures >= self.failure_threshold):
            self.state = OPEN
            self.opened_at = now
            self.probe_inflight = False
            self.trips += 1
            return True
        return False

    def on_success(self):
        """Record one success: resets the consecutive-failure run and closes
        the breaker from HALF_OPEN (the probe came back healthy)."""
        self.consecutive_failures = 0
        if self.state == HALF_OPEN:
            self.state = CLOSED
            self.probe_inflight = False


class EngineHealth:
    """The per-engine breaker registry the serving stack consults.

    ``injector`` is an optional fault source with a
    ``before_op(engine, op)`` hook (see ``runtime.fault.EngineFaultInjector``)
    the executor fires before every engine op — the seam through which tests
    and benchmarks take an engine down mid-serve without touching engine
    code.

    Straggler defaults are deliberately conservative (``straggler_z=6``):
    node times on a healthy serve path vary with cache state and host load,
    and a false straggler trip would fail over AWAY from the fastest engine —
    strictly worse than tolerating a slow request.
    """

    def __init__(self, failure_threshold: int = 3, cooldown_s: float = 5.0,
                 straggler_z: float = 6.0, straggler_warmup: int = 8,
                 straggler_min_s: float = 0.0,
                 always_up: Tuple[str, ...] = DEFAULT_ALWAYS_UP,
                 time_fn=time.monotonic, injector=None,
                 channels: Optional[Iterable[str]] = None):
        # ``channels`` overrides the default per-engine registry: the
        # procpool master tracks WORKER PROCESSES ("worker:0", ...) through
        # the same breaker protocol — a dead worker is an engine failure one
        # level up the stack
        self._failure_threshold = failure_threshold
        self._cooldown_s = cooldown_s
        names = tuple(channels) if channels is not None else tuple(ENGINES)
        self.breakers: Dict[str, CircuitBreaker] = {
            name: CircuitBreaker(name, failure_threshold, cooldown_s)
            for name in names}
        # built lazily per engine (StragglerDetector lives in runtime.fault;
        # importing it at module scope would couple core to runtime)
        self._stragglers: Dict[str, object] = {}
        self._straggler_z = straggler_z
        self._straggler_warmup = straggler_warmup
        # absolute floor under which a z-flagged node time is still NOT a
        # breaker failure: healthy node times have near-zero variance, so a
        # few ms of scheduler jitter can carry an enormous z-score — and a
        # false trip fails over AWAY from the fastest engine.  Set it around
        # the serving latency target; 0.0 keeps the pure-z behavior
        self._straggler_min_s = straggler_min_s
        self._steps: Dict[str, int] = {name: 0 for name in names}
        self.always_up = tuple(always_up)
        self.time_fn = time_fn
        self.injector = injector
        # optional runtime.telemetry.Metrics; the owning BigDAWG wires it so
        # breaker trips land in the shared registry ("health.breaker_trips")
        self.metrics = None
        self._lock = threading.Lock()

    # -- registry management ------------------------------------------------
    def ensure_channel(self, name: str):
        """Add a breaker channel on demand (procpool worker respawns can
        mint fresh channel names); a no-op when it already exists."""
        with self._lock:
            if name not in self.breakers:
                self.breakers[name] = CircuitBreaker(
                    name, self._failure_threshold, self._cooldown_s)
                self._steps[name] = 0

    def reset(self, name: str):
        """Force a channel back to CLOSED with a clean failure run — used
        after a worker respawn: the REPLACEMENT process is healthy, and
        making it re-earn trust through the half-open probe would shed
        requests at a fully recovered worker."""
        with self._lock:
            br = self.breakers[name]
            br.state = CLOSED
            br.consecutive_failures = 0
            br.probe_inflight = False

    # -- executor-facing hooks ---------------------------------------------
    def before_op(self, engine: str, op: str = ""):
        """Fired by the executor just before running ``op`` on ``engine`` —
        the fault-injection seam.  May raise (a raised ``SimulatedFailure``
        is classified as an engine failure and fed back to the breaker by
        the executor's failure path)."""
        if self.injector is not None:
            self.injector.before_op(engine, op)

    def record_failure(self, engine: str) -> bool:
        """One engine failure (op or cast).  Returns True when it tripped
        the breaker open."""
        with self._lock:
            br = self.breakers[engine]
            br.poll(self.time_fn())
            tripped = br.on_failure(self.time_fn())
        if tripped:
            self._note_trip(engine)
        return tripped

    def _note_trip(self, engine: str):
        """Mirror a breaker trip into the metrics registry (no-op until the
        owning middleware wires ``self.metrics``).  Called OUTSIDE
        ``self._lock``: metrics takes its own lock."""
        if self.metrics is not None:
            self.metrics.counter("health.breaker_trips")

    def record_success(self, engine: str):
        with self._lock:
            br = self.breakers[engine]
            br.poll(self.time_fn())
            br.on_success()

    # -- middleware-facing hooks -------------------------------------------
    def mask_for_request(self) -> Tuple[FrozenSet[str], Tuple[str, ...]]:
        """``(masked_engines, probe_grants)`` for one request.

        OPEN engines are masked.  A HALF_OPEN engine with no probe in flight
        is granted to THIS request (left unmasked, returned in
        ``probe_grants``) — the request serves as the recovery probe; its
        success/failure decides the breaker, and the caller must
        ``release_probes`` when done.  Other requests see a HALF_OPEN engine
        as still masked, so at most one request at a time risks the maybe-
        dead engine."""
        masked: List[str] = []
        probes: List[str] = []
        now = self.time_fn()
        with self._lock:
            for name, br in self.breakers.items():
                state = br.poll(now)
                if state == OPEN:
                    masked.append(name)
                elif state == HALF_OPEN:
                    if br.probe_inflight:
                        masked.append(name)
                    else:
                        br.probe_inflight = True
                        probes.append(name)
        return frozenset(masked), tuple(probes)

    def release_probes(self, probes: Iterable[str]):
        """Return probe grants (called from the request's ``finally``): a
        probe whose request neither succeeded nor failed on the engine (the
        plan never touched it) goes back to grantable HALF_OPEN."""
        with self._lock:
            for name in probes:
                br = self.breakers[name]
                if br.state == HALF_OPEN:
                    br.probe_inflight = False

    def degrade_mask(self) -> FrozenSet[str]:
        """The graceful-degradation mask: every engine EXCEPT the always-up
        set — what an overloaded server plans with before shedding."""
        return frozenset(n for n in self.breakers if n not in self.always_up)

    def after_plan(self, engine_seconds: Iterable[Tuple[str, float]]):
        """Feed one successful plan's per-node ``(engine, seconds)`` pairs:
        each engine's node times go through its straggler detector; a
        flagged node counts as a breaker failure for that engine (slow ==
        down, eventually), an unflagged run counts as a success."""
        per_engine: Dict[str, List[float]] = {}
        for engine, secs in engine_seconds:
            per_engine.setdefault(engine, []).append(secs)
        tripped: List[str] = []
        with self._lock:
            now = self.time_fn()
            for engine, times in per_engine.items():
                det = self._straggler(engine)
                flagged = False
                for secs in times:
                    step = self._steps[engine]
                    self._steps[engine] += 1
                    # a z-flagged observation is excluded from the Welford
                    # stats either way; it only counts as a breaker failure
                    # above the absolute floor
                    if det.observe(step, secs) and \
                            secs >= self._straggler_min_s:
                        flagged = True
                br = self.breakers[engine]
                br.poll(now)
                if flagged:
                    if br.on_failure(now):
                        tripped.append(engine)
                else:
                    br.on_success()
        for engine in tripped:
            self._note_trip(engine)

    def _straggler(self, engine: str):
        det = self._stragglers.get(engine)
        if det is None:
            from repro.runtime.fault import StragglerDetector
            det = StragglerDetector(z_threshold=self._straggler_z,
                                    warmup=self._straggler_warmup)
            self._stragglers[engine] = det
        return det

    # -- introspection ------------------------------------------------------
    def state(self, engine: str) -> str:
        with self._lock:
            return self.breakers[engine].poll(self.time_fn())

    def trips(self) -> int:
        """Lifetime breaker trips summed over engines — the
        ``stats["breaker_trips"]`` figure."""
        with self._lock:
            return sum(br.trips for br in self.breakers.values())

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """Current breaker states for stats/debugging — also the persistence
        payload ``restore`` consumes (the middleware writes it beside the
        monitor DB on ``persist()``)."""
        now = self.time_fn()
        with self._lock:
            return {name: {"state": br.poll(now), "trips": br.trips,
                           "consecutive_failures": br.consecutive_failures}
                    for name, br in self.breakers.items()}

    def restore(self, channels: Dict[str, Dict[str, object]]):
        """Adopt a persisted ``snapshot()``: a restarted process must not
        re-burn a full failure budget rediscovering an outage it already
        paid to learn about.  CLOSED channels restore verbatim.  OPEN and
        HALF_OPEN both restore as OPEN with the cooldown restarted from NOW
        — the wall-clock gap since the snapshot is unknowable under an
        injectable monotonic clock, and an engine that recovered meanwhile
        re-earns trust through one half-open probe after the cooldown (the
        cheap direction to be wrong in).  Probe grants never persist: the
        granted request died with the old process.  Unknown channels are
        created on demand (procpool worker channels); malformed entries are
        skipped."""
        now = self.time_fn()
        for name, blob in channels.items():
            if not isinstance(blob, dict):
                continue
            self.ensure_channel(str(name))
            with self._lock:
                br = self.breakers[str(name)]
                state = blob.get("state")
                if state not in (CLOSED, OPEN, HALF_OPEN):
                    continue
                br.state = OPEN if state in (OPEN, HALF_OPEN) else CLOSED
                br.opened_at = now if br.state == OPEN else 0.0
                br.probe_inflight = False
                try:
                    br.trips = max(0, int(blob.get("trips", 0)))
                    br.consecutive_failures = max(0, int(
                        blob.get("consecutive_failures", 0)))
                except (TypeError, ValueError):
                    br.trips, br.consecutive_failures = br.trips, 0
