"""qlang — the paper's textual multi-island query surface.

"Version 0.1 of the BigDAWG Polystore System" presents queries as nested
island blocks — ``BIGDAWG(ARRAY(multiply(RELATIONAL(select A), B)))`` — where
each upper-case block SCOPEs its fragment to one island and the seams between
blocks are CASTs.  ``bigdawg(text)`` parses exactly that shape (plus a
pipeline sugar) into the same ``PolyOp`` IR the attribute API builds, so the
demo-paper surface round-trips through parse → plan → execute:

    bigdawg("RELATIONAL(join(A, B, left_on=key, right_on=key)) "
            "|> ARRAY(matmul(_, W))")

Grammar (recursive descent; whitespace-insensitive):

    query    :=  "BIGDAWG" "(" pipeline ")"  |  pipeline
    pipeline :=  stage ("|>" stage)*
    stage    :=  ISLAND "(" expr ")"
    expr     :=  ISLAND "(" expr ")"             -- nested block -> scope node
              |  op "(" (expr | kw)* ")"         -- island operator call
              |  name                            -- catalog Ref
              |  "_"                             -- previous pipeline stage
    kw       :=  name "=" (number | string | bare-word | true | false)

* ``ISLAND`` is an ALL-CAPS island name — ``RELATIONAL``, ``ARRAY``,
  ``TEXT``, ``STREAM``, or ``DEGENERATE:engine`` (e.g.
  ``DEGENERATE:dense_array``); lower-case names are operators or refs.
* A nested island block compiles to ``islands.scope(outer_island, inner)``:
  the inner fragment runs under the inner island's semantics and is CAST to
  the outer island's data model at the seam — the planner prices that edge.
* ``|>`` feeds the previous stage into the next stage's ``_`` placeholder
  (scoped to the next stage's island, once, even if ``_`` repeats).
* Keyword values: numbers (``lo=-0.5``), quoted strings, or bare words
  (``column=value`` means the string ``"value"``); ``true``/``false`` parse
  as booleans.

**SQL-style select (RELATIONAL blocks).**  The paper's §III examples write
the relational fragment as literal SQL text; RELATIONAL blocks accept that
surface too:

    sql    :=  "select" ("*" | name ("," name)*) "from" (name | "_")
               [ "where" cond ("and" cond)* ]
    cond   :=  name ("<" | "<=" | ">" | ">=" | "=") number

``bigdawg("RELATIONAL(select * from A where v >= 0.5)")`` compiles to the
SAME ``relational.select(A, column=v, lo=0.5)`` IR the attribute API builds
— signature-identical, so both surfaces share plans and monitor history.
Conditions on one column fold into one select node's ``lo``/``hi`` bounds
(``=`` pins both); a non-star column list appends a ``project``.  Bounds
are closed intervals (the columnar engine's select is inclusive), so strict
``<``/``>`` compile to the closed bound — exact for the continuous-valued
columns the demo data uses.

Errors carry position context; an unknown operator raises the island's
available op list (via ``Island.__getattr__``), an unknown island names the
registered islands.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

# QueryParseError now lives in the unified BigDAWGError taxonomy
# (core.errors); re-exported here, its historical home, for back-compat
from repro.core.errors import QueryParseError
from repro.core.islands import ISLANDS, Island, scope
from repro.core.ops import PolyOp, Ref

_TOKEN = re.compile(r"""
    (?P<ws>\s+)
  | (?P<pipe>\|>)
  | (?P<lparen>\()
  | (?P<rparen>\))
  | (?P<comma>,)
  | (?P<star>\*)
  | (?P<cmp><=|>=|<|>)
  | (?P<eq>=)
  | (?P<string>'[^']*'|"[^"]*")
  | (?P<number>-?\d+(?:\.\d*)?(?:[eE][+-]?\d+)?)
  | (?P<name>[A-Za-z_][A-Za-z0-9_]*(?::[A-Za-z0-9_]+)?)
""", re.VERBOSE)


def _tokenize(text: str) -> List[Tuple[str, str, int]]:
    tokens, pos = [], 0
    while pos < len(text):
        m = _TOKEN.match(text, pos)
        if m is None:
            raise QueryParseError(_fmt_err(text, pos,
                                           f"unexpected character "
                                           f"{text[pos]!r}"))
        if m.lastgroup != "ws":
            tokens.append((m.lastgroup, m.group(), pos))
        pos = m.end()
    return tokens


def _fmt_err(text: str, pos: int, msg: str) -> str:
    return f"{msg}\n  {text}\n  {' ' * pos}^ (offset {pos})"


def _is_island_token(name: str) -> bool:
    """ALL-CAPS head = island block (the DEGENERATE:engine tail is an engine
    name and stays lower-case)."""
    head = name.split(":", 1)[0]
    return head.isupper()


def _resolve_island(name: str, text: str, pos: int) -> Island:
    isl = ISLANDS.get(name.lower())
    if isl is None:
        raise QueryParseError(_fmt_err(
            text, pos, f"unknown island {name!r}; available islands: "
                       f"{', '.join(sorted(ISLANDS)).upper()}"))
    return isl


def _finish_block(island: Island, node):
    """Close an island block: its body must be governed by (and delivered
    in) the block's island — a bare catalog ref or a foreign-island subtree
    gets an explicit boundary node; a native subtree passes through."""
    if isinstance(node, Ref) or \
            (isinstance(node, PolyOp) and node.island != island.name):
        return scope(island, node)
    return node


class _Parser:
    def __init__(self, text: str):
        self.text = text
        self.tokens = _tokenize(text)
        self.i = 0
        # the previous pipeline stage's subtree, scoped lazily (and at most
        # once per island) when an `_` placeholder pulls it in; repeated `_`
        # shares the node, so the boundary cast happens once
        self._prev: Optional[PolyOp] = None
        self._prev_used = False
        self._prev_scoped: Dict[str, PolyOp] = {}

    # -- token plumbing ----------------------------------------------------
    def _peek(self, kind: Optional[str] = None):
        if self.i >= len(self.tokens):
            return None
        tok = self.tokens[self.i]
        return tok if kind is None or tok[0] == kind else None

    def _next(self):
        tok = self._peek()
        if tok is None:
            raise QueryParseError(_fmt_err(self.text, len(self.text),
                                           "unexpected end of query"))
        self.i += 1
        return tok

    def _expect(self, kind: str, what: str):
        tok = self._peek()
        if tok is None or tok[0] != kind:
            pos = tok[2] if tok else len(self.text)
            got = repr(tok[1]) if tok else "end of query"
            raise QueryParseError(_fmt_err(self.text, pos,
                                           f"expected {what}, got {got}"))
        self.i += 1
        return tok

    # -- grammar -----------------------------------------------------------
    def parse_query(self) -> PolyOp:
        tok = self._peek("name")
        if tok and tok[1] == "BIGDAWG":      # optional paper-style wrapper
            self._next()
            self._expect("lparen", "'(' after BIGDAWG")
            node = self.parse_pipeline()
            self._expect("rparen", "')' closing BIGDAWG(...)")
        else:
            node = self.parse_pipeline()
        trailing = self._peek()
        if trailing is not None:
            raise QueryParseError(_fmt_err(
                self.text, trailing[2],
                f"trailing input after query: {trailing[1]!r}"))
        return node

    def parse_pipeline(self) -> PolyOp:
        node = self.parse_stage()
        while self._peek("pipe"):
            self._next()
            self._prev, self._prev_used, self._prev_scoped = node, False, {}
            nxt = self.parse_stage()
            if not self._prev_used:
                tok = self.tokens[self.i - 1]
                raise QueryParseError(_fmt_err(
                    self.text, tok[2],
                    "pipeline stage never consumed '_' — each stage after "
                    "'|>' must reference the previous stage's result"))
            self._prev = None
            node = nxt
        return node

    def parse_stage(self) -> PolyOp:
        tok = self._expect("name", "an ISLAND block (e.g. RELATIONAL(...))")
        if not _is_island_token(tok[1]):
            raise QueryParseError(_fmt_err(
                self.text, tok[2],
                f"each pipeline stage must be an ISLAND(...) block; got "
                f"{tok[1]!r} (island names are ALL-CAPS: "
                f"{', '.join(sorted(ISLANDS)).upper()})"))
        island = _resolve_island(tok[1], self.text, tok[2])
        self._expect("lparen", f"'(' after {tok[1]}")
        node = self.parse_expr(island)
        self._expect("rparen", f"')' closing {tok[1]}(...)")
        return _finish_block(island, node)

    def _placeholder(self, island: Island, pos: int) -> PolyOp:
        if self._prev is None:
            raise QueryParseError(_fmt_err(
                self.text, pos,
                "'_' placeholder outside a '|>' pipeline stage"))
        self._prev_used = True
        if self._prev.island == island.name:
            return self._prev
        # one scope node per (stage, island): repeated `_` shares the cast
        return self._prev_scoped.setdefault(island.name,
                                            scope(island, self._prev))

    def parse_expr(self, island: Island):
        tok = self._next()
        kind, val, pos = tok
        if kind == "name":
            if val == "_":
                return self._placeholder(island, pos)
            if val == "select" and (self._peek("star") or self._peek("name")):
                # the paper's literal SQL surface: select ... from ...
                return self._parse_sql_select(island, pos)
            if self._peek("lparen"):
                self._next()
                if _is_island_token(val):    # nested block -> boundary node
                    inner = _resolve_island(val, self.text, pos)
                    sub = _finish_block(inner, self.parse_expr(inner))
                    self._expect("rparen", f"')' closing {val}(...)")
                    # the enclosing island consumes the inner fragment
                    # across the seam — unless the blocks name the same
                    # island, where no boundary exists
                    return sub if inner.name == island.name \
                        else scope(island, sub)
                return self._parse_call(island, val, pos)
            return Ref(val)                  # bare name: catalog reference
        if kind in ("number", "string"):
            raise QueryParseError(_fmt_err(
                self.text, pos,
                f"literal {val} is only allowed as a keyword argument "
                f"(e.g. lo={val})"))
        raise QueryParseError(_fmt_err(self.text, pos,
                                       f"unexpected token {val!r}"))

    def _parse_sql_select(self, island: Island, pos: int):
        """``select (*|cols) from table [where col <op> num [and ...]]`` —
        the §III literal text, compiled onto the existing relational ops
        (see the module docstring).  Only the RELATIONAL island carries this
        surface; per-column bounds fold into one ``select`` node each, and
        a non-star column list becomes a trailing ``project``."""
        if island.name != "relational":
            raise QueryParseError(_fmt_err(
                self.text, pos,
                f"literal 'select ... from ...' text is the RELATIONAL "
                f"surface; inside {island.name.upper()}(...) use the "
                f"operator form select(...)"))
        cols: Optional[List[str]] = None
        if self._peek("star"):
            self._next()
        else:
            cols = [self._expect("name", "a column name or '*'")[1]]
            while self._peek("comma"):
                self._next()
                cols.append(self._expect("name", "a column name")[1])
        frm = self._expect("name", "'from'")
        if frm[1].lower() != "from":
            raise QueryParseError(_fmt_err(
                self.text, frm[2], f"expected 'from', got {frm[1]!r}"))
        tbl = self._expect("name", "a table name (or '_')")
        node = self._placeholder(island, tbl[2]) if tbl[1] == "_" \
            else Ref(tbl[1])
        nxt = self._peek("name")
        if nxt is not None and nxt[1].lower() == "where":
            self._next()
            # column -> [lo, hi]; repeated bounds tighten (max lo, min hi)
            bounds: Dict[str, List[Optional[float]]] = {}
            order: List[str] = []
            while True:
                col = self._expect("name", "a column name")[1]
                optok = self._peek()
                if optok is None or optok[0] not in ("cmp", "eq"):
                    got = repr(optok[1]) if optok else "end of query"
                    p = optok[2] if optok else len(self.text)
                    raise QueryParseError(_fmt_err(
                        self.text, p,
                        f"expected a comparison (<, <=, >, >=, =), "
                        f"got {got}"))
                self._next()
                op = optok[1]
                numtok = self._expect("number", "a numeric bound")
                v = float(numtok[1]) if any(c in numtok[1] for c in ".eE") \
                    else int(numtok[1])
                if col not in bounds:
                    bounds[col] = [None, None]
                    order.append(col)
                b = bounds[col]
                if op in (">", ">="):
                    b[0] = v if b[0] is None else max(b[0], v)
                elif op in ("<", "<="):
                    b[1] = v if b[1] is None else min(b[1], v)
                else:                                   # '=' pins both
                    b[0] = v if b[0] is None else max(b[0], v)
                    b[1] = v if b[1] is None else min(b[1], v)
                conj = self._peek("name")
                if conj is not None and conj[1].lower() == "and":
                    self._next()
                    continue
                break
            for col in order:
                lo, hi = bounds[col]
                kw: Dict[str, object] = {"column": col}
                if lo is not None:
                    kw["lo"] = lo
                if hi is not None:
                    kw["hi"] = hi
                node = island.select(node, **kw)
        if cols is not None:
            node = island.project(node, columns=cols)
        return node

    def _parse_call(self, island: Island, opname: str, pos: int):
        args, kwargs = [], {}
        while not self._peek("rparen"):
            tok = self._peek()
            if tok is None:
                raise QueryParseError(_fmt_err(
                    self.text, len(self.text),
                    f"unclosed argument list of {opname}(...)"))
            if tok[0] == "name" and self.tokens[self.i + 1:self.i + 2] and \
                    self.tokens[self.i + 1][0] == "eq":
                self._next()                 # keyword name
                self._next()                 # '='
                kwargs[tok[1]] = self._parse_literal()
            else:
                args.append(self.parse_expr(island))
            if self._peek("comma"):
                self._next()
        self._expect("rparen", f"')' closing {opname}(...)")
        # getattr goes through Island.__getattr__, so an unknown operator
        # raises with the island's available op vocabulary
        return getattr(island, opname)(*args, **kwargs)

    def _parse_literal(self):
        kind, val, pos = self._next()
        if kind == "number":
            return float(val) if any(c in val for c in ".eE") else int(val)
        if kind == "string":
            return val[1:-1]
        if kind == "name":
            if val in ("true", "false"):
                return val == "true"
            return val                       # bare word -> string value
        raise QueryParseError(_fmt_err(
            self.text, pos, f"expected a literal keyword value, got {val!r}"))


def bigdawg(text: str) -> PolyOp:
    """Parse the paper's textual ``BIGDAWG(ISLAND(query))`` syntax (and the
    ``|>`` pipeline sugar) into a ``PolyOp`` query — the same IR the
    attribute API builds, signature-identical to a hand-built equivalent, so
    textual queries share plans, monitor history and cache entries with
    their programmatic twins.  See the module docstring for the grammar."""
    node = _Parser(text).parse_query()
    if isinstance(node, Ref):
        raise QueryParseError(f"query {text!r} is a bare catalog reference; "
                              f"wrap it in an island block to give it a "
                              f"delivery model")
    return node
