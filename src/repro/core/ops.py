"""PolyOp — the island-operator IR.

A query is a DAG of PolyOp nodes; leaves are ``Ref``s into the middleware
catalog (named, engine-homed objects), mirroring the paper's
``ARRAY(multiply(RELATIONAL(select * from A), B))`` example where each scope
tag names the island interpreting that fragment.

Island boundaries are first-class: a node with ``op == SCOPE_OP`` (built by
``islands.scope(island, subtree)`` or a nested island block in the textual
``qlang`` syntax) marks the point where one island consumes a subtree from
another.  A scope node is semantically the identity on its input's *logical*
content, but it pins the payload to the target island's data model — the
planner restricts its engine candidates to that model's member engines and
charges the inter-island cast on the boundary edge (multi-hop routed, sized
per hop), and the executor materializes the cast through the migrator.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, Tuple, Union

_ids = itertools.count()

# operator name of the island-boundary node (see module docstring); the
# user-facing builder is ``islands.scope``, which validates the island name
SCOPE_OP = "scope"


@dataclass(frozen=True)
class Ref:
    """A reference to a catalog object (leaf)."""
    name: str

    def walk(self):
        yield self


@dataclass(frozen=True, eq=False)
class PolyOp:
    op: str                                  # operator name
    island: str                              # scope: array|relational|text|stream|degenerate:<engine>
    inputs: Tuple[Union["PolyOp", Ref], ...]
    attrs: Dict[str, Any] = field(default_factory=dict)
    uid: int = field(default_factory=lambda: next(_ids))

    def walk(self):
        """Post-order traversal."""
        for i in self.inputs:
            yield from i.walk()
        yield self

    def nodes(self):
        return [n for n in self.walk() if isinstance(n, PolyOp)]

    def refs(self):
        return [n for n in self.walk() if isinstance(n, Ref)]

    def __repr__(self):
        args = ", ".join(repr(i) if isinstance(i, Ref) else f"#{i.uid}:{i.op}"
                         for i in self.inputs)
        return f"{self.island.upper()}({self.op} {args})"
