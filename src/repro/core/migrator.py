"""Migrator (paper §III-C / [18]): executes casts between engines, keeps
account of the bytes moved (the executor charges them to the plan's stats),
and times every transfer so the calibrated cost model can learn real cast
bandwidth per (src, dst) data-model pair."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Tuple

from repro.core import cast as castmod
from repro.core.engines import ENGINES


@dataclass
class Migrator:
    bytes_moved: float = 0.0
    n_casts: int = 0
    # (src_kind, dst_kind, bytes, seconds) per executed cast
    events: List[Tuple[str, str, float, float]] = field(default_factory=list)

    def to_engine(self, obj, engine_name: str):
        eng = ENGINES[engine_name]
        if obj.kind == eng.kind:
            return obj
        nbytes = obj.nbytes
        self.bytes_moved += nbytes
        self.n_casts += 1
        t0 = time.perf_counter()
        out = castmod.cast(obj, eng.kind)
        self.events.append((obj.kind, eng.kind, float(nbytes),
                            time.perf_counter() - t0))
        return out

    def reset(self):
        self.bytes_moved = 0.0
        self.n_casts = 0
        self.events.clear()
