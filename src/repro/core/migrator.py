"""Migrator (paper §III-C / [18]): executes casts between engines, keeps
account of the bytes moved (the executor charges them to the plan's stats),
and times every transfer so the calibrated cost model can learn real cast
bandwidth per (src, dst) data-model pair.

Given a cost model, the migrator follows ``cast_path`` — the cheapest route
over the calibrated cast graph, which may be multi-hop (coo->dense->columnar
when the direct pair is slow), with every hop sized from the format the data
is actually in at that hop (a coo->dense hop densifies the payload).  Every
hop is timed and reported separately, so the model keeps learning true
per-pair bandwidths even on detours.

One Migrator instance is shared by all of a plan's nodes; in the executor's
thread-pooled concurrent mode several host workers cast through it at once,
so the byte/cast accounting is guarded by a lock (the casts themselves run
outside it and genuinely overlap).  Nothing is shared ACROSS plans: every
``execute_plan`` call builds its own Migrator, so concurrent request
threads (and background exploration tasks) never contend on each other's
accounting — the executor reads the totals only after the final level
barrier, when all of this plan's workers have joined."""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple

from repro.core import cast as castmod
from repro.core.engines import ENGINES


@dataclass
class Migrator:
    bytes_moved: float = 0.0
    n_casts: int = 0
    # (src_kind, dst_kind, bytes, seconds) per executed cast hop
    events: List[Tuple[str, str, float, float]] = field(default_factory=list)
    cost_model: Optional[Any] = None     # enables calibrated multi-hop routes
    trace: Optional[Any] = None          # parent tracing.Span for cast spans
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False,
                                  compare=False)

    def to_engine(self, obj, engine_name: str):
        eng = ENGINES[engine_name]
        if obj.kind == eng.kind:
            return obj
        path = castmod.cast_path(obj.kind, eng.kind, obj.nbytes,
                                 self.cost_model, obj=obj)
        for dst_kind in path[1:]:
            src_kind, nbytes = obj.kind, obj.nbytes
            t0 = time.perf_counter()
            obj = castmod.cast_step(obj, dst_kind)
            dt = time.perf_counter() - t0
            with self._lock:
                self.bytes_moved += nbytes
                self.n_casts += 1
                self.events.append((src_kind, dst_kind, float(nbytes), dt))
            if self.trace is not None:     # Trace appends take their own lock
                self.trace.static_child("cast", dt, src=src_kind,
                                        dst=dst_kind, bytes=float(nbytes))
        return obj

    def reset(self):
        with self._lock:
            self.bytes_moved = 0.0
            self.n_casts = 0
            self.events.clear()
