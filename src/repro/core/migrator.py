"""Migrator (paper §III-C / [18]): executes casts between engines and keeps
account of the bytes moved (the executor charges them to the plan's stats)."""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core import cast as castmod
from repro.core.engines import ENGINES


@dataclass
class Migrator:
    bytes_moved: float = 0.0
    n_casts: int = 0

    def to_engine(self, obj, engine_name: str):
        eng = ENGINES[engine_name]
        if obj.kind == eng.kind:
            return obj
        self.bytes_moved += obj.nbytes
        self.n_casts += 1
        return castmod.cast(obj, eng.kind)

    def reset(self):
        self.bytes_moved = 0.0
        self.n_casts = 0
