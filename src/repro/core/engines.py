"""Execution engines — the polystore's heterogeneous backends.

Each engine is an *execution regime*: a native data layout plus layout-true
algorithms.  The relative strengths are real, not simulated:

  dense_array (SciDB-analogue)   O(1) metadata count; MXU-shaped matmul/Haar;
                                 distinct must scan padded storage.
  columnar (Postgres/Myria)      scan count; sort-based distinct/group/join on
                                 compacted columns; matmul only via
                                 join-aggregate over triples (the paper's
                                 166-minute Postgres anecdote).
  kv_sparse (Accumulo/Graphulo)  O(1) nnz count; segment-sum spmm; natural
                                 TF-IDF over triples (D4M associative arrays).
  stream (S-Store)               windowed aggregation via scan; ETL to arrays.

Every op: fn(attrs, *containers) -> container.  Ops that a given engine cannot
express are simply absent — the planner must cast (paper: islands have partial
engine coverage).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tables import COOMatrix, ColumnarTable, DenseTensor, StreamBuffer


# ==========================================================================
# shared math
# ==========================================================================

def haar_1d_levels(x: jnp.ndarray, levels: int) -> jnp.ndarray:
    """Multi-level Haar DWT over the last axis.  Returns [a_L, d_L, ..., d_1]
    concatenated (same length as input; length must be divisible by 2^levels)."""
    inv = 1.0 / math.sqrt(2.0)
    details = []
    a = x
    for _ in range(levels):
        e, o = a[..., 0::2], a[..., 1::2]
        details.append((e - o) * inv)
        a = (e + o) * inv
    return jnp.concatenate([a] + details[::-1], axis=-1)


def _scale_slices(T: int, levels: int):
    """[(offset, length)] per band of the haar_1d_levels output layout."""
    out = [(0, T >> levels)]
    off = T >> levels
    for l in range(levels, 0, -1):
        n = T >> l
        out.append((off, n))
        off += n
    return out


def tfidf_dense(tf: jnp.ndarray) -> jnp.ndarray:
    """tf: (docs, terms) counts -> l2-normalized tf-idf."""
    n = tf.shape[0]
    df = jnp.sum(tf > 0, axis=0)
    idf = jnp.log(n / (1.0 + df.astype(jnp.float32))) + 1.0
    w = tf.astype(jnp.float32) * idf[None, :]
    norm = jnp.linalg.norm(w, axis=1, keepdims=True)
    return w / jnp.maximum(norm, 1e-9)


# ==========================================================================
# dense_array engine
# ==========================================================================

def _da_count(attrs, d: DenseTensor):
    # SciDB-style: element count is container metadata — O(1)
    return DenseTensor(jnp.asarray(d.valid_count, jnp.int32), valid_count=1)


def _da_distinct(attrs, d: DenseTensor):
    # must scan the full (padded) dense storage — fill values included in the
    # sort, exactly the cost a real array store pays on sparse data
    flat = jnp.sort(d.data.ravel())
    neq = jnp.concatenate([jnp.array([True]), flat[1:] != flat[:-1]])
    return DenseTensor(jnp.sum(neq).astype(jnp.int32), valid_count=1)


def _da_matmul(attrs, a: DenseTensor, b: DenseTensor):
    return DenseTensor(jnp.dot(a.data, b.data))


def _da_select(attrs, d: DenseTensor):
    lo, hi = attrs.get("lo", -np.inf), attrs.get("hi", np.inf)
    m = (d.data >= lo) & (d.data <= hi)
    return DenseTensor(jnp.where(m, d.data, d.fill),
                       valid_count=int(jnp.sum(m)))


def _da_haar(attrs, d: DenseTensor):
    # TPU hot spot — served by kernels/haar.py on real hardware
    from repro.kernels import ops as kops
    return DenseTensor(kops.haar(d.data, attrs["levels"]))


def _da_bin_hist(attrs, d: DenseTensor):
    """Per-scale histograms of Haar coefficients via one-hot matmul — the
    dense engine pays for scatter-free layout with a padded one-hot GEMM."""
    nbins, levels = attrs["nbins"], attrs["levels"]
    lo, hi = attrs.get("lo", -3.0), attrs.get("hi", 3.0)
    N, T = d.data.shape
    slices = _scale_slices(T, levels)
    outs = []
    for off, ln in slices:
        seg = d.data[:, off:off + ln]
        idx = jnp.clip(((seg - lo) / (hi - lo) * nbins).astype(jnp.int32),
                       0, nbins - 1)
        oh = jax.nn.one_hot(idx, nbins, dtype=jnp.float32)   # (N, ln, nbins)
        outs.append(jnp.einsum("nlb->nb", oh))
    return DenseTensor(jnp.concatenate(outs, axis=1))


def _da_tfidf(attrs, d: DenseTensor):
    return DenseTensor(tfidf_dense(d.data))


def _da_knn(attrs, train: DenseTensor, test: DenseTensor):
    """Cosine-distance kNN via one GEMM + top-k (kernels/knn.py on TPU)."""
    from repro.kernels import ops as kops
    idx, score = kops.knn(train.data, jnp.atleast_2d(test.data), attrs["k"])
    return DenseTensor(idx)


def _da_add(attrs, a, b):
    return DenseTensor(a.data + b.data)


def _da_concat(attrs, a: DenseTensor, b: DenseTensor):
    """Row concatenation (leading axis).  ``valid_count`` adds — exact for
    unpadded operands, and for padded ones the per-operand counts are still
    the only row-attributable accounting available."""
    return DenseTensor(jnp.concatenate([a.data, b.data], axis=0),
                       valid_count=a.valid_count + b.valid_count,
                       fill=a.fill)


def _da_scale(attrs, a):
    return DenseTensor(a.data * attrs["factor"])


def _da_transpose(attrs, a):
    return DenseTensor(a.data.T)


# ==========================================================================
# columnar engine
# ==========================================================================

def _col_count(attrs, t: ColumnarTable):
    # full validity scan — Postgres-style COUNT(*)
    return DenseTensor(jnp.sum(t.valid).astype(jnp.int32), valid_count=1)


def _col_distinct(attrs, t: ColumnarTable):
    col = attrs.get("column", "value")
    v = t.columns[col]
    sentinel = jnp.asarray(np.inf, v.dtype) if jnp.issubdtype(v.dtype, jnp.floating) \
        else jnp.iinfo(v.dtype).max
    vv = jnp.where(t.valid, v, sentinel)
    s = jnp.sort(vv)
    neq = jnp.concatenate([jnp.array([True]), s[1:] != s[:-1]])
    valid_sorted = jnp.sort(t.valid)[::-1]
    return DenseTensor(jnp.sum(neq & valid_sorted).astype(jnp.int32),
                       valid_count=1)


def _col_select(attrs, t: ColumnarTable):
    col, lo, hi = attrs["column"], attrs.get("lo", -np.inf), attrs.get("hi", np.inf)
    v = t.columns[col]
    m = t.valid & (v >= lo) & (v <= hi)
    return ColumnarTable(dict(t.columns), valid=m)


def _col_project(attrs, t: ColumnarTable):
    return ColumnarTable({c: t.columns[c] for c in attrs["columns"]},
                         valid=t.valid)


def _col_groupby_sum(attrs, t: ColumnarTable):
    key, val = attrs["key"], attrs["value"]
    nseg = attrs["num_groups"]
    k = jnp.where(t.valid, t.columns[key], nseg)         # invalid -> overflow seg
    s = jax.ops.segment_sum(t.columns[val], k, num_segments=nseg + 1)[:-1]
    return ColumnarTable({"key": jnp.arange(nseg, dtype=jnp.int32), "sum": s})


def _col_sort(attrs, t: ColumnarTable):
    """ORDER BY ``attrs["by"]`` (stable).  Output is COMPACTED — invalid rows
    are dropped, not carried — which is what makes the scatter–gather merge
    for this op a pure k-way ordered merge of per-shard runs.  Columns stay
    numpy for the same host-pool reasons as the join."""
    by = attrs["by"]
    valid = np.asarray(t.valid)
    cols = {c: np.asarray(v) for c, v in t.columns.items()}
    if not valid.all():
        cols = {c: v[valid] for c, v in cols.items()}
    order = np.argsort(cols[by], kind="stable")
    return ColumnarTable({c: v[order] for c, v in cols.items()})


def _col_join(attrs, a: ColumnarTable, b: ColumnarTable):
    """Sort-merge equi-join (eager; dynamic output size).

    The output columns stay NUMPY: argsort/searchsorted/fancy-indexing are
    host work that releases the GIL (what makes joins overlap on the host
    pool), and wrapping the result in ``jnp.asarray`` here would serialize
    every worker on the XLA transfer lock.  A downstream device consumer
    (segment_sum in matmul/knn, a dense cast) pulls the columns over when it
    actually needs them."""
    ka, kb = attrs["left_on"], attrs["right_on"]
    av = np.asarray(a.valid); bv = np.asarray(b.valid)

    def live(cols, mask):
        # skip the boolean gather when nothing is masked out (the common
        # catalog-table case): an all-true fancy index would copy every
        # column — pure memory-bandwidth burn that scales terribly across
        # concurrent requests
        if mask.all():
            return {c: np.asarray(v) for c, v in cols.items()}
        return {c: np.asarray(v)[mask] for c, v in cols.items()}

    an = live(a.columns, av)
    bn = live(b.columns, bv)
    order = np.argsort(bn[kb], kind="stable")
    bk = bn[kb][order]
    left = np.searchsorted(bk, an[ka], side="left")
    right = np.searchsorted(bk, an[ka], side="right")
    counts = right - left
    ai = np.repeat(np.arange(an[ka].shape[0]), counts)
    offs = (left.astype(np.int64).repeat(counts)
            + _ranges_from_counts(counts))
    bi = order[offs]
    cols = {("l_" + c if c in bn else c): v[ai]
            for c, v in an.items()}
    cols.update({("r_" + c if ("l_" + c) in cols or c in an else c):
                 v[bi] for c, v in bn.items()})
    return ColumnarTable(cols)


def _ranges_from_counts(counts):
    total = int(counts.sum())
    out = np.ones(total, np.int64)
    if total == 0:
        return out
    starts = np.cumsum(counts)[:-1]
    out[0] = 0
    # zero counts make `starts` repeat an index; plain fancy-index -= keeps
    # only the last repeat's update, so unmatched rows corrupt every range
    # after them — subtract.at accumulates all of them.  Trailing zero
    # counts land a start AT ``total``: past every live range, droppable
    live = starts < total
    np.subtract.at(out, starts[live], counts[:-1][live])
    return np.cumsum(out)


def _col_matmul(attrs, a: ColumnarTable, b: ColumnarTable):
    """Relational matrix multiply: join A.j == B.i, multiply, group by (A.i,
    B.j) — the paper's Postgres-in-166-minutes formulation."""
    j = _col_join({"left_on": "j", "right_on": "i"} | {},
                  ColumnarTable({"i": a.columns["i"], "j": a.columns["j"],
                                 "value": a.columns["value"]}, a.valid),
                  ColumnarTable({"i": b.columns["i"], "j": b.columns["j"],
                                 "value": b.columns["value"]}, b.valid))
    prod = j.columns["l_value"] * j.columns["r_value"]
    n = int(jnp.max(j.columns["l_i"])) + 1 if j.nrows else 0
    m = int(jnp.max(j.columns["r_j"])) + 1 if j.nrows else 0
    key = j.columns["l_i"].astype(jnp.int32) * m + j.columns["r_j"]
    s = jax.ops.segment_sum(prod, key, num_segments=n * m)
    return ColumnarTable({
        "i": (jnp.arange(n * m) // m).astype(jnp.int32),
        "j": (jnp.arange(n * m) % m).astype(jnp.int32),
        "value": s})


def _col_haar(attrs, t: ColumnarTable):
    """Haar in the relational engine: ORDER BY (i, j), restructure to rows,
    transform, flatten back — the ordering/restructure cost is the honest
    price a row store pays for array math (paper Fig. 5, SciDB side)."""
    order = jnp.lexsort((t.columns["j"], t.columns["i"]))
    v = t.columns["value"][order]
    n = int(jnp.max(t.columns["i"])) + 1
    T = int(jnp.max(t.columns["j"])) + 1
    mat = v.reshape(n, T)
    out = haar_1d_levels(mat, attrs["levels"])
    return ColumnarTable({"i": t.columns["i"][order], "j": t.columns["j"][order],
                          "value": out.ravel()})


def _col_bin_hist(attrs, t: ColumnarTable):
    """Sort/segment histogram — natural in a column store."""
    nbins, levels = attrs["nbins"], attrs["levels"]
    lo, hi = attrs.get("lo", -3.0), attrs.get("hi", 3.0)
    i, jj, v = t.columns["i"], t.columns["j"], t.columns["value"]
    n = int(jnp.max(i)) + 1
    T = int(jnp.max(jj)) + 1
    slices = _scale_slices(T, levels)
    starts = jnp.asarray([s for s, _ in slices] + [T])
    scale_of_j = jnp.searchsorted(starts, jj, side="right") - 1
    b = jnp.clip(((v - lo) / (hi - lo) * nbins).astype(jnp.int32), 0, nbins - 1)
    nscales = len(slices)
    key = (i.astype(jnp.int32) * nscales + scale_of_j) * nbins + b
    hist = jax.ops.segment_sum(jnp.ones_like(v, jnp.float32), key,
                               num_segments=n * nscales * nbins)
    hh = hist.reshape(n, nscales * nbins)
    ii, bb = jnp.meshgrid(jnp.arange(n), jnp.arange(nscales * nbins),
                          indexing="ij")
    return ColumnarTable({"i": ii.ravel().astype(jnp.int32),
                          "j": bb.ravel().astype(jnp.int32),
                          "value": hh.ravel()})


def _col_tfidf(attrs, t: ColumnarTable):
    """TF-IDF over (i=doc, j=term, value=tf) triples via segment ops."""
    i, jj, v = t.columns["i"], t.columns["j"], t.columns["value"]
    n = int(jnp.max(i)) + 1
    V = int(jnp.max(jj)) + 1
    df = jax.ops.segment_sum((v > 0).astype(jnp.float32), jj, num_segments=V)
    idf = jnp.log(n / (1.0 + df)) + 1.0
    w = v * idf[jj]
    norm2 = jax.ops.segment_sum(w * w, i, num_segments=n)
    w = w / jnp.sqrt(jnp.maximum(norm2[i], 1e-18))
    return ColumnarTable({"i": i, "j": jj, "value": w})


def _col_knn(attrs, train: ColumnarTable, test: ColumnarTable):
    """kNN as join-aggregate: join train and test on the term column, multiply,
    group by train doc."""
    j = _col_join({"left_on": "j", "right_on": "j"},
                  train, ColumnarTable({"j": test.columns["j"],
                                        "value": test.columns["value"]},
                                       test.valid))
    prod = j.columns["l_value"] * j.columns["r_value"]
    n = int(jnp.max(j.columns["i"])) + 1
    scores = jax.ops.segment_sum(prod, j.columns["i"], num_segments=n)
    _, idx = jax.lax.top_k(scores, attrs["k"])
    return DenseTensor(idx[None, :])


# ==========================================================================
# kv_sparse engine (Accumulo / Graphulo / D4M)
# ==========================================================================

def _kv_count(attrs, m: COOMatrix):
    return DenseTensor(jnp.asarray(m.nnz, jnp.int32), valid_count=1)


def _kv_distinct(attrs, m: COOMatrix):
    s = jnp.sort(m.vals)
    neq = jnp.concatenate([jnp.array([True]), s[1:] != s[:-1]])
    return DenseTensor(jnp.sum(neq).astype(jnp.int32), valid_count=1)


def _kv_spmm(attrs, m: COOMatrix, d: DenseTensor):
    """Graphulo-style server-side sparse matmul: segment-sum over triples."""
    contrib = m.vals[:, None] * d.data[m.cols]
    out = jax.ops.segment_sum(contrib, m.rows, num_segments=m.shape[0])
    return DenseTensor(out)


def _kv_tfidf(attrs, m: COOMatrix):
    n, V = m.shape
    df = jax.ops.segment_sum((m.vals > 0).astype(jnp.float32), m.cols,
                             num_segments=V)
    idf = jnp.log(n / (1.0 + df)) + 1.0
    w = m.vals * idf[m.cols]
    norm2 = jax.ops.segment_sum(w * w, m.rows, num_segments=n)
    w = w / jnp.sqrt(jnp.maximum(norm2[m.rows], 1e-18))
    return COOMatrix(m.rows, m.cols, w, m.shape)


def _kv_knn(attrs, train: COOMatrix, test):
    if isinstance(test, COOMatrix):         # migrator homed the test vector
        dense = jnp.zeros(test.shape[1], jnp.float32).at[test.cols].set(
            test.vals.astype(jnp.float32))
        q = dense
    else:
        q = test.data.ravel()
    contrib = train.vals * q[train.cols]
    scores = jax.ops.segment_sum(contrib, train.rows,
                                 num_segments=train.shape[0])
    _, idx = jax.lax.top_k(scores, attrs["k"])
    return DenseTensor(idx[None, :])


def _kv_degree(attrs, m: COOMatrix):
    axis = attrs.get("axis", 0)
    seg = m.rows if axis == 0 else m.cols
    n = m.shape[axis]
    return DenseTensor(jax.ops.segment_sum(jnp.ones_like(m.vals), seg,
                                           num_segments=n))


# ==========================================================================
# stream engine (S-Store)
# ==========================================================================

def _st_window_agg(attrs, s: StreamBuffer):
    fn = {"mean": jnp.mean, "max": jnp.max, "min": jnp.min}[attrs.get("fn", "mean")]
    return DenseTensor(fn(s.data, axis=1))


def _st_haar(attrs, s: StreamBuffer):
    return StreamBuffer(haar_1d_levels(s.data, attrs["levels"]), s.t0)


def _st_to_array(attrs, s: StreamBuffer):
    return DenseTensor(s.data.reshape(-1, s.data.shape[-1]))


def _st_ingest(attrs, s: StreamBuffer, d: DenseTensor):
    """Append new windows (ETL path of the paper's streaming application)."""
    new = d.data.reshape((-1,) + s.data.shape[1:])
    return StreamBuffer(jnp.concatenate([s.data, new], axis=0), s.t0)


# ==========================================================================
# registry
# ==========================================================================

class Engine:
    def __init__(self, name: str, kind: str, ops: Dict[str, Callable]):
        self.name = name
        self.kind = kind          # native container kind
        self.ops = ops

    def supports(self, op: str) -> bool:
        return op in self.ops

    def run(self, op: str, attrs, *inputs):
        return self.ops[op](attrs, *inputs)

    def __repr__(self):
        return f"Engine({self.name})"


ENGINES: Dict[str, Engine] = {
    "dense_array": Engine("dense_array", "dense", {
        "count": _da_count, "distinct": _da_distinct, "matmul": _da_matmul,
        "select": _da_select, "haar": _da_haar, "bin_hist": _da_bin_hist,
        "tfidf": _da_tfidf, "knn": _da_knn, "add": _da_add,
        "scale": _da_scale, "transpose": _da_transpose,
        "concat": _da_concat,
    }),
    "columnar": Engine("columnar", "columnar", {
        "count": _col_count, "distinct": _col_distinct, "select": _col_select,
        "project": _col_project, "groupby_sum": _col_groupby_sum,
        "sort": _col_sort,
        "join": _col_join, "matmul": _col_matmul, "haar": _col_haar,
        "bin_hist": _col_bin_hist, "tfidf": _col_tfidf, "knn": _col_knn,
    }),
    "kv_sparse": Engine("kv_sparse", "coo", {
        "count": _kv_count, "distinct": _kv_distinct, "spmm": _kv_spmm,
        "tfidf": _kv_tfidf, "knn": _kv_knn, "degree": _kv_degree,
    }),
    "stream": Engine("stream", "stream", {
        "window_agg": _st_window_agg, "haar": _st_haar,
        "to_array": _st_to_array, "ingest": _st_ingest,
    }),
}
