from repro.optim.adamw import AdamW
from repro.optim.schedule import cosine_schedule, constant_schedule
from repro.optim.compression import int8_ef_compress, int8_ef_init

__all__ = ["AdamW", "cosine_schedule", "constant_schedule",
           "int8_ef_compress", "int8_ef_init"]
