"""Int8 error-feedback gradient compression.

In an SPMD/jit program the DP gradient reduction is XLA-inserted, so the
compression is applied at the microbatch-accumulation boundary — the exact
point a hand-rolled collective would compress before its reduce-scatter.  The
residual (quantization error) is carried in the train state and re-added the
next step (error feedback), which keeps SGD convergence (tested in
tests/test_optim.py).  The 4x wire-size reduction is credited in the roofline
collective term when the plan enables it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def int8_ef_compress(grads, ef_state):
    """Quantize grads to int8 with error feedback.

    Returns (dequantized grads as would arrive post-reduce, new ef_state)."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, s = _quant(g)
        dq = _dequant(q, s)
        return dq, g - dq

    out = jax.tree.map(one, grads, ef_state)
    dq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return dq, ef
