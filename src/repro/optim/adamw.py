"""AdamW with decoupled weight decay, global-norm clipping, and configurable
moment dtype (bf16 moments for memory-bound giants like grok-1)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Union

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


@dataclass(frozen=True)
class AdamW:
    learning_rate: Union[float, Callable[[jnp.ndarray], jnp.ndarray]] = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float = 1.0
    moment_dtype: str = "float32"

    def init(self, params):
        mdt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def _lr(self, count):
        if callable(self.learning_rate):
            return self.learning_rate(count)
        return jnp.float32(self.learning_rate)

    def update(self, grads, state, params):
        """Returns (new_params, new_state, stats). grads/params: f32 trees."""
        count = state["count"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9)) \
            if self.clip_norm else jnp.float32(1.0)
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)
        lr = self._lr(count)
        mdt = jnp.dtype(self.moment_dtype)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            step = (m32 / c1) / (jnp.sqrt(v32 / c2) + self.eps)
            p2 = p.astype(jnp.float32) - lr * (step + self.weight_decay
                                               * p.astype(jnp.float32))
            return p2.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m, "v": new_v, "count": count}, \
            {"grad_norm": gnorm, "lr": lr}
