"""Synthetic MIMIC-II-like medical dataset.

The real MIMIC II requires a data-use agreement; this generator reproduces its
*shape*: structured patient records (relational), free-text notes (sparse
term counts), and physiologic ECG-like waveforms (arrays), with a
hemodynamic-deterioration label wired into the waveform statistics so the
paper's §IV-B classifier has signal to find (Saeed & Mark's wavelet-signature
method can separate the classes).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.tables import COOMatrix, ColumnarTable, DenseTensor


def ecg_waveforms(n_patients: int, n_samples: int = 16384, seed: int = 0,
                  deterioration_frac: float = 0.3):
    """(N, T) waveforms + (N,) binary deterioration labels.

    Healthy: stable quasi-periodic beats.  Deteriorating: progressive
    amplitude decay, rate drift and rising low-frequency variance — the
    multi-scale wavelet energy signature Saeed & Mark exploit.
    """
    rng = np.random.default_rng(seed)
    labels = (rng.random(n_patients) < deterioration_frac).astype(np.int32)
    t = np.arange(n_samples, dtype=np.float32)
    out = np.empty((n_patients, n_samples), np.float32)
    for i in range(n_patients):
        rate = rng.uniform(0.035, 0.055)            # beats per sample
        phase = rng.uniform(0, 2 * np.pi)
        beat = (np.sin(2 * np.pi * rate * t + phase)
                + 0.4 * np.sin(4 * np.pi * rate * t + 2 * phase)
                + 0.15 * np.sin(6 * np.pi * rate * t))
        noise = rng.normal(0, 0.12, n_samples).astype(np.float32)
        if labels[i]:
            decay = np.exp(-t / (n_samples * rng.uniform(0.7, 1.4)))
            drift = 0.35 * np.sin(2 * np.pi * rng.uniform(1.5, 4.0)
                                  * t / n_samples)
            lfn = np.cumsum(rng.normal(0, 0.02, n_samples)).astype(np.float32)
            sig = beat * decay + drift + lfn + noise
        else:
            sig = beat + noise
        out[i] = sig.astype(np.float32)
    return out, labels


def patients_table(n_patients: int, seed: int = 1) -> ColumnarTable:
    rng = np.random.default_rng(seed)
    return ColumnarTable({
        "patient_id": jnp.arange(n_patients, dtype=jnp.int32),
        "age": jnp.asarray(rng.integers(18, 95, n_patients).astype(np.int32)),
        "gender": jnp.asarray(rng.integers(0, 2, n_patients).astype(np.int32)),
        "icu_type": jnp.asarray(rng.integers(0, 4, n_patients).astype(np.int32)),
        "heart_rate_mean": jnp.asarray(
            rng.normal(82, 14, n_patients).astype(np.float32)),
        "sapsi": jnp.asarray(rng.integers(0, 32, n_patients).astype(np.int32)),
    })


def notes_coo(n_patients: int, vocab: int = 4096, terms_per_note: int = 60,
              n_topics: int = 8, seed: int = 2) -> COOMatrix:
    """Doctor/nurse notes as a (patients × terms) sparse count matrix with
    topic structure (for the Text Analytics application)."""
    rng = np.random.default_rng(seed)
    topic_of = rng.integers(0, n_topics, n_patients)
    rows, cols, vals = [], [], []
    base = rng.zipf(1.5, size=(n_topics, terms_per_note)) % vocab
    for i in range(n_patients):
        terms = np.unique(np.concatenate([
            base[topic_of[i]],
            rng.integers(0, vocab, terms_per_note // 3)]))
        rows.append(np.full(terms.shape, i, np.int32))
        cols.append(terms.astype(np.int32))
        vals.append(rng.poisson(2.0, terms.shape).astype(np.float32) + 1.0)
    return COOMatrix(jnp.asarray(np.concatenate(rows)),
                     jnp.asarray(np.concatenate(cols)),
                     jnp.asarray(np.concatenate(vals)),
                     (n_patients, vocab))


def mimic_like_dataset(n_patients: int = 600, n_samples: int = 16384,
                       seed: int = 0):
    """The full polystore-resident dataset of the paper's §III:
    waveforms -> array engine, demographics -> columnar, notes -> kv."""
    waves, labels = ecg_waveforms(n_patients, n_samples, seed)
    return {
        "waveforms": DenseTensor(jnp.asarray(waves)),
        "labels": labels,
        "patients": patients_table(n_patients, seed + 1),
        "notes": notes_coo(n_patients, seed=seed + 2),
    }
