from repro.data.synthetic import (mimic_like_dataset, ecg_waveforms,
                                  patients_table, notes_coo)
from repro.data.tokens import TokenStream
from repro.data.loader import ShardedLoader

__all__ = ["mimic_like_dataset", "ecg_waveforms", "patients_table",
           "notes_coo", "TokenStream", "ShardedLoader"]
