"""Sharded, prefetching, resumable data loader.

Wraps any step->batch function; places batches with the plan's input
sharding; prefetches one step ahead on a background thread (overlapping host
datagen with device compute — the data-path half of compute/comm overlap).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Optional

import jax


class ShardedLoader:
    def __init__(self, batch_fn: Callable[[int], dict], start_step: int = 0,
                 shardings=None, prefetch: int = 2):
        self.batch_fn = batch_fn
        self.shardings = shardings
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self.shardings is None:
            return batch
        return jax.tree.map(lambda x, s: jax.device_put(x, s), batch,
                            self.shardings)

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            b = self._place(self.batch_fn(step))
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, b = self._q.get()
        self.step = step + 1
        return b

    def __iter__(self):
        return self

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

    def state(self) -> dict:
        """Checkpointable loader state (resume = rebuild at this step)."""
        return {"step": self.step}
