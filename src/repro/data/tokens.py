"""Deterministic synthetic LM token pipeline.

Batches are a pure function of (seed, step) — the property fault-tolerant
training needs: a restart from checkpoint step k regenerates exactly the
batches k, k+1, ... (tested in tests/test_runtime.py).  The stream has
first-order Markov structure so small LMs have real signal to learn.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp


@dataclass
class TokenStream:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    n_states: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # block-diagonal-ish Markov chain over token buckets
        self._trans = rng.dirichlet(np.full(self.n_states, 0.3),
                                    size=self.n_states).astype(np.float64)
        self._emit_base = rng.integers(
            0, self.vocab_size, size=self.n_states)

    def batch_at(self, step: int) -> jnp.ndarray:
        """(batch, seq_len) int32 tokens for a given step — pure function."""
        rng = np.random.default_rng((self.seed * 1_000_003 + step) & 0x7FFFFFFF)
        out = np.empty((self.batch, self.seq_len), np.int32)
        state = rng.integers(0, self.n_states, self.batch)
        for t in range(self.seq_len):
            u = rng.random(self.batch)
            cdf = np.cumsum(self._trans[state], axis=1)
            state = (u[:, None] < cdf).argmax(axis=1)
            jitter = rng.integers(0, 7, self.batch)
            out[:, t] = (self._emit_base[state] + jitter) % self.vocab_size
        return jnp.asarray(out)

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
