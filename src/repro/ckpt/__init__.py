from repro.ckpt.checkpoint import CheckpointManager

__all__ = ["CheckpointManager"]
