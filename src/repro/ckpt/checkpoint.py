"""Sharded, async, elastic checkpointing.

Layout:  <dir>/step_<k>/manifest.json + <leaf-path>.npy per tree leaf.
 - async: the device->host gather happens on the caller thread (cheap),
   serialization runs on a background thread; ``wait()`` joins it.
 - elastic restore: leaves are restored with *target* shardings supplied at
   restore time, so a checkpoint taken on one mesh resumes on another
   (different device count / axis split) — the elastic-scaling path.
 - integrity: manifest carries shapes/dtypes; restore validates before use.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import numpy as np
import jax


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ---- save --------------------------------------------------------------
    def save(self, step: int, state: Any, blocking: bool = False,
             extra: Optional[dict] = None):
        self.wait()
        flat = _flatten(state)
        # device -> host while still on the caller thread (cheap on CPU;
        # on TPU this is the only device-touching part)
        host = {k: np.asarray(v) for k, v in flat.items()}
        treedef = jax.tree_util.tree_structure(state)
        manifest = {
            "step": step,
            "time": time.time(),
            "treedef": str(treedef),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host.items()},
            "extra": extra or {},
        }

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            os.makedirs(tmp, exist_ok=True)
            for k, v in host.items():
                p = os.path.join(tmp, k.replace("/", "__") + ".npy")
                np.save(p, v)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=1)
            final = os.path.join(self.dir, f"step_{step}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic publish
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ---- restore -------------------------------------------------------------
    def steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                out.append(int(d.split("_", 1)[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, int]:
        """Restore into the structure of `like`.  `shardings` (same tree
        structure, or None) enables elastic placement on the current mesh."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        manifest = json.load(open(os.path.join(d, "manifest.json")))
        flat_like = _flatten(like)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        restored = {}
        for k, leaf in flat_like.items():
            meta = manifest["leaves"].get(k)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {k}")
            arr = np.load(os.path.join(d, k.replace("/", "__") + ".npy"))
            want_shape = tuple(getattr(leaf, "shape", arr.shape))
            if tuple(arr.shape) != want_shape:
                raise ValueError(f"{k}: ckpt shape {arr.shape} != {want_shape}")
            s = flat_shard.get(k)
            restored[k] = jax.device_put(arr, s) if s is not None \
                else jax.device_put(arr)
        # rebuild tree in `like`'s structure
        flat_paths = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, _ in flat_paths[0]:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            leaves.append(restored[key])
        tree = jax.tree_util.tree_unflatten(flat_paths[1], leaves)
        return tree, step
