"""Zamba2-style hybrid: Mamba2 backbone + one SHARED attention block applied
every ``attn_period`` layers with per-invocation LoRA deltas.

Layout: ``num_layers = n_groups * attn_period + tail``.  Each scan step runs
``attn_period`` Mamba2 layers then the shared transformer block (weights
shared across groups, LoRA per group).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, PlanConfig
from repro.models import layers as L
from repro.models import ssm
from repro.models.partition import pcon
from repro.models.transformer import padded_vocab, lm_loss_from_hidden


def _geometry(cfg: ArchConfig):
    n_groups = cfg.num_layers // cfg.attn_period
    tail = cfg.num_layers - n_groups * cfg.attn_period
    return n_groups, cfg.attn_period, tail


def init_hybrid(cfg: ArchConfig, key, plan: PlanConfig = PlanConfig()):
    dtype = jnp.dtype(plan.param_dtype)
    Vp = padded_vocab(cfg)
    G, P, T = _geometry(cfg)
    D, H, KV, hd, r = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                       cfg.head_dim, cfg.shared_lora_rank)
    ks = jax.random.split(key, 12)

    def stack_mamba(k, n):
        return jax.vmap(lambda kk: ssm.init_mamba_block(kk, cfg, dtype))(
            jax.random.split(k, n))

    params = {
        "emb": L._dense_init(ks[0], (Vp, D), D, dtype),
        "final_norm": jnp.ones((D,), dtype),
        # (G, P, ...) stacked backbone + (T, ...) tail
        "groups": jax.vmap(lambda k: stack_mamba(k, P))(jax.random.split(ks[1], G)),
        "shared": {
            "ln1": jnp.ones((D,), dtype),
            "ln2": jnp.ones((D,), dtype),
            "attn": L.init_attention(ks[2], cfg, dtype),
            "mlp": L.init_mlp(ks[3], D, cfg.d_ff, dtype),
        },
        "lora": {
            "a_q": L._dense_init(ks[4], (G, D, r), D, dtype),
            "b_q": jnp.zeros((G, r, H, hd), dtype),
            "a_k": L._dense_init(ks[5], (G, D, r), D, dtype),
            "b_k": jnp.zeros((G, r, KV, hd), dtype),
            "a_v": L._dense_init(ks[6], (G, D, r), D, dtype),
            "b_v": jnp.zeros((G, r, KV, hd), dtype),
            "a_1": L._dense_init(ks[7], (G, D, r), D, dtype),
            "b_1": jnp.zeros((G, r, cfg.d_ff), dtype),
            "a_3": L._dense_init(ks[8], (G, D, r), D, dtype),
            "b_3": jnp.zeros((G, r, cfg.d_ff), dtype),
        },
    }
    if T:
        params["tail"] = stack_mamba(ks[9], T)
    return params


def _shared_effective(shared, lora_i):
    """Apply LoRA deltas to the shared block weights."""
    attn = dict(shared["attn"])
    attn["wq"] = attn["wq"] + jnp.einsum("dr,rhk->dhk", lora_i["a_q"], lora_i["b_q"])
    attn["wk"] = attn["wk"] + jnp.einsum("dr,rhk->dhk", lora_i["a_k"], lora_i["b_k"])
    attn["wv"] = attn["wv"] + jnp.einsum("dr,rhk->dhk", lora_i["a_v"], lora_i["b_v"])
    mlp = dict(shared["mlp"])
    mlp["w1"] = mlp["w1"] + jnp.einsum("dr,rf->df", lora_i["a_1"], lora_i["b_1"])
    mlp["w3"] = mlp["w3"] + jnp.einsum("dr,rf->df", lora_i["a_3"], lora_i["b_3"])
    return {"ln1": shared["ln1"], "ln2": shared["ln2"], "attn": attn, "mlp": mlp}


def _shared_block_apply(sp, cfg, plan, x, positions):
    h = L.rms_norm(x, sp["ln1"], cfg.norm_eps)
    h, cache = L.attention_apply(sp["attn"], cfg, h, positions,
                                 chunk=plan.attn_chunk,
                                 unroll=plan.unroll_inner)
    x = x + h
    h = L.rms_norm(x, sp["ln2"], cfg.norm_eps)
    return x + L.mlp_apply(sp["mlp"], h), cache


def _inner_scan(body, x, stacked, unroll: bool, n: int):
    """scan for production; python loop for dry-run cost probes."""
    if not unroll:
        return jax.lax.scan(body, x, stacked)
    ys = []
    for i in range(n):
        x, y = body(x, jax.tree.map(lambda a: a[i], stacked))
        ys.append(y)
    ys = None if ys and ys[0] is None else jax.tree.map(
        lambda *a: jnp.stack(a), *ys)
    return x, ys


def hybrid_hidden(cfg, plan: PlanConfig, params, embeds, positions,
                  collect_cache=False):
    shared = params["shared"]
    G, P, T = _geometry(cfg)

    def mamba_body(x, lp):
        from repro.models.specs import gather_fsdp
        x = pcon(x, "dp", "sp", None)
        lp = gather_fsdp(lp)
        h, state = ssm.mamba_apply(lp, cfg, L.rms_norm(x, lp["ln"], cfg.norm_eps),
                                   unroll=plan.unroll_inner)
        return x + h, (state if collect_cache else None)

    def group_body(x, inp):
        from repro.models.specs import gather_fsdp
        gp, lora_i = inp
        x, states = _inner_scan(mamba_body, x, gp, plan.unroll_inner, P)
        sp = _shared_effective(gather_fsdp(shared), gather_fsdp(lora_i))
        x, kv = _shared_block_apply(sp, cfg, plan, x, positions)
        return x, (states, kv if collect_cache else None)

    if plan.remat == "block":
        group_body = jax.remat(group_body)
    from repro.models.util import stack_scan
    x, ys = stack_scan(group_body, embeds, (params["groups"], params["lora"]),
                       plan.unroll_layers)
    g_states, kvs = ys if ys is not None else (None, None)
    t_states = None
    if "tail" in params:
        x, t_states = _inner_scan(mamba_body, x, params["tail"],
                                  plan.unroll_inner, T)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, {"groups": g_states, "tail": t_states, "kv": kvs}


def hybrid_loss(cfg, plan, params, tokens, aux_coef=0.0):
    e = pcon(params["emb"][tokens], "dp", None, None)
    positions = jnp.arange(tokens.shape[1])
    hidden, _ = hybrid_hidden(cfg, plan, params, e, positions)
    Bsz, S = tokens.shape
    targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.concatenate([jnp.ones((Bsz, S - 1), jnp.float32),
                            jnp.zeros((Bsz, 1), jnp.float32)], axis=1)
    return lm_loss_from_hidden(cfg, plan, params, hidden, targets, mask)


def init_hybrid_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    G, P, T = _geometry(cfg)
    s, c = ssm.init_mamba_state(cfg, batch, dtype)
    cache = {
        "ssm_g": jnp.zeros((G, P) + s.shape, s.dtype),
        "conv_g": jnp.zeros((G, P) + c.shape, c.dtype),
        "k": jnp.zeros((G, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((G, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
    }
    if T:
        cache["ssm_t"] = jnp.zeros((T,) + s.shape, s.dtype)
        cache["conv_t"] = jnp.zeros((T,) + c.shape, c.dtype)
    return cache


def hybrid_decode_step(cfg: ArchConfig, plan: PlanConfig, params, cache, tokens,
                       pos):
    x = params["emb"][tokens]
    shared = params["shared"]

    def mamba_body(x, inp):
        from repro.models.specs import gather_fsdp
        lp, s, c = inp
        lp = gather_fsdp(lp)
        h, (s2, c2) = ssm.mamba_step(lp, cfg,
                                     L.rms_norm(x, lp["ln"], cfg.norm_eps), (s, c))
        return x + h, (s2, c2)

    def group_body(x, inp):
        from repro.models.specs import gather_fsdp
        gp, lora_i, s, c, ck, cv = inp
        x, (s2, c2) = _inner_scan(mamba_body, x, (gp, s, c),
                                  plan.unroll_inner, cfg.attn_period)
        sp = _shared_effective(gather_fsdp(shared), gather_fsdp(lora_i))
        h = L.rms_norm(x, sp["ln1"], cfg.norm_eps)
        h, ck2, cv2 = L.attention_decode(sp["attn"], cfg, h, ck, cv, pos,
                                         use_cp=plan.decode_cp)
        x = x + h
        h = L.rms_norm(x, sp["ln2"], cfg.norm_eps)
        x = x + L.mlp_apply(sp["mlp"], h)
        return x, (s2, c2, ck2, cv2)

    # fori_loop with the caches in the CARRY (in-place updates) — scan ys
    # threading double-buffers the 500k-token KV cache (see transformer.py)
    G = jax.tree.leaves(params["groups"])[0].shape[0]
    mobile = (cache["ssm_g"], cache["conv_g"], cache["k"], cache["v"])

    def one_group(i, x, mob):
        sg, cg, ck, cv = mob
        idx = lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False)
        inp = (jax.tree.map(idx, params["groups"]),
               jax.tree.map(idx, params["lora"]),
               idx(sg), idx(cg), idx(ck), idx(cv))
        x, (s2, c2, ck2, cv2) = group_body(x, inp)
        upd = lambda a, u: jax.lax.dynamic_update_index_in_dim(
            a, u.astype(a.dtype), i, 0)
        return x, (upd(sg, s2), upd(cg, c2), upd(ck, ck2), upd(cv, cv2))

    if plan.unroll_layers:
        for i in range(G):
            x, mobile = one_group(i, x, mobile)
    else:
        x, mobile = jax.lax.fori_loop(
            0, G, lambda i, c: one_group(i, c[0], c[1]), (x, mobile))
    s2, c2, ck2, cv2 = mobile
    new_cache = dict(cache, ssm_g=s2, conv_g=c2, k=ck2, v=cv2)
    if "tail" in params:
        G_, P_, T_ = _geometry(cfg)
        x, (st, ct) = _inner_scan(mamba_body, x,
                                  (params["tail"], cache["ssm_t"],
                                   cache["conv_t"]), plan.unroll_inner, T_)
        new_cache["ssm_t"], new_cache["conv_t"] = st, ct
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x, params["emb"]).astype(jnp.float32)
    logits = pcon(logits, "dp", "tp")
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return nxt, new_cache
