"""Unified model API across the five architecture families.

Everything downstream (trainer, server, dry-run, tensorplan) talks to models
exclusively through this module:

  init_params(cfg, key, plan)               -> param pytree
  get_loss_fn(cfg, plan)                    -> f(params, batch) -> scalar
  make_train_step(cfg, plan, opt)           -> f(state, batch) -> (state, metrics)
  make_prefill(cfg, shape, plan)            -> f(params, batch) -> (logits, cache, pos)
  make_decode_step(cfg, shape, plan)        -> f(params, cache, tokens, pos) -> (tok, cache)
  example_batch / example_cache / ...       -> ShapeDtypeStruct stand-ins
  param_specs / cache_specs / batch_specs   -> PartitionSpec pytrees (plan-resolved)
  count_params(cfg)                         -> analytic N (via eval_shape, no alloc)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, PlanConfig, ShapeConfig
from repro.models import encdec, hybrid, ssm_lm, transformer as T, vlm
from repro.models.partition import current_env, pcon, plan_scope
from repro.optim.compression import int8_ef_compress, int8_ef_init

# --------------------------------------------------------------------------
# family dispatch
# --------------------------------------------------------------------------

F32_SENSITIVE = {"router", "A_log", "dt_bias", "Dskip"}


def init_params(cfg: ArchConfig, key, plan: PlanConfig = PlanConfig()):
    if cfg.family in ("dense", "moe", "vlm"):
        return T.init_lm(cfg, key, plan)
    if cfg.family == "ssm":
        return ssm_lm.init_ssm_lm(cfg, key, plan)
    if cfg.family == "hybrid":
        return hybrid.init_hybrid(cfg, key, plan)
    if cfg.family == "encdec":
        return encdec.init_encdec(cfg, key, plan)
    raise ValueError(cfg.family)


def cast_params(params, dtype):
    def one(path, p):
        name = _leaf_name(path)
        if name in F32_SENSITIVE or not jnp.issubdtype(p.dtype, jnp.floating):
            return p
        return p.astype(dtype)
    return jax.tree_util.tree_map_with_path(one, params)


def get_loss_fn(cfg: ArchConfig, plan: PlanConfig):
    if cfg.family in ("dense", "moe"):
        return lambda p, b: T.lm_loss(cfg, plan, p, b["tokens"])
    if cfg.family == "vlm":
        return lambda p, b: vlm.vlm_loss(cfg, plan, p, b["patch_embeds"],
                                         b["tokens"])
    if cfg.family == "ssm":
        return lambda p, b: ssm_lm.ssm_lm_loss(cfg, plan, p, b["tokens"])
    if cfg.family == "hybrid":
        return lambda p, b: hybrid.hybrid_loss(cfg, plan, p, b["tokens"])
    if cfg.family == "encdec":
        return lambda p, b: encdec.encdec_loss(cfg, plan, p, b["frames"],
                                               b["tokens"])
    raise ValueError(cfg.family)


def make_prefill(cfg: ArchConfig, shape: ShapeConfig, plan: PlanConfig):
    max_len = shape.seq_len
    if cfg.family in ("dense", "moe"):
        return lambda p, b: T.lm_prefill(cfg, plan, p, b["tokens"], max_len)
    if cfg.family == "vlm":
        return lambda p, b: vlm.vlm_prefill(cfg, plan, p, b["patch_embeds"],
                                            b["tokens"], max_len)
    if cfg.family == "ssm":
        return lambda p, b: ssm_lm.ssm_prefill(cfg, plan, p, b["tokens"])
    if cfg.family == "hybrid":
        def f(p, b):
            e = pcon(p["emb"][b["tokens"]], "dp", None, None)
            positions = jnp.arange(b["tokens"].shape[1])
            h, caches = hybrid.hybrid_hidden(cfg, plan, p, e, positions,
                                             collect_cache=True)
            logits = jnp.einsum("bd,vd->bv", h[:, -1], p["emb"]).astype(jnp.float32)
            Bsz, S = b["tokens"].shape
            cache = hybrid.init_hybrid_cache(cfg, Bsz, max_len, e.dtype)
            cache["ssm_g"] = caches["groups"][0]
            cache["conv_g"] = caches["groups"][1].astype(e.dtype)
            if caches["tail"] is not None:
                cache["ssm_t"] = caches["tail"][0]
                cache["conv_t"] = caches["tail"][1].astype(e.dtype)
            kvs = caches["kv"]
            cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], kvs[0].astype(e.dtype), 0, axis=2)
            cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], kvs[1].astype(e.dtype), 0, axis=2)
            return logits, cache, jnp.full((Bsz,), S, jnp.int32)
        return f
    if cfg.family == "encdec":
        return lambda p, b: encdec.encdec_prefill(cfg, plan, p, b["frames"],
                                                  b["tokens"], max_len)
    raise ValueError(cfg.family)


def make_decode_step(cfg: ArchConfig, shape: ShapeConfig, plan: PlanConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return lambda p, c, t, pos: T.lm_decode_step(cfg, plan, p, c, t, pos)
    if cfg.family == "ssm":
        return lambda p, c, t, pos: ssm_lm.ssm_decode_step(cfg, plan, p, c, t, pos)
    if cfg.family == "hybrid":
        return lambda p, c, t, pos: hybrid.hybrid_decode_step(cfg, plan, p, c, t, pos)
    if cfg.family == "encdec":
        return lambda p, c, t, pos: encdec.encdec_decode_step(cfg, plan, p, c, t, pos)
    raise ValueError(cfg.family)


def example_cache(cfg: ArchConfig, shape: ShapeConfig, plan: PlanConfig,
                  batch: Optional[int] = None):
    """ShapeDtypeStruct cache for a decode cell (capacity = shape.seq_len)."""
    B = batch if batch is not None else shape.global_batch
    dt = jnp.dtype(plan.param_dtype)
    if cfg.family in ("dense", "moe", "vlm"):
        mk = lambda: T.init_cache(cfg, B, shape.seq_len, dt)
    elif cfg.family == "ssm":
        mk = lambda: ssm_lm.init_ssm_cache(cfg, B, dt)
    elif cfg.family == "hybrid":
        mk = lambda: hybrid.init_hybrid_cache(cfg, B, shape.seq_len, dt)
    elif cfg.family == "encdec":
        mk = lambda: encdec.init_encdec_cache(cfg, B, shape.seq_len,
                                              encdec.DECODE_ENC_LEN, dt)
    else:
        raise ValueError(cfg.family)
    return jax.eval_shape(mk)


def example_batch(cfg: ArchConfig, shape: ShapeConfig, plan: PlanConfig):
    """ShapeDtypeStruct inputs for a cell (weak-type-correct, no alloc)."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(plan.param_dtype)
    tok = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    if shape.mode == "decode":
        return {"tokens": tok(B), "pos": tok(B)}
    if cfg.family == "vlm":
        Pf = cfg.num_frontend_tokens
        return {"patch_embeds": jax.ShapeDtypeStruct((B, Pf, cfg.d_model), dt),
                "tokens": tok(B, S - Pf)}
    if cfg.family == "encdec":
        # encoder frames carry the seq_len; decoder prompt: full seq for train,
        # BOS-only for prefill
        S_dec = S if shape.mode == "train" else 1
        return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), dt),
                "tokens": tok(B, S_dec)}
    return {"tokens": tok(B, S)}


# --------------------------------------------------------------------------
# partition-spec rules (see models/specs.py for the rule tables)
# --------------------------------------------------------------------------

from repro.models import specs as _specs

_leaf_name = _specs.leaf_name


def param_specs(cfg: ArchConfig, plan: PlanConfig, params_shapes):
    """PartitionSpec pytree for a param tree (must run under plan_scope)."""
    def one(path, leaf):
        rule = _specs.rule_for(_leaf_name(path), leaf.shape, plan.moe_ep)
        if rule is None:
            return P()                                  # norms, scalars: replicate
        return _specs.trailing_spec(leaf.shape, rule)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def cache_specs(cfg: ArchConfig, plan: PlanConfig, cache_shapes):
    def one(path, leaf):
        rule = _specs.CACHE_RULES.get(_leaf_name(path))
        if rule is None:
            return P()
        return _specs.trailing_spec(leaf.shape, rule)
    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def batch_specs(cfg: ArchConfig, plan: PlanConfig, batch_shapes):
    def one(path, leaf):
        fn = _specs.BATCH_RULES.get(_leaf_name(path))
        if fn is None:
            return P()
        from repro.models import partition
        return partition.spec(leaf.shape, fn(len(leaf.shape)))
    return jax.tree_util.tree_map_with_path(one, batch_shapes)


def to_shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


# --------------------------------------------------------------------------
# train step
# --------------------------------------------------------------------------

def init_train_state(cfg: ArchConfig, plan: PlanConfig, key, optimizer):
    mplan = plan.with_(param_dtype=plan.master_dtype)
    master = init_params(cfg, key, mplan)
    state = {"master": master, "opt": optimizer.init(master),
             "step": jnp.zeros((), jnp.int32)}
    if plan.grad_compression == "int8_ef":
        state["ef"] = int8_ef_init(master)
    return state


def train_state_specs(cfg: ArchConfig, plan: PlanConfig, state_shapes):
    ps = param_specs(cfg, plan, state_shapes["master"])
    out = {"master": ps,
           "opt": {"m": ps, "v": ps, "count": P()},
           "step": P()}
    if "ef" in state_shapes:
        out["ef"] = ps
    return out


def make_train_step(cfg: ArchConfig, plan: PlanConfig, optimizer):
    loss_fn = get_loss_fn(cfg, plan)
    compute_dt = jnp.dtype(plan.compute_dtype)

    def loss_of(master, mb):
        return loss_fn(cast_params(master, compute_dt), mb)

    def train_step(state, batch):
        master = state["master"]
        if plan.accum > 1:
            A = plan.accum
            mbs = jax.tree.map(
                lambda x: x.reshape((A, x.shape[0] // A) + x.shape[1:]), batch)
            gzero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), master)

            def body(carry, mb):
                lacc, gacc = carry
                l, g = jax.value_and_grad(loss_of)(master, mb)
                gacc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                    gacc, g)
                return (lacc + l, gacc), None

            if plan.unroll_inner:
                carry = (jnp.float32(0.0), gzero)
                for i in range(A):
                    carry, _ = body(carry, jax.tree.map(lambda x: x[i], mbs))
                loss, grads = carry
            else:
                (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), gzero),
                                                mbs)
            loss = loss / A
            grads = jax.tree.map(lambda g: g / A, grads)
        else:
            loss, grads = jax.value_and_grad(loss_of)(master, batch)

        new_state = dict(state)
        if plan.grad_compression == "int8_ef":
            grads, new_state["ef"] = int8_ef_compress(grads, state["ef"])
        new_master, new_opt, stats = optimizer.update(grads, state["opt"], master)
        new_state.update(master=new_master, opt=new_opt, step=state["step"] + 1)
        metrics = {"loss": loss, **stats}
        return new_state, metrics

    return train_step


def make_serve_step(cfg: ArchConfig, shape: ShapeConfig, plan: PlanConfig):
    """Decode-mode step: (params, cache, tokens, pos) -> (next_tokens, cache)."""
    decode = make_decode_step(cfg, shape, plan)
    return decode


# --------------------------------------------------------------------------
# analytic parameter counts (for MODEL_FLOPS)
# --------------------------------------------------------------------------

def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    import math
    shapes = jax.eval_shape(
        lambda k: init_params(cfg, k, PlanConfig()),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))
    if active_only and cfg.moe is not None:
        expert_names = {"we1", "we2", "we3"}
        routed = 0
        def count_routed(path, leaf):
            nonlocal routed
            if _leaf_name(path) in expert_names:
                routed += math.prod(leaf.shape)
            return leaf
        jax.tree_util.tree_map_with_path(count_routed, shapes)
        frac = cfg.moe.top_k / cfg.moe.num_experts
        total = total - routed + int(routed * frac)
    return total
