"""Decoder-only transformer LM (dense / GQA / MLA / MoE variants).

Layers are stacked (leading dim L) and iterated with ``lax.scan`` — measured
on this container an 80-layer unrolled compile takes 286 s vs 3.3 s scanned,
and the roofline harness compensates for scan's body-counted-once cost
accounting with a single-layer probe (see launch/dryrun.py).

The loss is vocab-parallel: logits are sharded on the (padded) vocab dim over
the TP axis and computed in sequence chunks under remat, so the full
(B, S, V) logits tensor never materializes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, PlanConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models.partition import pcon


def padded_vocab(cfg: ArchConfig) -> int:
    return ((cfg.vocab_size + 255) // 256) * 256


def _dtype(plan: PlanConfig):
    return jnp.dtype(plan.param_dtype)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_block(key, cfg: ArchConfig, dtype, *, use_moe: bool, d_ff: int):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": (L.init_mla(k1, cfg, dtype) if cfg.mla is not None
                 else L.init_attention(k1, cfg, dtype)),
    }
    if use_moe:
        p["moe"] = M.init_moe(k2, cfg, dtype)
    else:
        p["mlp"] = L.init_mlp(k2, cfg.d_model, d_ff, dtype)
    return p


def _stack_init(init_fn, key, n):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_lm(cfg: ArchConfig, key, plan: PlanConfig = PlanConfig()):
    dtype = _dtype(plan)
    Vp = padded_vocab(cfg)
    ke, kb, kp, kh = jax.random.split(key, 4)
    n_prefix = cfg.moe.first_dense_layers if cfg.moe else 0
    n_main = cfg.num_layers - n_prefix
    params = {
        "emb": L._dense_init(ke, (Vp, cfg.d_model), cfg.d_model, dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "blocks": _stack_init(
            lambda k: init_block(k, cfg, dtype, use_moe=cfg.moe is not None,
                                 d_ff=cfg.d_ff), kb, n_main),
    }
    if n_prefix:
        params["prefix_blocks"] = _stack_init(
            lambda k: init_block(k, cfg, dtype, use_moe=False,
                                 d_ff=cfg.moe.d_ff_dense), kp, n_prefix)
    if not cfg.tie_embeddings:
        params["head"] = L._dense_init(kh, (Vp, cfg.d_model), cfg.d_model, dtype)
    return params


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def block_apply(p, cfg: ArchConfig, x, positions, *, chunk, use_moe,
                unroll=False, moe_group=0, sp_residual=False):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        h, cache = L.mla_apply(p["attn"], cfg, h, positions, chunk=chunk,
                               unroll=unroll)
    else:
        h, cache = L.attention_apply(p["attn"], cfg, h, positions, chunk=chunk,
                                     unroll=unroll)
    x = x + h
    if sp_residual:
        x = pcon(x, "dp", "sp", None)   # force reduce-scatter of the partial
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if use_moe:
        h, aux = M.moe_apply(p["moe"], cfg, h, group_size=moe_group,
                             unroll=unroll)
    else:
        h, aux = L.mlp_apply(p["mlp"], h), jnp.float32(0.0)
    x = x + h
    if sp_residual:
        x = pcon(x, "dp", "sp", None)
    return x, cache, aux


def _scan_stack(cfg, plan: PlanConfig, blocks, x, positions, *, use_moe,
                collect_cache: bool):
    def body(x, lp):
        from repro.models.specs import gather_fsdp
        x = pcon(x, "dp", "sp", None)
        lp = gather_fsdp(lp, plan.moe_ep)   # FSDP: gather weights, per layer
        x, cache, aux = block_apply(lp, cfg, x, positions,
                                    chunk=plan.attn_chunk, use_moe=use_moe,
                                    unroll=plan.unroll_inner,
                                    moe_group=plan.moe_group_size,
                                    sp_residual=plan.sp_residual)
        return x, (cache if collect_cache else None, aux)

    if plan.remat == "block":
        body = jax.remat(body)
    from repro.models.util import stack_scan
    x, ys = stack_scan(body, x, blocks, plan.unroll_layers)
    caches, auxs = ys if ys is not None else (None, jnp.zeros((1,)))
    return x, caches, jnp.sum(auxs)


def lm_hidden(cfg: ArchConfig, plan: PlanConfig, params, embeds, positions,
              collect_cache=False):
    """embeds: (B, S, D) -> final hidden (B, S, D), caches, aux loss."""
    x = embeds
    caches = {}
    aux = jnp.float32(0.0)
    if "prefix_blocks" in params:
        x, c, a = _scan_stack(cfg, plan, params["prefix_blocks"], x, positions,
                              use_moe=False, collect_cache=collect_cache)
        caches["prefix"] = c
        aux += a
    x, c, a = _scan_stack(cfg, plan, params["blocks"], x, positions,
                          use_moe=cfg.moe is not None,
                          collect_cache=collect_cache)
    caches["main"] = c
    aux += a
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, caches, aux


def embed_tokens(cfg: ArchConfig, params, tokens):
    e = params["emb"][tokens]
    return pcon(e, "dp", None, None)


def unembed(cfg: ArchConfig, params, x):
    head = params["emb"] if "head" not in params else params["head"]
    head = pcon(head, "tp", None)           # gather FSDP dim before contraction
    logits = jnp.einsum("...d,vd->...v", x, head).astype(jnp.float32)
    return logits


# --------------------------------------------------------------------------
# loss (vocab-parallel, sequence-chunked)
# --------------------------------------------------------------------------

def lm_loss_from_hidden(cfg: ArchConfig, plan: PlanConfig, params, hidden,
                        targets, mask):
    """hidden: (B, S, D); targets/mask: (B, S).  Mean NLL over mask."""
    B, S, D = hidden.shape
    Vp = padded_vocab(cfg)
    head = params["emb"] if "head" not in params else params["head"]
    head = pcon(head, "tp", None)           # gather FSDP dim before contraction
    chunk = min(plan.loss_chunk, S)
    if S % chunk:
        chunk = S
    nc = S // chunk

    def chunk_loss(args):
        xc, tc, mc = args
        logits = jnp.einsum("bsd,vd->bsv", xc, head).astype(jnp.float32)
        logits = pcon(logits, "dp", None, "tp")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(tc, Vp, dtype=jnp.float32)
        tgt = jnp.sum(logits * onehot, axis=-1)
        return jnp.sum((lse - tgt) * mc)

    if nc == 1:
        total = jax.remat(chunk_loss)((hidden, targets, mask.astype(jnp.float32)))
    else:
        xs = (hidden.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3),
              targets.reshape(B, nc, chunk).transpose(1, 0, 2),
              mask.astype(jnp.float32).reshape(B, nc, chunk).transpose(1, 0, 2))
        if plan.unroll_inner:
            total = sum(jax.remat(chunk_loss)(jax.tree.map(lambda a: a[i], xs))
                        for i in range(nc))
        else:
            total, _ = jax.lax.scan(
                lambda c, a: (c + jax.remat(chunk_loss)(a), None),
                jnp.float32(0.0), xs)
    return total / jnp.maximum(jnp.sum(mask), 1.0)


def lm_loss(cfg: ArchConfig, plan: PlanConfig, params, tokens,
            extra_embeds: Optional[jnp.ndarray] = None, aux_coef=0.01):
    """Next-token loss.  tokens: (B, S_text).  extra_embeds: (B, P, D) prepended
    (VLM patches); loss applies to text positions only."""
    e = embed_tokens(cfg, params, tokens)
    if extra_embeds is not None:
        e = jnp.concatenate([extra_embeds.astype(e.dtype), e], axis=1)
    Bsz, S, _ = e.shape
    positions = jnp.arange(S)
    hidden, _, aux = lm_hidden(cfg, plan, params, e, positions)
    P = 0 if extra_embeds is None else extra_embeds.shape[1]
    hid_text = hidden[:, P:, :]
    targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.concatenate(
        [jnp.ones((Bsz, tokens.shape[1] - 1), jnp.float32),
         jnp.zeros((Bsz, 1), jnp.float32)], axis=1)
    loss = lm_loss_from_hidden(cfg, plan, params, hid_text, targets, mask)
    return loss + aux_coef * aux


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    """Decode KV cache pytree (dense and MLA layouts)."""
    n_prefix = cfg.moe.first_dense_layers if cfg.moe else 0
    n_main = cfg.num_layers - n_prefix
    def dense_cache(n):
        return {
            "k": jnp.zeros((n, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((n, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        }
    def mla_cache(n):
        m = cfg.mla
        return {
            "c": jnp.zeros((n, batch, max_len, m.kv_lora_rank), dtype),
            "kr": jnp.zeros((n, batch, max_len, m.qk_rope_head_dim), dtype),
        }
    mk = mla_cache if cfg.mla is not None else dense_cache
    cache = {"main": mk(n_main)}
    if n_prefix:
        cache["prefix"] = mk(n_prefix)
    return cache


def block_decode(p, cfg: ArchConfig, x, cache_slices, pos, *, use_moe,
                 use_cp=False):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        h, c0, c1 = L.mla_decode(p["attn"], cfg, h, cache_slices["c"],
                                 cache_slices["kr"], pos)
        new_cache = {"c": c0, "kr": c1}
    else:
        h, c0, c1 = L.attention_decode(p["attn"], cfg, h, cache_slices["k"],
                                       cache_slices["v"], pos, use_cp=use_cp)
        new_cache = {"k": c0, "v": c1}
    x = x + h
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if use_moe:
        h, _ = M.moe_apply(p["moe"], cfg, h)
    else:
        h = L.mlp_apply(p["mlp"], h)
    return x + h, new_cache


def _decode_stack(cfg, plan, blocks, cache, x, pos, *, use_moe):
    """fori_loop with the cache in the CARRY and in-place dynamic updates —
    scan's xs->ys cache threading double-buffers the (huge) cache on the CPU
    scheduler, while a while-loop carry aliases in place."""
    from repro.models.specs import gather_fsdp
    from repro.models.util import stack_scan
    L = jax.tree.leaves(blocks)[0].shape[0]

    def one_layer(i, x, cache):
        lp = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            blocks)
        cs = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            cache)
        lp = gather_fsdp(lp, plan.moe_ep)
        x, new_cs = block_decode(lp, cfg, x, cs, pos, use_moe=use_moe,
                                 use_cp=plan.decode_cp)
        cache = jax.tree.map(
            lambda a, u: jax.lax.dynamic_update_index_in_dim(
                a, u.astype(a.dtype), i, 0), cache, new_cs)
        return x, cache

    if plan.unroll_layers:
        for i in range(L):
            x, cache = one_layer(i, x, cache)
        return x, cache
    x, cache = jax.lax.fori_loop(
        0, L, lambda i, c: one_layer(i, c[0], c[1]), (x, cache))
    return x, cache


def lm_decode_step(cfg: ArchConfig, plan: PlanConfig, params, cache, tokens, pos):
    """One decode step.  tokens: (B,) int32; pos: (B,) write positions.

    Returns (next_tokens (B,), new_cache)."""
    x = params["emb"][tokens]
    new_cache = {}
    if "prefix_blocks" in params:
        x, c = _decode_stack(cfg, plan, params["prefix_blocks"], cache["prefix"],
                             x, pos, use_moe=False)
        new_cache["prefix"] = c
    x, c = _decode_stack(cfg, plan, params["blocks"], cache["main"], x, pos,
                         use_moe=cfg.moe is not None)
    new_cache["main"] = c
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, x)                     # (B, Vp)
    logits = pcon(logits, "dp", "tp")
    next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tokens, new_cache


def lm_prefill(cfg: ArchConfig, plan: PlanConfig, params, tokens, max_len,
               extra_embeds=None, cache_dtype=None):
    """Run the prompt, build a decode cache of capacity max_len.

    Returns (last_logits (B, Vp), cache, next_pos (B,))."""
    e = embed_tokens(cfg, params, tokens)
    if extra_embeds is not None:
        e = jnp.concatenate([extra_embeds.astype(e.dtype), e], axis=1)
    Bsz, S, _ = e.shape
    positions = jnp.arange(S)
    hidden, caches, _ = lm_hidden(cfg, plan, params, e, positions,
                                  collect_cache=True)
    cdt = cache_dtype or e.dtype
    cache = init_cache(cfg, Bsz, max_len, cdt)

    def fill(dst, src_pair, names):
        for name, src in zip(names, src_pair):
            # src: (L, B, S, ...) -> write into (L, B, max_len, ...)
            dst[name] = jax.lax.dynamic_update_slice_in_dim(
                dst[name], src.astype(cdt), 0, axis=2)
        return dst

    names = ("c", "kr") if cfg.mla is not None else ("k", "v")
    if "prefix" in cache and caches.get("prefix") is not None:
        cache["prefix"] = fill(cache["prefix"], caches["prefix"], names)
    cache["main"] = fill(cache["main"], caches["main"], names)
    for grp in cache.values():
        for k in grp:
            grp[k] = pcon(grp[k], None, "dp", "cache", None) if grp[k].ndim == 4 \
                else pcon(grp[k], None, "dp", "cache", None, None)
    last = hidden[:, -1, :]
    logits = unembed(cfg, params, last)
    next_pos = jnp.full((Bsz,), S, jnp.int32)
    return logits, cache, next_pos
