"""Mamba2 (state-space duality) block — chunked SSD scan, pure JAX reference.

The intra-chunk quadratic part is the compute hot-spot; on TPU it is replaced
by the Pallas kernel in ``repro.kernels.ssd_scan`` (same math, VMEM-tiled).
Heads are TP-sharded; the inter-chunk recurrence is a ``lax.scan`` with a
local (per-head-shard) carry, so the whole block needs no collectives until
the output projection.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models.layers import _dense_init, rms_norm_gated
from repro.models.partition import pcon


# --------------------------------------------------------------------------
# causal depthwise conv1d
# --------------------------------------------------------------------------

def causal_conv1d(x, w, b):
    """x: (B, S, Ch); w: (W, Ch); b: (Ch,).  Shift-and-add (W is tiny)."""
    W = w.shape[0]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):
        shift = W - 1 - i
        xi = x if shift == 0 else jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xi.astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def conv1d_step(conv_state, x_new, w, b):
    """conv_state: (B, W-1, Ch) raw past inputs; x_new: (B, Ch)."""
    window = jnp.concatenate([conv_state, x_new[:, None]], axis=1)   # (B, W, Ch)
    y = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    y = (y + b.astype(jnp.float32)).astype(x_new.dtype)
    return y, window[:, 1:]


# --------------------------------------------------------------------------
# SSD scan (chunked state-space dual form)
# --------------------------------------------------------------------------

def _segsum(x):
    """x: (..., Q) -> (..., Q, Q) with S[i, j] = sum_{j < k <= i} x[k], -inf above diag."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None,
                unroll: bool = False):
    """SSD over chunks.

    x: (b, s, h, p); dt: (b, s, h); A: (h,) negative decay rates;
    B, C: (b, s, g, n).  Returns (y (b, s, h, p), final_state (b, h, n, p)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))     # dt=0 => identity update
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S = x.shape[1]
    nc, Q = S // chunk, chunk

    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    xdt = xdt.reshape(b, nc, Q, g, hg, p)
    da = (dt.astype(jnp.float32) * A.astype(jnp.float32)).reshape(b, nc, Q, h)
    da = da.transpose(0, 3, 1, 2)                        # (b, h, nc, Q)
    Bc = B.astype(jnp.float32).reshape(b, nc, Q, g, n)
    Cc = C.astype(jnp.float32).reshape(b, nc, Q, g, n)

    A_cs = jnp.cumsum(da, axis=-1)                       # (b, h, nc, Q)
    L = jnp.exp(_segsum(da))                             # (b, h, nc, Q, Q)
    Lg = L.reshape(b, g, hg, nc, Q, Q)

    # intra-chunk (quadratic, attention-like)
    G = jnp.einsum("bcqgn,bckgn->bgcqk", Cc, Bc)         # (b, g, nc, Q, Q)
    M = G[:, :, None] * Lg                               # (b, g, hg, nc, Q, Q)
    Y_intra = jnp.einsum("bghcqk,bckghp->bcqghp", M, xdt)

    # per-chunk input state contribution
    decay_states = jnp.exp(A_cs[..., -1:] - A_cs)        # (b, h, nc, Q)
    dsg = decay_states.reshape(b, g, hg, nc, Q)
    states = jnp.einsum("bckgn,bghck,bckghp->bcghnp", Bc, dsg, xdt)  # (b,nc,g,hg,n,p)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(A_cs[..., -1])                 # (b, h, nc)
    cdg = chunk_decay.reshape(b, g, hg, nc).transpose(3, 0, 1, 2)    # (nc, b, g, hg)
    states_t = states.transpose(1, 0, 2, 3, 4, 5)        # (nc, b, g, hg, n, p)
    if initial_state is None:
        init = jnp.zeros((b, g, hg, n, p), jnp.float32)
    else:
        init = initial_state.reshape(b, g, hg, n, p).astype(jnp.float32)

    def step(run, inp):
        st, dec = inp
        new = run * dec[..., None, None] + st
        return new, run                                   # emit state BEFORE chunk

    if unroll:
        run, prevs = init, []
        for ci in range(nc):
            run, prev = step(run, (states_t[ci], cdg[ci]))
            prevs.append(prev)
        final, prev_states = run, jnp.stack(prevs)
    else:
        final, prev_states = jax.lax.scan(step, init, (states_t, cdg))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4, 5)  # (b, nc, g, hg, n, p)

    # inter-chunk output: C_t · (decayed running state)
    state_decay = jnp.exp(A_cs).reshape(b, g, hg, nc, Q)
    Y_inter = jnp.einsum("bcqgn,bcghnp,bghcq->bcqghp", Cc, prev_states, state_decay)

    y = (Y_intra + Y_inter).reshape(b, S, h, p)[:, :s]
    return y.astype(x.dtype), final.reshape(b, h, n, p)


def ssd_step(state, x_t, dt_t, A, B_t, C_t):
    """Single-token recurrence.  state: (b,h,n,p); x_t: (b,h,p); dt_t: (b,h);
    B_t, C_t: (b,g,n)."""
    b, h, n, p = state.shape
    g = B_t.shape[1]
    hg = h // g
    da = jnp.exp(dt_t.astype(jnp.float32) * A.astype(jnp.float32))   # (b,h)
    xdt = x_t.astype(jnp.float32) * dt_t.astype(jnp.float32)[..., None]
    Bh = jnp.repeat(B_t.astype(jnp.float32), hg, axis=1)             # (b,h,n)
    Ch = jnp.repeat(C_t.astype(jnp.float32), hg, axis=1)
    new_state = state * da[..., None, None] + jnp.einsum("bhn,bhp->bhnp", Bh, xdt)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state)
    return new_state, y.astype(x_t.dtype)


# --------------------------------------------------------------------------
# Mamba2 block
# --------------------------------------------------------------------------

def init_mamba_block(key, cfg: ArchConfig, dtype):
    m: SSMConfig = cfg.ssm
    D = cfg.d_model
    di = m.expand * D
    H = di // m.head_dim
    GN = m.n_groups * m.state_dim
    conv_ch = di + 2 * GN
    ks = jax.random.split(key, 8)
    dt = jnp.exp(jax.random.uniform(ks[6], (H,), jnp.float32,
                                    math.log(1e-3), math.log(1e-1)))
    return {
        "ln": jnp.ones((D,), dtype),
        "w_z": _dense_init(ks[0], (D, di), D, dtype),
        "w_x": _dense_init(ks[1], (D, di), D, dtype),
        "w_B": _dense_init(ks[2], (D, GN), D, dtype),
        "w_C": _dense_init(ks[3], (D, GN), D, dtype),
        "w_dt": _dense_init(ks[4], (D, H), D, dtype),
        "dt_bias": jnp.log(jnp.expm1(dt)).astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "Dskip": jnp.ones((H,), jnp.float32),
        "conv_w": _dense_init(ks[5], (m.conv_width, conv_ch), m.conv_width, dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "ssm_norm": jnp.ones((di,), dtype),
        "out_proj": _dense_init(ks[7], (di, D), di, dtype),
    }


def _split_xbc(xBC, cfg):
    m = cfg.ssm
    di = m.expand * cfg.d_model
    GN = m.n_groups * m.state_dim
    x = xBC[..., :di]
    B = xBC[..., di:di + GN]
    C = xBC[..., di + GN:]
    return x, B, C


def mamba_apply(p, cfg: ArchConfig, x, initial_state=None, unroll=False):
    """x: (B, S, D).  Returns (out (B,S,D), (ssm_state, conv_tail))."""
    m: SSMConfig = cfg.ssm
    Bsz, S, D = x.shape
    di = m.expand * D
    H = di // m.head_dim
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    xx = jnp.einsum("bsd,de->bse", x, p["w_x"])
    xB = jnp.einsum("bsd,de->bse", x, p["w_B"])
    xC = jnp.einsum("bsd,de->bse", x, p["w_C"])
    xBC = jnp.concatenate([xx, xB, xC], axis=-1)
    conv_tail = xBC[:, -(m.conv_width - 1):]
    if initial_state is not None:
        _, prev_conv = initial_state
        xBC_in = jnp.concatenate([prev_conv, xBC], axis=1)
        conv = causal_conv1d(xBC_in, p["conv_w"], p["conv_b"])[:, m.conv_width - 1:]
    else:
        conv = causal_conv1d(xBC, p["conv_w"], p["conv_b"])
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xs, Bs, Cs = _split_xbc(conv, cfg)
    xs = pcon(xs.reshape(Bsz, S, H, m.head_dim), "dp", None, "tp", None)
    Bs = Bs.reshape(Bsz, S, m.n_groups, m.state_dim)
    Cs = Cs.reshape(Bsz, S, m.n_groups, m.state_dim)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    ssm_init = initial_state[0] if initial_state is not None else None
    y, fstate = ssd_chunked(xs, dt, A, Bs, Cs, m.chunk, ssm_init, unroll=unroll)
    y = y + p["Dskip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(Bsz, S, di).astype(x.dtype)
    y = rms_norm_gated(y, z, p["ssm_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, (fstate, conv_tail)


def mamba_step(p, cfg: ArchConfig, x, state):
    """Single-token decode.  x: (B, D); state = (ssm_state, conv_state)."""
    m: SSMConfig = cfg.ssm
    Bsz, D = x.shape
    di = m.expand * D
    H = di // m.head_dim
    ssm_state, conv_state = state
    z = jnp.einsum("bd,de->be", x, p["w_z"])
    xx = jnp.einsum("bd,de->be", x, p["w_x"])
    xB = jnp.einsum("bd,de->be", x, p["w_B"])
    xC = jnp.einsum("bd,de->be", x, p["w_C"])
    xBC = jnp.concatenate([xx, xB, xC], axis=-1)
    conv, conv_state = conv1d_step(conv_state, xBC, p["conv_w"], p["conv_b"])
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xs, Bs, Cs = _split_xbc(conv, cfg)
    xs = xs.reshape(Bsz, H, m.head_dim)
    Bs = Bs.reshape(Bsz, m.n_groups, m.state_dim)
    Cs = Cs.reshape(Bsz, m.n_groups, m.state_dim)
    dt = jax.nn.softplus(
        jnp.einsum("bd,dh->bh", x, p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    ssm_state, y = ssd_step(ssm_state, xs, dt, A, Bs, Cs)
    y = y + p["Dskip"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(Bsz, di).astype(x.dtype)
    y = rms_norm_gated(y, z, p["ssm_norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])
    return out, (ssm_state, conv_state)


def init_mamba_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    m: SSMConfig = cfg.ssm
    di = m.expand * cfg.d_model
    H = di // m.head_dim
    conv_ch = di + 2 * m.n_groups * m.state_dim
    return (jnp.zeros((batch, H, m.state_dim, m.head_dim), jnp.float32),
            jnp.zeros((batch, m.conv_width - 1, conv_ch), dtype))
