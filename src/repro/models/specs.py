"""Partition-spec rules for params / caches / batches, plus the FSDP gather
constraint.

``gather_fsdp`` is load-bearing: FSDP shards weights along contraction dims,
and without an explicit per-layer constraint the SPMD partitioner may choose
partial-sums + full-size activation all-reduces instead of gathering the
(much smaller) weights — measured 15.5 GB/layer/device of collectives on
internlm2 vs ~0.7 GB with the constraint.  Calling gather_fsdp(lp) at the top
of every layer body pins the all-gather-weights schedule (the standard FSDP
pattern, and what frameworks like MaxText do via logical axis rules).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.models import partition

PARAM_RULES = {
    "emb": ("tp", "fsdp"), "head": ("tp", "fsdp"),
    "wq": ("fsdp", "tp", None), "wk": ("fsdp", "tp", None),
    "wv": ("fsdp", "tp", None), "wo": ("tp", None, "fsdp"),
    "bq": ("tp", None), "bk": (None, None), "bv": (None, None),
    "w1": ("fsdp", "tp"), "w3": ("fsdp", "tp"), "w2": ("tp", "fsdp"),
    "router": ("fsdp", None),
    "wkv_a": ("fsdp", None), "kv_norm": (None,), "wkv_b": (None, "tp", None),
    "w_z": ("fsdp", "tp"), "w_x": ("fsdp", "tp"),
    "w_B": ("fsdp", None), "w_C": ("fsdp", None), "w_dt": ("fsdp", None),
    "conv_w": (None, "tp"), "conv_b": ("tp",),
    "ssm_norm": ("tp",), "out_proj": ("tp", "fsdp"),
    "a_q": ("fsdp", None), "a_k": ("fsdp", None), "a_v": ("fsdp", None),
    "a_1": ("fsdp", None), "a_3": ("fsdp", None),
    "b_q": (None, "tp", None), "b_k": (None, "tp", None),
    "b_v": (None, "tp", None), "b_1": (None, "tp"), "b_3": (None, "tp"),
}

CACHE_RULES = {
    "k": ("dp", "cache", None, None), "v": ("dp", "cache", None, None),
    "xk": ("dp", "cache", None, None), "xv": ("dp", "cache", None, None),
    "c": ("dp", "cache", None), "kr": ("dp", "cache", None),
    "ssm": ("dp", "tp", None, None), "ssm_g": ("dp", "tp", None, None),
    "ssm_t": ("dp", "tp", None, None),
    "conv": ("dp", None, "tp"), "conv_g": ("dp", None, "tp"),
    "conv_t": ("dp", None, "tp"),
}

BATCH_RULES = {
    "tokens": lambda nd: ("dp",) + (None,) * (nd - 1),
    "pos": lambda nd: ("dp",),
    "frames": lambda nd: ("dp", None, None),
    "patch_embeds": lambda nd: ("dp", None, None),
}

EXPERT_NAMES = {"we1", "we2", "we3"}


def leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def _expert_rule(name: str, shape, moe_ep: bool):
    env = partition.current_env()
    tp_size = env.axes_size(env.resolve("tp")) if env else 1
    E = shape[-3]
    ep_ok = moe_ep and tp_size > 1 and E % tp_size == 0
    if name == "we2":
        return ("ep", None, "fsdp") if ep_ok else (None, "tp", "fsdp")
    return ("ep", "fsdp", None) if ep_ok else (None, "fsdp", "tp")


def rule_for(name: str, shape, moe_ep: bool = True):
    if name in EXPERT_NAMES:
        return _expert_rule(name, shape, moe_ep)
    return PARAM_RULES.get(name)


def trailing_spec(shape, rule) -> P:
    names = (None,) * (len(shape) - len(rule)) + tuple(rule)
    return partition.spec(shape, names)


def gather_fsdp(tree, moe_ep: bool = True):
    """Constrain every weight to its spec with the FSDP axes dropped —
    pinning per-layer all-gather-weights instead of activation all-reduces."""
    env = partition.current_env()
    if env is None:
        return tree

    def one(path, leaf):
        rule = rule_for(leaf_name(path), leaf.shape, moe_ep)
        if rule is None or "fsdp" not in rule:
            return leaf
        names = (None,) * (leaf.ndim - len(rule)) + tuple(
            None if n == "fsdp" else n for n in rule)
        return partition.pcon(leaf, *names)

    return jax.tree_util.tree_map_with_path(one, tree)
