"""InternVL2-style VLM: vision frontend STUB + decoder LM backbone.

``input_specs()`` provides precomputed patch embeddings (B, P, D) — the
InternViT tower is out of scope per the assignment.  Patches are prepended to
the token embeddings; loss applies to text positions only.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ArchConfig, PlanConfig
from repro.models import transformer as T


init_vlm = T.init_lm


def vlm_loss(cfg: ArchConfig, plan: PlanConfig, params, patch_embeds, tokens,
             aux_coef=0.0):
    return T.lm_loss(cfg, plan, params, tokens, extra_embeds=patch_embeds,
                     aux_coef=aux_coef)


def vlm_prefill(cfg, plan, params, patch_embeds, tokens, max_len):
    return T.lm_prefill(cfg, plan, params, tokens, max_len,
                        extra_embeds=patch_embeds)


vlm_decode_step = T.lm_decode_step
