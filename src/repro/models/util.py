"""Scan-or-unroll helper.

``cost_analysis()`` on a compiled XLA program counts a while-loop body ONCE —
it does not scale by trip count (measured on this container; see DESIGN.md
§5).  Dry-run cost probes therefore python-unroll the layer stacks at reduced
depths (L1=1, L2=2) and extrapolate linearly; production programs keep
``lax.scan`` (3.3 s vs 286 s compile at 80 layers)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stack_scan(body, x, stacked, unroll: bool):
    """lax.scan(body, x, stacked) or an equivalent python loop."""
    if not unroll:
        return jax.lax.scan(body, x, stacked)
    n = jax.tree.leaves(stacked)[0].shape[0]
    ys = []
    for i in range(n):
        x, y = body(x, jax.tree.map(lambda a: a[i], stacked))
        ys.append(y)
    if not ys or all(l is None for l in jax.tree.leaves(ys[0],
                                                        is_leaf=lambda z: z is None)):
        return x, None
    return x, jax.tree.map(lambda *a: jnp.stack(a), *ys)
