"""Shared model layers: norms, RoPE, SwiGLU MLP, GQA/MHA/MLA attention.

Everything is functional: params are plain dict pytrees created by ``init_*``
functions; forward functions take (params, inputs).  Sharding is expressed via
``partition.pcon`` logical constraints so the same code runs unsharded on CPU
and fully sharded under a plan scope.

Attention follows the expand-KV formulation (repeat KV heads to H, shard H
over TP) — on real TPUs the Pallas flash kernel (`repro.kernels.flash_attention`)
replaces the jnp path and never materializes expanded KV.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MLAConfig
from repro.models.partition import pcon

# --------------------------------------------------------------------------
# initialization helpers
# --------------------------------------------------------------------------

def _dense_init(key, shape, in_dim, dtype):
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def rms_norm_gated(x, z, w, eps: float = 1e-5):
    """Mamba2 gated norm: rmsnorm(x * silu(z))."""
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_apply(x, positions, theta: float):
    """Rotate the last dim.  x: (..., S, H, rd) or (..., H, rd) for decode.

    positions broadcasts against x's sequence/batch dims: (S,) or (B, S) or
    (B,) for single-token decode.
    """
    rd = x.shape[-1]
    assert rd % 2 == 0, "rope dim must be even"
    half = rd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs          # (..., half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == ang.ndim + 2:      # (..., S, H, rd) vs (..., S, half)
        cos, sin = cos[..., None, :], sin[..., None, :]
    elif x.ndim == ang.ndim + 1:    # decode: (B, H, rd) vs (B, half)
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": _dense_init(k1, (d_model, d_ff), d_model, dtype),
        "w3": _dense_init(k2, (d_model, d_ff), d_model, dtype),
        "w2": _dense_init(k3, (d_ff, d_model), d_ff, dtype),
    }


def mlp_apply(p, x):
    h = jnp.einsum("...d,df->...f", x, p["w1"])
    g = jnp.einsum("...d,df->...f", x, p["w3"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    h = pcon(h, "dp", None, "tp") if h.ndim == 3 else h
    return jnp.einsum("...f,fd->...d", h, p["w2"])


# --------------------------------------------------------------------------
# dense GQA/MHA attention
# --------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig, dtype):
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (D, H, hd), D, dtype),
        "wk": _dense_init(ks[1], (D, KV, hd), D, dtype),
        "wv": _dense_init(ks[2], (D, KV, hd), D, dtype),
        "wo": _dense_init(ks[3], (H, hd, D), H * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype)
    return p


def _expand_kv(k, n_rep):
    return k if n_rep == 1 else jnp.repeat(k, n_rep, axis=-2)


def sdpa_chunked(q, k, v, *, causal: bool, chunk: int, q_offset=0,
                 kv_len: Optional[jnp.ndarray] = None, unroll: bool = False):
    """Query-chunked attention.  q: (B,Sq,H,hd); k,v: (B,Sk,H,hd).

    kv_len: optional (B,) valid KV lengths (decode-style masking).
    Memory is bounded by one (B, H, chunk, Sk) score block.
    unroll: python loop over chunks (dry-run cost accounting; see PlanConfig).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    vd = v.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    chunk = min(chunk, Sq)
    if Sq % chunk != 0:
        chunk = Sq
    nc = Sq // chunk
    kpos = jnp.arange(Sk)

    def one_chunk(ci, qc):
        # qc: (B, chunk, H, hd)
        s = jnp.einsum("bchd,bshd->bhcs", qc, k).astype(jnp.float32) * scale
        if causal:
            qpos = q_offset + ci * chunk + jnp.arange(chunk)
            m = qpos[:, None] >= kpos[None, :]
            s = jnp.where(m[None, None], s, -jnp.inf)
        if kv_len is not None:
            m2 = kpos[None, :] < kv_len[:, None]
            s = jnp.where(m2[:, None, None, :], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return jnp.einsum("bhcs,bshd->bchd", p, v)

    if nc == 1:
        return one_chunk(0, q)
    qr = q.reshape(B, nc, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    if unroll:
        outs = jnp.stack([one_chunk(i, qr[i]) for i in range(nc)])
    else:
        _, outs = jax.lax.scan(lambda c, args: (c, one_chunk(args[0], args[1])),
                               0, (jnp.arange(nc), qr))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, vd)


def attention_apply(p, cfg: ArchConfig, x, positions, *, causal=True,
                    chunk=1024, xkv=None, unroll=False):
    """Full-sequence attention (train/prefill).  Returns (out, (k, v) cache)."""
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    src = x if xkv is None else xkv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if positions is not None:
        q = rope_apply(q, positions, cfg.rope_theta)
        kpos = positions if xkv is None else jnp.arange(src.shape[1])
        k = rope_apply(k, kpos, cfg.rope_theta)
    kv_cache = (k, v)
    k = pcon(_expand_kv(k, H // KV), "dp", None, "tp", None)
    v = pcon(_expand_kv(v, H // KV), "dp", None, "tp", None)
    q = pcon(q, "dp", None, "tp", None)
    o = sdpa_chunked(q, k, v, causal=causal, chunk=chunk, unroll=unroll)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, kv_cache


def attention_decode(p, cfg: ArchConfig, x, cache_k, cache_v, pos,
                     use_cp: bool = False):
    """Single-token decode.  x: (B, D); cache_k/v: (B, Smax, KV, hd); pos: (B,).

    Returns (out (B, D), new_k_entry, new_v_entry) — the caller owns the cache
    update (so layer-scan can thread stacked caches).

    use_cp: context-parallel attention over the seq-sharded cache via
    shard_map — each TP shard attends to its local KV span and the shards
    combine with the log-sum-exp trick (psum of (B,H[,hd]) partials).  The
    naive jnp path makes XLA all-gather the sharded cache instead (measured
    2.2 GB/layer/device on internlm2 decode_32k).
    """
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
    k = jnp.einsum("bd,dhk->bhk", x, p["wk"])
    v = jnp.einsum("bd,dhk->bhk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = rope_apply(q, pos, cfg.rope_theta)
    k = rope_apply(k, pos, cfg.rope_theta)
    # constrain layout BEFORE the in-place update so the .set aliases the
    # donated buffer instead of materializing a resharded copy
    B = x.shape[0]
    cache_k = pcon(cache_k, "dp", "cache", None, None)
    cache_v = pcon(cache_v, "dp", "cache", None, None)
    cache_k = cache_k.at[jnp.arange(B), pos].set(k.astype(cache_k.dtype))
    cache_v = cache_v.at[jnp.arange(B), pos].set(v.astype(cache_v.dtype))
    o = _decode_attend_cp(cfg, q, cache_k, cache_v, pos) if use_cp else \
        _decode_attend(cfg, q, cache_k, cache_v, pos)
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"])
    return out, cache_k, cache_v


def _decode_attend(cfg, q, cache_k, cache_v, pos):
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ke = _expand_kv(cache_k, H // KV)
    ve = _expand_kv(cache_v, H // KV)
    s = jnp.einsum("bhk,bshk->bhs", q, ke).astype(jnp.float32) / math.sqrt(hd)
    mask = jnp.arange(ke.shape[1])[None, :] <= pos[:, None]
    s = jnp.where(mask[:, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhs,bshk->bhk", w, ve)


def _decode_attend_cp(cfg, q, cache_k, cache_v, pos):
    """Context-parallel decode attention: shard_map over the cache-seq axis."""
    from repro.models.partition import current_env
    from repro.models import specs as _specs
    env = current_env()
    tp = env.resolve("cache") if env is not None else None
    if tp is None:                         # no mesh / cache not seq-sharded
        return _decode_attend(cfg, q, cache_k, cache_v, pos)
    mesh = env.mesh
    dpax = env.resolve("dp")
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    B = q.shape[0]
    from jax.sharding import PartitionSpec as P
    from repro.models.partition import spec as _pspec

    dp_entry = _pspec((B,), ("dp",))[0]    # honors divisibility guard

    def shard_fn(q, ck, cv, pos):
        # local spans: ck/cv (Bl, S_loc, KV, hd); q replicated over tp
        s_loc = ck.shape[1]
        idx = jax.lax.axis_index(tp)
        kpos = idx * s_loc + jnp.arange(s_loc)
        ke = _expand_kv(ck, H // KV)
        ve = _expand_kv(cv, H // KV)
        s = jnp.einsum("bhk,bshk->bhs", q, ke).astype(jnp.float32) \
            / math.sqrt(hd)
        mask = kpos[None, :] <= pos[:, None]
        s = jnp.where(mask[:, None, :], s, -jnp.inf)
        m = jnp.max(s, axis=-1)                            # (B, H) local max
        mg = jax.lax.pmax(m, tp)                           # global max
        w = jnp.exp(s - mg[..., None])
        l = jax.lax.psum(jnp.sum(w, axis=-1), tp)          # global denom
        o = jnp.einsum("bhs,bshk->bhk", w.astype(q.dtype), ve)
        o = jax.lax.psum(o.astype(jnp.float32), tp)
        return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    return _shard_map_compat(
        shard_fn, mesh=mesh,
        in_specs=(P(dp_entry, None, None), P(dp_entry, tp, None, None),
                  P(dp_entry, tp, None, None), P(dp_entry)),
        out_specs=P(dp_entry, None, None),
    )(q, cache_k, cache_v, pos)


def _shard_map_compat(fn, *, mesh, in_specs, out_specs):
    """shard_map across JAX versions: top-level ``jax.shard_map`` with
    ``check_vma`` on current releases, ``jax.experimental.shard_map`` with
    ``check_rep`` on 0.4.x."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        except TypeError:     # top-level shard_map that still takes check_rep
            return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
    from jax.experimental.shard_map import shard_map as esm
    return esm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


# --------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2)
# --------------------------------------------------------------------------

def init_mla(key, cfg: ArchConfig, dtype):
    m: MLAConfig = cfg.mla
    D, H = cfg.d_model, cfg.num_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense_init(ks[0], (D, H, qd), D, dtype),
        "wkv_a": _dense_init(ks[1], (D, m.kv_lora_rank + m.qk_rope_head_dim), D, dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wkv_b": _dense_init(ks[2], (m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim),
                             m.kv_lora_rank, dtype),
        "wo": _dense_init(ks[3], (H, m.v_head_dim, D), H * m.v_head_dim, dtype),
    }


def mla_apply(p, cfg: ArchConfig, x, positions, *, chunk=1024, unroll=False):
    """MLA train/prefill (naive expansion).  Returns (out, (c_kv, k_rope))."""
    m: MLAConfig = cfg.mla
    H = cfg.num_heads
    nope, rd, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope_apply(q_rope, positions, cfg.rope_theta)
    a = jnp.einsum("bsd,dk->bsk", x, p["wkv_a"])
    c_kv = rms_norm(a[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = rope_apply(a[..., m.kv_lora_rank:][:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]                       # (B,S,rd)
    kv = jnp.einsum("bsk,khj->bshj", c_kv, p["wkv_b"])
    k_nope, v = kv[..., :nope], kv[..., nope:]
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], k_nope.shape[:3] + (rd,))],
        axis=-1)
    qf = pcon(qf, "dp", None, "tp", None)
    kf = pcon(kf, "dp", None, "tp", None)
    v = pcon(v, "dp", None, "tp", None)
    o = sdpa_chunked(qf, kf, v, causal=True, chunk=chunk, unroll=unroll)
    out = jnp.einsum("bshv,hvd->bsd", o, p["wo"])
    return out, (c_kv, k_rope)


def mla_decode(p, cfg: ArchConfig, x, cache_c, cache_kr, pos):
    """Absorbed MLA decode: attend in the latent space (never expand KV).

    x: (B,D); cache_c: (B,Smax,lora); cache_kr: (B,Smax,rd); pos: (B,).
    """
    m: MLAConfig = cfg.mla
    H = cfg.num_heads
    nope, rd, vd, R = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim, m.kv_lora_rank
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
    q_nope, q_rope = q[..., :nope], rope_apply(q[..., nope:], pos, cfg.rope_theta)
    a = jnp.einsum("bd,dk->bk", x, p["wkv_a"])
    c_new = rms_norm(a[..., :R], p["kv_norm"], cfg.norm_eps)
    kr_new = rope_apply(a[:, None, R:], pos, cfg.rope_theta)[:, 0]
    B = x.shape[0]
    cache_c = pcon(cache_c, "dp", "cache", None)
    cache_kr = pcon(cache_kr, "dp", "cache", None)
    cache_c = cache_c.at[jnp.arange(B), pos].set(c_new.astype(cache_c.dtype))
    cache_kr = cache_kr.at[jnp.arange(B), pos].set(kr_new.astype(cache_kr.dtype))
    # absorb: q' = q_nope @ W_b^K  -> latent space
    wb_k = p["wkv_b"][..., :nope]                        # (R, H, nope)
    wb_v = p["wkv_b"][..., nope:]                        # (R, H, vd)
    q_lat = jnp.einsum("bhk,rhk->bhr", q_nope, wb_k)     # (B, H, R)
    s = (jnp.einsum("bhr,bsr->bhs", q_lat, cache_c)
         + jnp.einsum("bhk,bsk->bhs", q_rope, cache_kr)).astype(jnp.float32)
    s = s / math.sqrt(nope + rd)
    mask = jnp.arange(cache_c.shape[1])[None, :] <= pos[:, None]
    s = jnp.where(mask[:, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhs,bsr->bhr", w, cache_c)       # (B, H, R)
    o = jnp.einsum("bhr,rhv->bhv", o_lat, wb_v)          # (B, H, vd)
    out = jnp.einsum("bhv,hvd->bd", o, p["wo"])
    return out, cache_c, cache_kr
