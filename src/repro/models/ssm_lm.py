"""Pure-SSM LM (mamba2-370m): stacked Mamba2 blocks, no attention anywhere."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, PlanConfig
from repro.models import layers as L
from repro.models import ssm
from repro.models.partition import pcon
from repro.models.transformer import padded_vocab, lm_loss_from_hidden


def init_ssm_lm(cfg: ArchConfig, key, plan: PlanConfig = PlanConfig()):
    dtype = jnp.dtype(plan.param_dtype)
    Vp = padded_vocab(cfg)
    ke, kb = jax.random.split(key)
    keys = jax.random.split(kb, cfg.num_layers)
    return {
        "emb": L._dense_init(ke, (Vp, cfg.d_model), cfg.d_model, dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "blocks": jax.vmap(lambda k: ssm.init_mamba_block(k, cfg, dtype))(keys),
    }


def ssm_hidden(cfg: ArchConfig, plan: PlanConfig, params, embeds,
               collect_state=False):
    def body(x, lp):
        from repro.models.specs import gather_fsdp
        x = pcon(x, "dp", "sp", None)
        lp = gather_fsdp(lp)
        h, state = ssm.mamba_apply(lp, cfg, L.rms_norm(x, lp["ln"], cfg.norm_eps),
                                   unroll=plan.unroll_inner)
        return x + h, (state if collect_state else None)

    if plan.remat == "block":
        body = jax.remat(body)
    from repro.models.util import stack_scan
    x, states = stack_scan(body, embeds, params["blocks"], plan.unroll_layers)
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps), states


def ssm_lm_loss(cfg, plan, params, tokens, aux_coef=0.0):
    e = pcon(params["emb"][tokens], "dp", None, None)
    hidden, _ = ssm_hidden(cfg, plan, params, e)
    Bsz, S = tokens.shape
    targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.concatenate([jnp.ones((Bsz, S - 1), jnp.float32),
                            jnp.zeros((Bsz, 1), jnp.float32)], axis=1)
    return lm_loss_from_hidden(cfg, plan, params, hidden, targets, mask)


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype):
    """Per-layer (ssm_state, conv_state) stacked over layers."""
    s, c = ssm.init_mamba_state(cfg, batch, dtype)
    L_ = cfg.num_layers
    return {"ssm": jnp.zeros((L_,) + s.shape, s.dtype),
            "conv": jnp.zeros((L_,) + c.shape, c.dtype)}


def ssm_prefill(cfg, plan, params, tokens):
    e = pcon(params["emb"][tokens], "dp", None, None)
    hidden, states = ssm_hidden(cfg, plan, params, e, collect_state=True)
    logits = jnp.einsum("bd,vd->bv", hidden[:, -1],
                        params["emb"]).astype(jnp.float32)
    cache = {"ssm": states[0], "conv": states[1].astype(e.dtype)}
    pos = jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)
    return logits, cache, pos


def ssm_decode_step(cfg: ArchConfig, plan: PlanConfig, params, cache, tokens, pos):
    """pos is unused (state-space models carry no positional cache)."""
    x = params["emb"][tokens]

    def body(x, inp):
        from repro.models.specs import gather_fsdp
        lp, s, c = inp
        lp = gather_fsdp(lp)
        h, (s2, c2) = ssm.mamba_step(lp, cfg, L.rms_norm(x, lp["ln"], cfg.norm_eps),
                                     (s, c))
        return x + h, (s2, c2)

    from repro.models.util import stack_scan
    x, (s2, c2) = stack_scan(body, x, (params["blocks"], cache["ssm"],
                                       cache["conv"]), plan.unroll_layers)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x, params["emb"]).astype(jnp.float32)
    logits = pcon(logits, "dp", "tp")
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return nxt, {"ssm": s2, "conv": c2}
