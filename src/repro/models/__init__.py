"""Model zoo: five families (dense/moe transformer, ssm, hybrid, encdec, vlm)
behind the unified API in ``repro.models.api``."""
