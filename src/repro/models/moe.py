"""Mixture-of-Experts layer with capacity-factor dispatch and EP sharding.

Routing is *group-limited*: tokens route independently within a group (one
sequence during train/prefill; the whole batch during decode).  Each expert
takes its top-C tokens per group (C = ceil(T·k/E·cf)); overflow tokens are
dropped (standard capacity semantics; they keep the residual path).

Dispatch/combine are expressed WITHOUT scatter ops: the inverse (slot ->
token) mapping is recovered with one argsort over slots plus
``take_along_axis`` gathers.  This matters for SPMD: a (G,T,D) scatter-add
makes the partitioner replicate the full activation and all-reduce it in f32
(measured 8.6 GB/device/layer on deepseek-v2-lite prefill); the sort+gather
formulation stays dp-sharded, and the expert<->data resharding lowers to the
canonical MoE all-to-all pair.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.layers import _dense_init, mlp_apply, init_mlp
from repro.models.partition import pcon


def init_moe(key, cfg: ArchConfig, dtype):
    m: MoEConfig = cfg.moe
    D, E, F = cfg.d_model, m.num_experts, m.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (D, E), D, jnp.float32),
        "we1": _dense_init(ks[1], (E, D, F), D, dtype),
        "we3": _dense_init(ks[2], (E, D, F), D, dtype),
        "we2": _dense_init(ks[3], (E, F, D), F, dtype),
    }
    if m.num_shared_experts:
        p["shared"] = init_mlp(ks[4], D, m.d_ff_shared, dtype)
    return p


def _capacity(tokens_per_group: int, m: MoEConfig) -> int:
    c = math.ceil(tokens_per_group * m.top_k * m.capacity_factor / m.num_experts)
    return max(1, min(c, tokens_per_group))


def moe_apply(p, cfg: ArchConfig, x, *, group_size: int = 0,
              unroll: bool = False):
    """x: (B, S, D) or (B, D) for decode.  Returns (out, aux_loss).

    group_size > 0 chunks the sequence through the dispatch/combine so the
    (G,E,C,D) buffers are live one chunk at a time (lax.scan; python loop
    under dry-run cost probes)."""
    m: MoEConfig = cfg.moe
    decode = x.ndim == 2
    if not decode and group_size and x.shape[1] > group_size \
            and x.shape[1] % group_size == 0:
        B, S, D = x.shape
        nc = S // group_size
        xr = x.reshape(B, nc, group_size, D).transpose(1, 0, 2, 3)

        def body(aux, xc):
            yc, a = moe_apply(p, cfg, xc, group_size=0)
            return aux + a, yc

        if unroll:
            aux, ys = jnp.float32(0.0), []
            for i in range(nc):
                aux, yc = body(aux, xr[i])
                ys.append(yc)
            y = jnp.stack(ys)
        else:
            aux, y = jax.lax.scan(body, jnp.float32(0.0), xr)
        out = y.transpose(1, 0, 2, 3).reshape(B, S, D)
        return out, aux / nc

    xg = x[None] if decode else x                       # (G, T, D)
    G, T, D = xg.shape
    E, K = m.num_experts, m.top_k
    C = _capacity(T, m)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)             # (G, T, E)
    topw, topi = jax.lax.top_k(probs, K)                # (G, T, K)
    topw = topw / (jnp.sum(topw, axis=-1, keepdims=True) + 1e-9)

    # per-token-per-expert combine weight (0 if expert not in token's top-k)
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)            # (G,T,K,E)
    tok_w = jnp.einsum("gtk,gtke->gte", topw, onehot)              # (G,T,E)

    # each expert picks its top-C tokens in the group by combine weight
    ex_w, ex_idx = jax.lax.top_k(tok_w.transpose(0, 2, 1), C)      # (G,E,C)
    xe = jnp.take_along_axis(xg[:, None], ex_idx[..., None], axis=2)  # (G,E,C,D)
    xe = pcon(xe, None if decode else "dp", "ep", None, None)      # dispatch

    h = jnp.einsum("gecd,edf->gecf", xe, p["we1"])
    gt = jnp.einsum("gecd,edf->gecf", xe, p["we3"])
    h = jax.nn.silu(gt.astype(jnp.float32)).astype(xe.dtype) * h
    ye = jnp.einsum("gecf,efd->gecd", h, p["we2"])                 # (G,E,C,D)
    ye = pcon(ye, None if decode else "dp", None, None, None)      # combine a2a

    # ---- scatter-free combine: argsort inverse mapping -------------------
    # zero-weight slots point at an out-of-range token id so sorting pushes
    # them to the end (otherwise top_k tie-slots alias token 0)
    flat_tok = jnp.where(ex_w > 0, ex_idx, T).reshape(G, E * C)
    flat_w = ex_w.reshape(G, E * C).astype(jnp.float32)
    order = jnp.argsort(flat_tok, axis=1)                          # (G, EC)
    sorted_tok = jnp.take_along_axis(flat_tok, order, axis=1)
    base = jax.vmap(lambda st: jnp.searchsorted(st, jnp.arange(T)))(sorted_tok)
    pos = jnp.clip(base[..., None] + jnp.arange(K)[None, None], 0, E * C - 1)
    cand = jnp.take_along_axis(sorted_tok, pos.reshape(G, -1), 1).reshape(G, T, K)
    valid = (cand == jnp.arange(T)[None, :, None])                 # (G,T,K)
    slot = jnp.take_along_axis(order, pos.reshape(G, -1), 1).reshape(G, T, K)
    w = jnp.take_along_axis(flat_w, slot.reshape(G, -1), 1).reshape(G, T, K)
    w = w * valid
    yk = jnp.take_along_axis(ye.reshape(G, E * C, D),
                             slot.reshape(G, T * K)[..., None],
                             axis=1).reshape(G, T, K, D)
    out = jnp.sum(yk.astype(jnp.float32) * w[..., None], axis=2)
    out = pcon(out, None if decode else "dp", None, None).astype(x.dtype)

    # Switch-style load-balance aux loss
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=2), axis=1)        # (G, E)
    frac_probs = jnp.mean(probs, axis=1)                           # (G, E)
    aux = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))

    if m.num_shared_experts:
        out = out + mlp_apply(p["shared"], xg).astype(out.dtype)
    if decode:
        out = out[0]
    return out, aux
