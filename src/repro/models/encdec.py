"""Seamless-M4T-style encoder-decoder backbone (audio frontend is a stub:
the encoder consumes precomputed frame embeddings (B, S, D))."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, PlanConfig
from repro.models import layers as L
from repro.models.partition import pcon
from repro.models.transformer import padded_vocab, lm_loss_from_hidden

# fixed encoder-context length used by decode-shape cells (see DESIGN.md)
DECODE_ENC_LEN = 4096


def init_encdec(cfg: ArchConfig, key, plan: PlanConfig = PlanConfig()):
    dtype = jnp.dtype(plan.param_dtype)
    Vp = padded_vocab(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 5)

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": jnp.ones((D,), dtype), "ln2": jnp.ones((D,), dtype),
                "attn": L.init_attention(k1, cfg, dtype),
                "mlp": L.init_mlp(k2, D, cfg.d_ff, dtype)}

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": jnp.ones((D,), dtype), "lnx": jnp.ones((D,), dtype),
                "ln2": jnp.ones((D,), dtype),
                "attn": L.init_attention(k1, cfg, dtype),
                "xattn": L.init_attention(k2, cfg, dtype),
                "mlp": L.init_mlp(k3, D, cfg.d_ff, dtype)}

    return {
        "emb": L._dense_init(ks[0], (Vp, D), D, dtype),
        "enc_blocks": jax.vmap(enc_block)(jax.random.split(ks[1], cfg.encoder_layers)),
        "dec_blocks": jax.vmap(dec_block)(jax.random.split(ks[2], cfg.num_layers)),
        "enc_norm": jnp.ones((D,), dtype),
        "final_norm": jnp.ones((D,), dtype),
    }


def encode(cfg, plan: PlanConfig, params, frames):
    """frames: (B, S_enc, D) stub embeddings -> encoder hidden."""
    positions = jnp.arange(frames.shape[1])

    def body(x, lp):
        from repro.models.specs import gather_fsdp
        x = pcon(x, "dp", "sp", None)
        lp = gather_fsdp(lp)
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        h, _ = L.attention_apply(lp["attn"], cfg, h, positions, causal=False,
                                 chunk=plan.attn_chunk,
                                 unroll=plan.unroll_inner)
        x = x + h
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + L.mlp_apply(lp["mlp"], h), None

    if plan.remat == "block":
        body = jax.remat(body)
    from repro.models.util import stack_scan
    x, _ = stack_scan(body, frames, params["enc_blocks"], plan.unroll_layers)
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def decode_hidden(cfg, plan: PlanConfig, params, tokens, enc_out,
                  collect_cache=False):
    x = pcon(params["emb"][tokens], "dp", None, None)
    positions = jnp.arange(tokens.shape[1])

    def body(x, lp):
        from repro.models.specs import gather_fsdp
        x = pcon(x, "dp", "sp", None)
        lp = gather_fsdp(lp)
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        h, self_kv = L.attention_apply(lp["attn"], cfg, h, positions,
                                       causal=True, chunk=plan.attn_chunk,
                                       unroll=plan.unroll_inner)
        x = x + h
        h = L.rms_norm(x, lp["lnx"], cfg.norm_eps)
        h, cross_kv = L.attention_apply(lp["xattn"], cfg, h, None, causal=False,
                                        chunk=plan.attn_chunk, xkv=enc_out,
                                        unroll=plan.unroll_inner)
        x = x + h
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.mlp_apply(lp["mlp"], h)
        return x, ((self_kv, cross_kv) if collect_cache else None)

    if plan.remat == "block":
        body = jax.remat(body)
    from repro.models.util import stack_scan
    x, caches = stack_scan(body, x, params["dec_blocks"], plan.unroll_layers)
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps), caches


def encdec_loss(cfg, plan, params, frames, tokens, aux_coef=0.0):
    enc_out = encode(cfg, plan, params, frames)
    hidden, _ = decode_hidden(cfg, plan, params, tokens, enc_out)
    Bsz, S = tokens.shape
    targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.concatenate([jnp.ones((Bsz, S - 1), jnp.float32),
                            jnp.zeros((Bsz, 1), jnp.float32)], axis=1)
    return lm_loss_from_hidden(cfg, plan, params, hidden, targets, mask)


def init_encdec_cache(cfg: ArchConfig, batch: int, max_len: int, enc_len: int,
                      dtype):
    Ld, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((Ld, batch, max_len, KV, hd), dtype),
        "v": jnp.zeros((Ld, batch, max_len, KV, hd), dtype),
        "xk": jnp.zeros((Ld, batch, enc_len, KV, hd), dtype),
        "xv": jnp.zeros((Ld, batch, enc_len, KV, hd), dtype),
    }


def encdec_prefill(cfg, plan, params, frames, bos_tokens, max_len):
    """Encode frames, run the decoder prompt, build self+cross caches."""
    enc_out = encode(cfg, plan, params, frames)
    hidden, caches = decode_hidden(cfg, plan, params, bos_tokens, enc_out,
                                   collect_cache=True)
    dt = enc_out.dtype
    Bsz, Sp = bos_tokens.shape
    cache = init_encdec_cache(cfg, Bsz, max_len, frames.shape[1], dt)
    (sk, sv), (xk, xv) = caches[0], caches[1]
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], sk.astype(dt), 0, axis=2)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], sv.astype(dt), 0, axis=2)
    cache["xk"], cache["xv"] = xk.astype(dt), xv.astype(dt)
    logits = jnp.einsum("bd,vd->bv", hidden[:, -1], params["emb"]).astype(jnp.float32)
    return logits, cache, jnp.full((Bsz,), Sp, jnp.int32)


def encdec_decode_step(cfg: ArchConfig, plan: PlanConfig, params, cache, tokens,
                       pos):
    import math
    x = params["emb"][tokens]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    def body(x, inp):
        from repro.models.specs import gather_fsdp
        lp, ck, cv, xk, xv = inp
        lp = gather_fsdp(lp)
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        h, ck2, cv2 = L.attention_decode(lp["attn"], cfg, h, ck, cv, pos)
        x = x + h
        # cross attention over the fixed encoder cache
        h = L.rms_norm(x, lp["lnx"], cfg.norm_eps)
        q = jnp.einsum("bd,dhk->bhk", h, lp["xattn"]["wq"])
        if cfg.qkv_bias:
            q = q + lp["xattn"]["bq"]
        ke = L._expand_kv(xk, H // KV)
        ve = L._expand_kv(xv, H // KV)
        s = jnp.einsum("bhk,bshk->bhs", q, ke).astype(jnp.float32) / math.sqrt(hd)
        w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhs,bshk->bhk", w, ve)
        x = x + jnp.einsum("bhk,hkd->bd", o, lp["xattn"]["wo"])
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.mlp_apply(lp["mlp"], h)
        return x, (ck2, cv2)

    from repro.models.util import stack_scan
    x, (ck2, cv2) = stack_scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"], cache["xk"],
                  cache["xv"]), plan.unroll_layers)
    new_cache = dict(cache, k=ck2, v=cv2)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x, params["emb"]).astype(jnp.float32)
    logits = pcon(logits, "dp", "tp")
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache
