"""Logical-axis partitioning environment.

Model code annotates tensors with *logical* dimension names; this module
resolves them to mesh axes according to the active ``PlanConfig`` (the
polystore tensor-plan, i.e. which "engine"/sharding regime executes the step).

Logical names:
  "dp"    data-parallel axes (("pod","data") on the multi-pod mesh)
  "fsdp"  parameter sharding over the DP axes (ZeRO-3 style) — plan.fsdp
  "tp"    tensor-parallel axis ("model")                      — plan.tp
  "sp"    sequence sharding of remat boundaries over "model"  — plan.sp_boundary
  "ep"    expert sharding over "model"                        — plan.moe_ep
  "cache" decode KV-cache seq sharding over "model"           — plan.cache_seq_shard
  None    replicated

Resolution silently drops an axis whose size does not divide the dimension
(e.g. kv_heads=2 over a 16-way model axis), exactly like replicating KV heads
on real deployments.  Outside a ``plan_scope`` every constraint is a no-op, so
the same model code runs on a bare CPU device.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import PlanConfig

_ENV = threading.local()


class PlanEnv:
    def __init__(self, mesh: Mesh, plan: PlanConfig):
        self.mesh = mesh
        self.plan = plan
        names = mesh.axis_names
        self.dp_axes = tuple(a for a in ("pod", "data") if a in names) or (names[0],)
        self.tp_axis = "model" if "model" in names else None
        self.axis_size = {a: mesh.shape[a] for a in names}

    def resolve(self, name) -> Union[None, str, tuple]:
        plan = self.plan
        if name is None:
            return None
        if name == "dp":
            axes = self.dp_axes
            if not plan.tp and self.tp_axis:
                axes = axes + (self.tp_axis,)   # tp off: DP absorbs model axis
            return axes if len(axes) > 1 else axes[0]
        if name == "fsdp":
            return self.resolve("dp") if plan.fsdp else None
        if name == "tp":
            return self.tp_axis if plan.tp else None
        if name == "sp":
            return self.tp_axis if (plan.tp and plan.sp_boundary) else None
        if name == "ep":
            return self.tp_axis if (plan.tp and plan.moe_ep) else None
        if name == "cache":
            return self.tp_axis if (plan.tp and plan.cache_seq_shard) else None
        raise ValueError(f"unknown logical axis {name!r}")

    def axes_size(self, resolved) -> int:
        if resolved is None:
            return 1
        if isinstance(resolved, tuple):
            n = 1
            for a in resolved:
                n *= self.axis_size[a]
            return n
        return self.axis_size[resolved]


def current_env() -> Optional[PlanEnv]:
    return getattr(_ENV, "env", None)


@contextmanager
def plan_scope(mesh: Optional[Mesh], plan: PlanConfig):
    prev = getattr(_ENV, "env", None)
    _ENV.env = PlanEnv(mesh, plan) if mesh is not None else None
    try:
        if mesh is not None:
            with mesh:
                yield _ENV.env
        else:
            yield None
    finally:
        _ENV.env = prev


def spec(shape: Sequence[int], names: Sequence) -> P:
    """Resolve logical names against the active env, honoring divisibility."""
    env = current_env()
    if env is None:
        return P()
    entries = []
    for dim, name in zip(shape, names):
        r = env.resolve(name)
        if r is not None and dim % env.axes_size(r) != 0:
            r = None  # cannot shard this dim — replicate (e.g. kv_heads < tp)
        entries.append(r)
    return P(*entries)


def pcon(x, *names):
    """with_sharding_constraint using logical names; identity w/o a plan env."""
    env = current_env()
    if env is None or env.mesh is None:
        return x
    s = spec(x.shape, names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(env.mesh, s))


def named_sharding(shape: Sequence[int], names: Sequence) -> Optional[NamedSharding]:
    env = current_env()
    if env is None:
        return None
    return NamedSharding(env.mesh, spec(shape, names))
