"""Fault-tolerant training driver.

Responsibilities: the step loop, periodic async checkpoints, restart-on-
failure (restore latest checkpoint, rebuild the deterministic data stream at
that step), straggler detection, and metric history.  ``run_with_restarts``
is the cluster-controller behavior: it survives injected failures and
produces a loss trajectory identical to an uninterrupted run (tested).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.runtime.fault import FailureInjector, SimulatedFailure, \
    StragglerDetector


@dataclass
class Trainer:
    train_step: Callable                     # (state, batch) -> (state, metrics)
    batch_fn: Callable[[int], dict]          # step -> batch (deterministic)
    ckpt: CheckpointManager
    ckpt_every: int = 20
    injector: Optional[FailureInjector] = None
    straggler: StragglerDetector = field(default_factory=StragglerDetector)
    history: List[Dict] = field(default_factory=list)

    def _run(self, state, start_step: int, num_steps: int):
        step_fn = self.train_step
        for step in range(start_step, num_steps):
            if self.injector is not None:
                self.injector.check(step)
            t0 = time.perf_counter()
            batch = self.batch_fn(step)
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.straggler.observe(step, dt)
            self.history.append(
                {"step": step, "seconds": dt,
                 **{k: float(v) for k, v in metrics.items()}})
            if (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1, state)
        self.ckpt.save(num_steps, state, blocking=True)
        return state

    def run(self, state, num_steps: int, start_step: int = 0):
        return self._run(state, start_step, num_steps)

    def run_with_restarts(self, init_state, num_steps: int,
                          max_restarts: int = 10, shardings=None):
        """Cluster-controller loop: on failure, restore the latest checkpoint
        (elastically resharded if the mesh changed) and continue."""
        state = init_state
        start = 0
        restarts = 0
        while True:
            try:
                return self._run(state, start, num_steps), restarts
            except SimulatedFailure:
                restarts += 1
                if restarts > max_restarts:
                    raise
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:       # failed before first checkpoint
                    state, start = init_state, 0
                else:
                    state, start = self.ckpt.restore(
                        jax.eval_shape(lambda: state), step=latest,
                        shardings=shardings)
                # drop history after the restore point (it will be replayed)
                self.history = [h for h in self.history if h["step"] < start]

    def losses(self) -> np.ndarray:
        return np.asarray([h["loss"] for h in self.history], np.float32)
