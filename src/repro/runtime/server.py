"""Serving loops.

``BatchServer`` — batched LM decode with continuous slot management: a
fixed-capacity decode batch over a shared KV cache: incoming requests are
prefilled one at a time into free slots (each prefill writes its cache rows),
decode steps advance ALL active slots together, and finished slots (EOS or
max-tokens) are released.  This is the standard continuous-batching serving
shape (vLLM-style) restricted to slot granularity — the polystore planner
picks the decode plan (tensorplan), and the monitor records per-step times.

``QueryServer`` — polystore query serving through the middleware's
signature-keyed plan cache: the first request for a signature pays the
training phase (plan enumeration + measured trials), every later request
executes the cached plan with concurrent DAG dispatch (topological levels
fanned out over the executor's host thread pool) and no re-enumeration.
Because the middleware persists its plan cache, monitor DB and calibration
beside each other (``persist()`` flushes all three), a restarted server
pointed at the same paths starts *warm*: previously-trained signatures are
served in production mode with zero plan enumerations.  The middleware's
adaptive loop still watches every run — ``stats["replans"]`` counts the
times measured/predicted divergence forced a fresh (cheap) DP pass, and
``stats["explorations"]`` counts the budgeted background trials of a k-best
DP runner-up plan (enable with ``BigDAWG(explore_budget=...)``) whose
measurements keep the monitor's plan ranking honest.

``QueryServer`` admits **concurrent traffic**: ``submit`` is safe to call
from many threads (the middleware serializes same-signature requests on a
per-signature lock, so a cold signature trains exactly once under any
admission pattern; stats updates are lock-guarded), and
``submit_many``/``serve`` drive a shared ``core.reqpool.RequestPool`` so
callers get multi-threaded admission without managing threads themselves.
The request pool is NOT the executor's host pool: request threads block on
level barriers, and parking them on the pool that runs the levels could
starve it.  Exploration runs off the request path (background host-pool
tasks), so ``stats["seconds"]`` — summed per-request wall time across
request threads — contains zero exploration time.

**Adaptive shedding** (``latency_target_s=``): instead of a fixed
``max_pending``, the in-flight bound tracks measured serve latency with the
classic AIMD rule — every completion under the target grows the bound by
one, a completion over it halves the bound — so admission follows what the
engines can actually sustain (queue-based load leveling).  Between the
adaptive bound and twice the bound, requests are admitted *degraded*
(planned on the always-up engine set via the middleware's health registry)
before anything is shed: the graceful-degradation rung of the ladder.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from collections.abc import Mapping
from typing import Any, Callable, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.errors import Overloaded
from repro.core.reqpool import RequestPool

# The pre-taxonomy name for a shed request's result slot.  ``Overloaded``
# (a BigDAWGError) plays the same role with the same ``query``/``reason``
# attributes, so the old name is a deprecated alias — ``isinstance(r, Shed)``
# and ``Shed(q)`` both keep working.
Shed = Overloaded


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                   # (len,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class BatchServer:
    def __init__(self, *, slots: int, max_len: int, prefill_fn, decode_fn,
                 params, init_cache_fn, eos_id: Optional[int] = None):
        """prefill_fn(params, tokens(1,L)) -> (logits(1,V), cache_rows, pos)
        decode_fn(params, cache, tokens(B,), pos(B,)) -> (next(B,), cache)."""
        self.slots = slots
        self.max_len = max_len
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.params = params
        self.cache = init_cache_fn(slots, max_len)
        self.eos_id = eos_id
        self.active: Dict[int, Request] = {}     # slot -> request
        self.pos = np.zeros((slots,), np.int32)
        self.tokens = np.zeros((slots,), np.int32)
        self.stats = {"prefills": 0, "decode_steps": 0, "tokens_out": 0,
                      "decode_seconds": 0.0}
        # guards slot state + the shared cache scatter; prefill COMPUTE runs
        # pool-parallel in serve(), attachment is serialized here
        self._slot_lock = threading.Lock()
        self._requests = RequestPool(thread_name_prefix="bigdawg-prefill")

    # -- slot management -----------------------------------------------------
    def _free_slots(self):
        return [s for s in range(self.slots) if s not in self.active]

    def _write_rows(self, cache_rows, slot: int, plen: int):
        """Scatter one request's prefilled cache rows into the batch cache.

        Generic across cache families: the batch axis of each leaf is located
        by matching (slots vs 1) dims; a following seq axis, if shorter in the
        source, is zero-padded to capacity."""
        def place(dst, src):
            b_axis = None
            for ax in range(dst.ndim):
                if dst.shape[ax] == self.slots and src.shape[ax] == 1:
                    b_axis = ax
                    break
            if b_axis is None:           # state-style leaf without seq dim
                return dst
            start = [0] * dst.ndim
            start[b_axis] = slot
            src_pad = src
            # seq axis, if present, is b_axis+1 with src length plen
            if (b_axis + 1 < dst.ndim
                    and src.shape[b_axis + 1] != dst.shape[b_axis + 1]):
                pad = dst.shape[b_axis + 1] - src.shape[b_axis + 1]
                widths = [(0, 0)] * dst.ndim
                widths[b_axis + 1] = (0, pad)
                src_pad = jnp.pad(src, widths)
            return jax.lax.dynamic_update_slice(dst, src_pad.astype(dst.dtype),
                                                start)
        self.cache = jax.tree.map(place, self.cache, cache_rows)

    def _prefill_compute(self, req: Request):
        """The pure-compute half of a prefill (no shared state): safe to run
        on a request-pool worker while other prefills compute beside it."""
        tok = jnp.asarray(req.prompt[None, :], jnp.int32)
        return self.prefill_fn(self.params, tok)

    def _attach(self, slot: int, req: Request, logits, cache_rows) -> None:
        """The stateful half: scatter the prefilled cache rows into the
        batch cache and activate the slot (serialized on the slot lock)."""
        with self._slot_lock:
            self._write_rows(cache_rows, slot, len(req.prompt))
            first = int(jnp.argmax(logits[0]))
            req.out_tokens.append(first)
            self.tokens[slot] = first
            self.pos[slot] = len(req.prompt)
            self.active[slot] = req
            self.stats["prefills"] += 1

    def submit(self, req: Request) -> bool:
        free = self._free_slots()
        if not free:
            return False
        logits, cache_rows, pos = self._prefill_compute(req)
        self._attach(free[0], req, logits, cache_rows)
        return True

    # -- decode ----------------------------------------------------------------
    def step(self):
        if not self.active:
            return
        t0 = time.perf_counter()
        nxt, self.cache = self.decode_fn(
            self.params, self.cache, jnp.asarray(self.tokens),
            jnp.asarray(self.pos))
        nxt = np.asarray(jax.block_until_ready(nxt))
        self.stats["decode_seconds"] += time.perf_counter() - t0
        self.stats["decode_steps"] += 1
        for slot, req in list(self.active.items()):
            tok = int(nxt[slot])
            req.out_tokens.append(tok)
            self.pos[slot] += 1
            self.tokens[slot] = tok
            self.stats["tokens_out"] += 1
            if ((self.eos_id is not None and tok == self.eos_id)
                    or len(req.out_tokens) >= req.max_new_tokens
                    or self.pos[slot] >= self.max_len - 1):
                req.done = True
                del self.active[slot]

    def run(self, requests: List[Request], max_steps: int = 10000):
        pending = list(requests)
        steps = 0
        while (pending or self.active) and steps < max_steps:
            while pending and self._free_slots():
                self.submit(pending.pop(0))
            self.step()
            steps += 1
        return requests

    def serve(self, requests: List[Request], workers: Optional[int] = None,
              max_steps: int = 10000):
        """``run`` with pool-parallel prefill: each admission wave computes
        its prefills concurrently on the shared request pool (the pure JAX
        calls overlap via async dispatch + GIL release), then attaches them
        to free slots on the caller thread — decode still advances all
        active slots together.  ``workers<=1`` degrades to ``run``'s
        sequential admission."""
        pending = list(requests)
        steps = 0
        while (pending or self.active) and steps < max_steps:
            free = self._free_slots()
            wave = pending[:len(free)]
            if wave:
                del pending[:len(wave)]
                outs = self._requests.map_ordered(self._prefill_compute,
                                                  wave, workers)
                for slot, req, (logits, cache_rows, _pos) in zip(
                        free, wave, outs):
                    self._attach(slot, req, logits, cache_rows)
            self.step()
            steps += 1
        return requests


class StatsView(Mapping):
    """Backward-compatible dict view over the server's ``Metrics`` registry.

    ``QueryServer.stats`` used to be a plain dict guarded by its own lock —
    one of three separately-locked counter stores in the serving stack.
    The counters now live in :class:`repro.runtime.telemetry.Metrics`; this
    view keeps every old read working: ``srv.stats["requests"]``,
    ``dict(srv.stats)``, iteration, ``len``.  Middleware-lifetime keys
    (``breaker_trips``, ``fused_serves``, ...) are read live off the
    backend, exactly as ``submit`` used to mirror them.  Calling the view
    (``srv.stats()``) returns a plain dict snapshot."""

    _KEYS = ("requests", "cache_hits", "trainings", "replans",
             "explorations", "shed", "seconds", "degraded", "failovers",
             "breaker_trips", "latency_ewma", "fused_serves",
             "fusion_fallbacks", "ivm_serves", "ivm_fallbacks")
    _FLOAT = frozenset(("seconds", "latency_ewma"))
    # lifetime middleware counters read live off the backend (a ProcPool
    # backend lacks the fused/ivm attributes -> 0, like the old mirror)
    _LIVE = frozenset(("breaker_trips", "fused_serves", "fusion_fallbacks",
                       "ivm_serves", "ivm_fallbacks"))

    def __init__(self, server: "QueryServer"):
        self._server = server

    def __getitem__(self, key: str):
        if key not in self._KEYS:
            raise KeyError(key)
        if key in self._LIVE:
            return int(getattr(self._server.bd, key, 0))
        v = self._server.metrics.value("server." + key)
        return float(v) if key in self._FLOAT else int(v)

    def __iter__(self):
        return iter(self._KEYS)

    def __len__(self) -> int:
        return len(self._KEYS)

    def __call__(self) -> Dict[str, Any]:
        return {k: self[k] for k in self._KEYS}

    def __repr__(self) -> str:
        return repr(self())


class QueryServer:
    """Production-facing polystore front end over a ``BigDAWG`` instance.

    Serving path: signature -> plan cache -> concurrent plan execution.  Only
    a cache/monitor miss (a never-seen signature) falls back to the training
    phase, so steady-state traffic never re-enumerates plans.

    Thread-safe: ``submit`` may be called from many threads at once (see the
    module docstring); ``submit_many``/``serve`` spin the requests over the
    server's own request pool.

    **Bounded admission.**  With ``max_pending=N``, batch admission
    (``submit_many``/``serve``) keeps at most N requests in flight at once:
    a request arriving while N are outstanding is *shed* — its result slot
    holds an ``Overloaded`` marker, ``stats["shed"]`` counts it, and the
    request is never executed (load-shedding backpressure instead of an
    unbounded queue; ROADMAP PR 4 follow-on).  ``max_pending=None``
    (default) admits everything, the pre-PR-5 behavior.  Direct ``submit``
    calls bypass the bound: the caller already owns a thread and blocking
    it is the natural backpressure there.

    **Adaptive shedding.**  ``latency_target_s=T`` replaces the fixed bound
    with an AIMD one keyed to measured serve latency: the bound grows by 1
    after each completion whose latency EWMA sits under T and halves when
    the EWMA overshoots, floored at 1 and capped at ``max_pending`` (when
    given).  Requests landing between the bound and twice the bound are
    admitted *degraded* — executed with the middleware's degrade mask
    (always-up engines only; requires ``BigDAWG(health=...)``) — so the
    server sheds only after degrading, and ``stats["degraded"]`` counts the
    slow-but-alive serves.
    """

    # default size of the request admission pool (submit_many/serve)
    DEFAULT_REQUEST_WORKERS = RequestPool.DEFAULT_WORKERS

    def __init__(self, bigdawg, max_pending: Optional[int] = None,
                 latency_target_s: Optional[float] = None,
                 processes: Optional[int] = None,
                 fuse: Optional[bool] = None,
                 incremental: Optional[Any] = None):
        # ``processes=N`` lifts the middleware into a core.procpool.ProcPool
        # — N worker processes each owning a full middleware stack, sharing
        # plans through the monitor/plan-cache files — so batch admission
        # fans across interpreters instead of threads under one GIL.  The
        # pool duck-types the middleware surface (execute/persist/health/
        # breaker_trips), so the admission logic below is unchanged.
        if processes is not None and processes > 1:
            from repro.core.procpool import ProcPool
            if not isinstance(bigdawg, ProcPool):
                bigdawg = ProcPool.from_bigdawg(bigdawg, processes)
        self.bd = bigdawg
        # fuse=True/False overrides the middleware's plan-level kernel
        # fusion knob for this server; None leaves the middleware's own
        # setting (BigDAWG(fuse=...)) untouched.  A ProcPool backend has no
        # fuse attribute — its workers own their middlewares — so the
        # override only applies to in-process backends that carry the knob
        if fuse is not None and hasattr(self.bd, "fuse"):
            self.bd.fuse = fuse
        # incremental=True/False/"force" overrides the middleware's
        # streaming-IVM knob the same way (None leaves BigDAWG(incremental=)
        # untouched; ProcPool backends without the attribute are skipped)
        if incremental is not None and hasattr(self.bd, "incremental"):
            self.bd.incremental = incremental
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        if latency_target_s is not None and latency_target_s <= 0:
            raise ValueError(f"latency_target_s must be > 0, got "
                             f"{latency_target_s}")
        self.max_pending = max_pending
        self.latency_target_s = latency_target_s
        # counters live in the middleware's Metrics registry when it has one
        # (so server.* and bd.* metrics land in one snapshot/file); a
        # pre-taxonomy stand-in without a registry gets a pathless private
        # one.  ``self.stats`` stays a dict-shaped view over it.
        from repro.runtime.telemetry import Metrics
        reg = getattr(self.bd, "metrics", None)
        self.metrics = reg if reg is not None else Metrics()
        self.stats = StatsView(self)
        self._pending = 0          # batch-admitted requests still in flight
        # adaptive in-flight bound (AIMD; only consulted when
        # latency_target_s is set) and the serve-latency EWMA driving it
        self._bound = float(max_pending or 2 * self.DEFAULT_REQUEST_WORKERS)
        self._lat_ewma = 0.0
        self._admit_lock = threading.Lock()
        # lazily-built request pool (NOT the executor host pool — request
        # threads block on level barriers); grows, never shrinks
        self._requests = RequestPool()

    def warm(self, queries) -> int:
        """Admission/warmup: train every query shape once so production
        traffic starts on cached plans."""
        n = 0
        for q in queries:
            self.bd.execute(q, mode="training")
            n += 1
        return n

    def persist(self) -> None:
        """Flush monitor DB, cost-model calibration and plan cache to their
        side-by-side files so the next server process restarts warm (no-ops
        for components constructed without a path).  Waits for in-flight
        background explorations first, so their measurements are included."""
        self.bd.persist()

    def close(self) -> None:
        """Shut down a process-pool backend (no-op for the in-process
        middleware): stops every worker after their pipes drain."""
        closer = getattr(self.bd, "close", None)
        if closer is not None:
            closer()

    def submit(self, query, degrade: bool = False):
        """Admit one request (safe from any thread).  The measured seconds
        cover the serve path only — background exploration the serve may
        have scheduled runs off-path and is never in this timing.
        ``degrade=True`` (the adaptive-shedding middle rung) executes under
        the middleware's degrade mask — always-up engines only."""
        t0 = time.perf_counter()
        if degrade:
            rep = self.bd.execute(query, mode="auto", degrade=True)
        else:     # plain call keeps pre-taxonomy BigDAWG stand-ins working
            rep = self.bd.execute(query, mode="auto")
        dt = time.perf_counter() - t0
        m = self.metrics
        m.counter("server.requests")
        m.counter("server.seconds", dt)
        m.observe("server.latency", dt)
        if rep.mode == "training":
            m.counter("server.trainings")
        if rep.cache_hit:
            m.counter("server.cache_hits")
        if rep.replanned:
            m.counter("server.replans")
        if rep.explored:
            m.counter("server.explorations")
        if getattr(rep, "degraded", False):
            m.counter("server.degraded")
        failovers = getattr(rep, "failovers", 0)
        if failovers:
            m.counter("server.failovers", float(failovers))
        if self.latency_target_s is not None and rep.mode != "training":
            # AIMD on the in-flight bound, driven by the latency EWMA:
            # under target -> +1 (up to max_pending when given), over ->
            # halve (floor 1).  Training requests are excluded — a cold
            # signature's plan-enumeration time says nothing about
            # steady-state serve latency
            with self._admit_lock:
                a = 0.2
                self._lat_ewma = dt if self._lat_ewma == 0.0 \
                    else (1 - a) * self._lat_ewma + a * dt
                if self._lat_ewma <= self.latency_target_s:
                    cap = float(self.max_pending) if self.max_pending \
                        else float("inf")
                    self._bound = min(cap, self._bound + 1.0)
                else:
                    self._bound = max(1.0, self._bound / 2.0)
                m.gauge("server.latency_ewma", self._lat_ewma)
        return rep

    def _try_admit(self) -> Optional[str]:
        """Reserve an in-flight slot for one batch request: ``"admit"``
        (serve normally), ``"degrade"`` (adaptive middle rung: serve on the
        always-up engines), or ``None`` (shed).  The check-and-increment is
        atomic under the admission lock, so concurrent ``submit_many`` batches
        can never jointly exceed the bound."""
        with self._admit_lock:
            if self.latency_target_s is not None:
                bound = max(1, int(self._bound))
                if self._pending < bound:
                    self._pending += 1
                    return "admit"
                # degrade before shedding — but only when the middleware
                # can actually plan a degraded serve (health registry)
                if self._pending < 2 * bound \
                        and getattr(self.bd, "health", None) is not None:
                    self._pending += 1
                    return "degrade"
                self.metrics.counter("server.shed")
                return None
            if self.max_pending is not None \
                    and self._pending >= self.max_pending:
                self.metrics.counter("server.shed")
                return None
            self._pending += 1
            return "admit"

    def _admitted_submit(self, q, degrade: bool = False):
        try:
            return self.submit(q, degrade=degrade)
        finally:
            with self._admit_lock:
                self._pending -= 1

    def submit_many(self, queries: Iterable, workers: Optional[int] = None
                    ) -> List:
        """Admit a batch of requests concurrently from the request pool and
        return their Reports in input order.  ``workers<=1`` degrades to a
        sequential loop (no pool round-trips).  Mixed cold/warm traffic is
        fine: the middleware's per-signature locking guarantees one training
        per cold signature no matter how the requests interleave.

        With ``max_pending=N`` on the server, a request arriving while N
        batch requests are in flight is rejected *without blocking*: its
        slot in the returned list is an ``Overloaded`` marker and
        ``stats["shed"]`` is bumped.  With ``latency_target_s`` the bound is
        the AIMD one, and overflow below twice the bound is served degraded
        instead of shed (see the class docstring)."""
        queries = list(queries)
        workers = workers or self.DEFAULT_REQUEST_WORKERS
        shed_reason = "latency_target" if self.latency_target_s is not None \
            else "max_pending"
        if workers <= 1 or len(queries) <= 1:
            # sequential admission still reserves an in-flight slot per
            # request: the bound is shared across batches, and a concurrent
            # submit_many on another thread must see this one's occupancy
            # (alone, a sequential batch never exceeds one slot)
            out = []
            for q in queries:
                adm = self._try_admit()
                out.append(Overloaded(q, shed_reason) if adm is None else
                           self._admitted_submit(q, degrade=adm == "degrade"))
            return out
        pool = self._requests.pool(workers)
        # the pool only grows (in-flight submits may hold the old one), so a
        # smaller `workers` must be enforced here or a 4-wide pool would run
        # a workers=2 batch 4 wide — and misreport every thread-count sweep.
        # The gate is taken at SUBMISSION time (this thread blocks, not a
        # pool worker): parking excess tasks inside workers would occupy
        # pool threads and FIFO-starve a concurrent caller's batch
        gate = threading.Semaphore(workers)
        futures: List = []
        for q in queries:
            # shed BEFORE the worker-width gate: a full server must reject
            # immediately, not park the caller until a slot frees
            adm = self._try_admit()
            if adm is None:
                futures.append(Overloaded(q, shed_reason))
                continue
            gate.acquire()
            fut = pool.submit(self._admitted_submit, q,
                              degrade=adm == "degrade")
            fut.add_done_callback(lambda _f: gate.release())
            futures.append(fut)
        return [f if isinstance(f, Overloaded) else f.result()
                for f in futures]

    def serve(self, queries: Iterable, workers: Optional[int] = None) -> Dict:
        """Drive a traffic batch through ``submit_many`` and summarize it:
        ``{"reports", "seconds" (wall), "rps", "shed", "workers"}`` — the
        requests/sec figure ``benchmarks/fig_concurrent_serving.py`` tracks
        (``rps`` counts served requests only; ``shed`` says how many of this
        batch admission control rejected)."""
        t0 = time.perf_counter()
        reports = self.submit_many(queries, workers=workers)
        wall = time.perf_counter() - t0
        shed = sum(1 for r in reports if isinstance(r, Overloaded))
        return {"reports": reports, "seconds": wall,
                "rps": (len(reports) - shed) / max(wall, 1e-9),
                "shed": shed,
                "workers": workers or self.DEFAULT_REQUEST_WORKERS}
