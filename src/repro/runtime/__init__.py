from repro.runtime.trainer import Trainer, SimulatedFailure
from repro.runtime.server import BatchServer, QueryServer, Shed
from repro.runtime.fault import FailureInjector, StragglerDetector

__all__ = ["Trainer", "SimulatedFailure", "BatchServer", "QueryServer",
           "Shed", "FailureInjector", "StragglerDetector"]
