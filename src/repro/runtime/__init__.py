from repro.runtime.trainer import Trainer, SimulatedFailure
from repro.runtime.server import BatchServer, Overloaded, QueryServer, Shed
from repro.runtime.fault import (EngineFaultInjector, FailureInjector,
                                 StragglerDetector, WorkerKillInjector)

__all__ = ["Trainer", "SimulatedFailure", "BatchServer", "QueryServer",
           "Shed", "Overloaded", "EngineFaultInjector", "FailureInjector",
           "StragglerDetector", "WorkerKillInjector"]
