from repro.runtime.trainer import Trainer, SimulatedFailure
from repro.runtime.server import BatchServer, Overloaded, QueryServer, Shed
from repro.runtime.fault import (EngineFaultInjector, FailureInjector,
                                 StragglerDetector, WorkerKillInjector)
from repro.runtime.telemetry import (Histogram, Metrics,
                                     default_metrics_path, load_merged)

__all__ = ["Trainer", "SimulatedFailure", "BatchServer", "QueryServer",
           "Shed", "Overloaded", "EngineFaultInjector", "FailureInjector",
           "StragglerDetector", "WorkerKillInjector",
           "Histogram", "Metrics", "default_metrics_path", "load_merged"]
