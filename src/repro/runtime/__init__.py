from repro.runtime.trainer import Trainer, SimulatedFailure
from repro.runtime.server import BatchServer, QueryServer
from repro.runtime.fault import FailureInjector, StragglerDetector

__all__ = ["Trainer", "SimulatedFailure", "BatchServer", "QueryServer",
           "FailureInjector", "StragglerDetector"]
