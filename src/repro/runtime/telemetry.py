"""Process-wide metrics registry: counters, gauges, latency histograms.

Absorbs the serving stack's scattered ``stats[...]`` dicts (server,
middleware, health, procpool) behind one API with a lock-free read path:
writers mutate plain floats/ints under one registry lock, readers
(:meth:`Metrics.snapshot`, the ``QueryServer.stats`` view) copy them
without taking it — under CPython each individual read is consistent, and
stats consumers only ever want a monotone point-in-time view.

Persistence mirrors the monitor's merge-on-save protocol, adapted for
counters: the JSON blob (``monitor.metrics.json`` beside the plan cache,
atomic via :mod:`repro.core.ioutil`) holds one section per *writer*
(a process-unique id), and each save rewrites only the caller's section
while carrying every other writer's through.  Totals are therefore exact
under multi-process contention — a worker's section is its own full
counts, last-writer-wins per section — which is what the procpool's
convergence tests hammer.

Histograms use fixed log-spaced buckets (factor ``10**(1/8)`` ≈ 1.33 from
3.2 µs to 100 s), so quantile estimates are within one bucket ratio of the
exact percentile; ``snapshot()`` surfaces p50/p95/p99 per histogram.
"""
from __future__ import annotations

import contextlib
import itertools
import os
import threading
from typing import Any, Dict, List, Optional

try:
    import fcntl
except ImportError:          # non-POSIX: degrade to best-effort merge
    fcntl = None

from repro.core.ioutil import atomic_json_dump, load_json

__all__ = ["Histogram", "Metrics", "default_metrics_path", "load_merged"]

_FORMAT = 1

# log-spaced bucket upper bounds: 10**(-5.5) .. 10**2 seconds, factor 10**(1/8)
HIST_BOUNDS: List[float] = [10.0 ** (e / 8.0) for e in range(-44, 17)]

_WRITER_IDS = itertools.count(1)


def default_metrics_path(monitor_path: str) -> str:
    """``state/monitor.json`` -> ``state/monitor.metrics.json`` — same
    satellite-file convention as the plan cache / views / health blobs."""
    root, _ = os.path.splitext(monitor_path)
    return root + ".metrics.json"


def _bucket(v: float) -> int:
    # branchless-ish bisect; HIST_BOUNDS is small and fixed
    lo, hi = 0, len(HIST_BOUNDS)
    while lo < hi:
        mid = (lo + hi) // 2
        if v <= HIST_BOUNDS[mid]:
            hi = mid
        else:
            lo = mid + 1
    return lo          # == len(HIST_BOUNDS) -> overflow bucket


class Histogram:
    """Fixed-bucket latency histogram with streaming sum/min/max."""

    __slots__ = ("counts", "count", "sum", "min", "max")

    def __init__(self) -> None:
        self.counts: Dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        b = _bucket(v)
        self.counts[b] = self.counts.get(b, 0) + 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def quantile(self, q: float) -> float:
        """Estimated ``q``-quantile: the geometric midpoint of the bucket
        where the cumulative count crosses ``q * count``, clamped to the
        observed min/max so tail quantiles never over/under-shoot."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        acc = 0.0
        for b in sorted(self.counts):
            acc += self.counts[b]
            if acc >= target:
                lo = HIST_BOUNDS[b - 1] if b > 0 else HIST_BOUNDS[0] / 10.0
                hi = HIST_BOUNDS[b] if b < len(HIST_BOUNDS) else self.max
                est = (lo * hi) ** 0.5 if hi > 0 else 0.0
                return min(max(est, self.min), self.max)
        return self.max

    def merge(self, other: "Histogram") -> None:
        for b, n in other.counts.items():
            self.counts[b] = self.counts.get(b, 0) + n
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    # -- (de)serialization -------------------------------------------------
    def to_blob(self) -> Dict[str, Any]:
        return {"counts": {str(b): n for b, n in self.counts.items()},
                "count": self.count, "sum": self.sum,
                "min": (None if self.count == 0 else self.min),
                "max": self.max}

    @classmethod
    def from_blob(cls, blob: Dict[str, Any]) -> "Histogram":
        h = cls()
        h.counts = {int(b): int(n) for b, n in blob.get("counts", {}).items()}
        h.count = int(blob.get("count", 0))
        h.sum = float(blob.get("sum", 0.0))
        mn = blob.get("min")
        h.min = float("inf") if mn is None else float(mn)
        h.max = float(blob.get("max", 0.0))
        return h

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "sum": round(self.sum, 9),
                "p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99),
                "min": (0.0 if self.count == 0 else self.min),
                "max": self.max}


class Metrics:
    """One process's metrics registry, optionally backed by a shared file.

    Writes (``counter``/``gauge``/``observe``) take one internal lock;
    reads (``value``/``snapshot``) do not — they see a consistent-enough
    point-in-time view (CPython dict reads are atomic, and stats are
    monotone counters).
    """

    def __init__(self, path: Optional[str] = None, shared: bool = False):
        self.path = path
        self.shared = bool(shared)
        # process-unique writer id: pid + in-process counter so respawns /
        # multiple registries in one process never collide in the file
        self.writer_id = "%d-%d" % (os.getpid(), next(_WRITER_IDS))
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, Histogram] = {}

    # -- write path --------------------------------------------------------
    def counter(self, name: str, delta: float = 1.0) -> float:
        with self._lock:
            v = self._counters.get(name, 0.0) + delta
            self._counters[name] = v
            return v

    def set_counter(self, name: str, value: float) -> None:
        with self._lock:
            self._counters[name] = float(value)

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = float(value)

    def observe(self, name: str, seconds: float) -> None:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram()
            h.observe(seconds)

    # -- lock-free read path ----------------------------------------------
    def value(self, name: str, default: float = 0.0) -> float:
        return self._counters.get(name, self._gauges.get(name, default))

    def histogram(self, name: str) -> Optional[Histogram]:
        return self._hists.get(name)

    def snapshot(self, merged: bool = False) -> Dict[str, Any]:
        """Point-in-time view: ``{"counters", "gauges", "histograms"}``.
        With ``merged=True`` and a backing file, other writers' persisted
        sections are folded in (counters/histograms sum; gauges are
        per-process, local values win)."""
        counters = dict(self._counters)
        gauges = dict(self._gauges)
        hists = {k: Histogram.from_blob(h.to_blob())
                 for k, h in list(self._hists.items())}
        if merged and self.path:
            for wid, sec in self._read_sections().items():
                if wid == self.writer_id:
                    continue
                for k, v in sec.get("counters", {}).items():
                    counters[k] = counters.get(k, 0.0) + float(v)
                for k, v in sec.get("gauges", {}).items():
                    gauges.setdefault(k, float(v))
                for k, hb in sec.get("histograms", {}).items():
                    h = hists.get(k)
                    if h is None:
                        hists[k] = Histogram.from_blob(hb)
                    else:
                        h.merge(Histogram.from_blob(hb))
        return {"counters": counters, "gauges": gauges,
                "histograms": {k: h.summary() for k, h in hists.items()}}

    # -- persistence -------------------------------------------------------
    def _section(self) -> Dict[str, Any]:
        with self._lock:
            return {"counters": dict(self._counters),
                    "gauges": dict(self._gauges),
                    "histograms": {k: h.to_blob()
                                   for k, h in self._hists.items()}}

    def _read_sections(self) -> Dict[str, Dict[str, Any]]:
        if not self.path:
            return {}
        try:
            blob = load_json(self.path)
        except (OSError, ValueError):
            return {}
        if not isinstance(blob, dict):
            return {}
        return blob.get("writers", {})

    @contextlib.contextmanager
    def _file_lock(self, path: str):
        """Advisory lock serializing the read-modify-write below.  The
        monitor's merge-on-save tolerates a racing writer resurrecting a
        stale sibling section (counts may trail, never corrupt); a metrics
        registry is judged on exact totals, so saves take a per-file flock
        when the platform has one and the hammer test asserts exactness."""
        if fcntl is None:
            yield
            return
        with open(path + ".lock", "a") as lk:
            fcntl.flock(lk, fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(lk, fcntl.LOCK_UN)

    def save(self, path: Optional[str] = None) -> None:
        """Merge-on-save: rewrite only this writer's section, carry every
        other writer's through.  Atomic via ``ioutil.atomic_json_dump``;
        exact under multi-process contention via the advisory file lock."""
        path = path or self.path
        if not path:
            return
        with self._file_lock(path):
            writers = self._read_sections() \
                if (self.shared or path == self.path) else {}
            writers[self.writer_id] = self._section()
            atomic_json_dump(path, {"format": _FORMAT, "writers": writers})


def load_merged(path: str) -> Dict[str, Any]:
    """Merged snapshot of a metrics file, summed across all writers."""
    agg = Metrics()           # pathless scratch registry
    try:
        blob = load_json(path)
    except (OSError, ValueError):
        return agg.snapshot()
    counters: Dict[str, float] = {}
    gauges: Dict[str, float] = {}
    hists: Dict[str, Histogram] = {}
    for sec in blob.get("writers", {}).values():
        for k, v in sec.get("counters", {}).items():
            counters[k] = counters.get(k, 0.0) + float(v)
        for k, v in sec.get("gauges", {}).items():
            gauges[k] = float(v)
        for k, hb in sec.get("histograms", {}).items():
            h = hists.get(k)
            if h is None:
                hists[k] = Histogram.from_blob(hb)
            else:
                h.merge(Histogram.from_blob(hb))
    return {"counters": counters, "gauges": gauges,
            "histograms": {k: h.summary() for k, h in hists.items()}}


# -- multi-process contention hammer (spawn target; must be importable) ----
def _metrics_hammer(path: str, private: str, shared_name: str,
                    rounds: int, seed: int) -> None:
    """Worker body for the 3-process merge-on-save contention test: bump a
    private counter and a shared-name counter each round, observe a
    latency, and save after every round so writers constantly race on the
    file.  Exactness invariant: the final merged file must show each
    private counter == rounds and the shared counter == writers*rounds."""
    import random
    rng = random.Random(seed)
    m = Metrics(path, shared=True)
    for i in range(rounds):
        m.counter(private)
        m.counter(shared_name)
        m.observe("hammer.latency", rng.uniform(1e-4, 1e-1))
        m.gauge("hammer.last_round", float(i))
        m.save()
