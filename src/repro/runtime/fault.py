"""Fault-tolerance primitives: failure injection + straggler detection.

On a real cluster the failure signal is a missing heartbeat from a worker;
here ``FailureInjector`` raises at configured steps so the restart path is
exercised end-to-end in tests.  ``StragglerDetector`` watches step times — on
detection the trainer notifies the monitor (the BigDAWG drift path: the plan
that was optimal under training-time conditions is re-evaluated).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Set


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    fail_at_steps: Set[int] = field(default_factory=set)
    _fired: Set[int] = field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


@dataclass
class StragglerDetector:
    """Welford running stats over step times; z-score threshold flags
    stragglers (slow steps) for plan re-selection / replacement."""
    z_threshold: float = 3.0
    warmup: int = 5
    n: int = 0
    mean: float = 0.0
    m2: float = 0.0
    flagged: List[int] = field(default_factory=list)
    on_straggler: Optional[Callable[[int, float], None]] = None

    def observe(self, step: int, seconds: float) -> bool:
        if self.n >= self.warmup:
            std = math.sqrt(self.m2 / max(self.n - 1, 1))
            if std > 0 and (seconds - self.mean) / std > self.z_threshold:
                self.flagged.append(step)
                if self.on_straggler:
                    self.on_straggler(step, seconds)
                return True              # straggler: exclude from stats
        self.n += 1
        d = seconds - self.mean
        self.mean += d / self.n
        self.m2 += d * (seconds - self.mean)
        return False
