"""Fault-tolerance primitives: failure injection + straggler detection.

On a real cluster the failure signal is a missing heartbeat from a worker;
here ``FailureInjector`` raises at configured steps so the restart path is
exercised end-to-end in tests.  ``StragglerDetector`` watches step times — on
detection the trainer notifies the monitor (the BigDAWG drift path: the plan
that was optimal under training-time conditions is re-evaluated).
"""
from __future__ import annotations

import math
import os
import signal
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set


class SimulatedFailure(RuntimeError):
    # injected faults stand in for real infrastructure failures, so the
    # executor's errors.is_engine_failure classifier must treat them as
    # breaker-feedable (unlike, say, a KeyError from a bad query)
    engine_failure = True


@dataclass
class FailureInjector:
    fail_at_steps: Set[int] = field(default_factory=set)
    _fired: Set[int] = field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


class EngineFaultInjector:
    """Engine-level fault source for the resilience path: plugged into
    ``core.health.EngineHealth(injector=...)``, its ``before_op`` hook fires
    in the executor just before every engine op, so a benchmark or test can
    take an engine down (or make it pathologically slow) MID-SERVE without
    touching engine code.

        inj = EngineFaultInjector()
        health = EngineHealth(injector=inj)
        ...
        inj.fail_engine("kv_sparse")          # ops now raise SimulatedFailure
        inj.slow_engine("dense_array", 0.05)  # ops now sleep 50 ms first
        inj.recover("kv_sparse")              # back to healthy

    Thread-safe: the serve path reads the fault maps under the same lock the
    control calls mutate them under."""

    def __init__(self):
        self._down: Set[str] = set()
        self._slow: Dict[str, float] = {}
        self._lock = threading.Lock()
        self.faults_fired = 0

    def fail_engine(self, engine: str):
        with self._lock:
            self._down.add(engine)

    def slow_engine(self, engine: str, seconds: float):
        with self._lock:
            self._slow[engine] = seconds

    def recover(self, engine: str):
        with self._lock:
            self._down.discard(engine)
            self._slow.pop(engine, None)

    def before_op(self, engine: str, op: str = ""):
        with self._lock:
            down = engine in self._down
            delay = self._slow.get(engine, 0.0)
            if down or delay:
                self.faults_fired += 1
        if down:
            raise SimulatedFailure(
                f"injected outage on engine {engine!r}"
                + (f" (op {op!r})" if op else ""))
        if delay:
            time.sleep(delay)


class FusionFaultInjector:
    """Fused-segment fault source for plan-level kernel fusion: plugged into
    ``BigDAWG(fusion_injector=...)``, its ``on_fuse`` hook fires in the
    executor just before every fused-segment invocation — the seam where a
    real trace/compile failure would surface — so tests can force the
    fused->unfused fallback MID-SERVE and assert it is sticky per segment
    signature (``fired`` records each key the injector hit).

        inj = FusionFaultInjector()
        bd = BigDAWG(fusion_injector=inj)
        ...
        inj.arm(1)          # next fused invocation raises SimulatedFailure

    The raise lands inside the executor's per-segment fallback guard, so the
    serve completes unfused with identical results and the segment key is
    marked broken (``fuseplan.mark_broken``) — it never becomes an
    ``EngineDown``.  Thread-safe; disarmed (``fail_next=0``) by default."""

    def __init__(self, fail_next: int = 0):
        self._fail_next = fail_next
        self._lock = threading.Lock()
        self.fired: List[str] = []        # segment keys hit, in order

    def arm(self, n: int = 1) -> None:
        """Fail the next ``n`` fused invocations."""
        with self._lock:
            self._fail_next = n

    def on_fuse(self, key: str) -> None:
        with self._lock:
            if self._fail_next <= 0:
                return
            self._fail_next -= 1
            self.fired.append(key)
        raise SimulatedFailure(
            f"injected fused-segment compile failure for {key!r}")


class WorkerKillInjector:
    """Process-level fault source for the multi-process pool: plugged into
    ``core.procpool.ProcPool(kill_injector=...)``, its ``on_dispatch`` hook
    fires in the master right after an execute request is written to a
    worker's pipe — SIGKILL at that instant lands MID-REQUEST, the hardest
    point in the RPC lifecycle (the message may or may not have been picked
    up; either way the master must detect the death, respawn, and retry or
    surface a clean ``EngineDown``, never hang).

        inj = WorkerKillInjector(kill_on_dispatch=3)   # 3rd execute dispatch
        pool = ProcPool(2, kill_injector=inj)

    ``target_worker`` restricts the kill to one worker index; ``kills``
    counts delivered signals.  One-shot by default (``repeat=False``)."""

    def __init__(self, kill_on_dispatch: int = 1,
                 target_worker: Optional[int] = None, repeat: bool = False):
        self.kill_on_dispatch = kill_on_dispatch
        self.target_worker = target_worker
        self.repeat = repeat
        self.kills = 0
        self._count = 0
        self._lock = threading.Lock()

    def on_dispatch(self, widx: int, pid: int) -> None:
        with self._lock:
            if self.target_worker is not None and widx != self.target_worker:
                return
            self._count += 1
            due = (self._count == self.kill_on_dispatch if not self.repeat
                   else self._count % self.kill_on_dispatch == 0)
            if not due:
                return
            self.kills += 1
        try:
            os.kill(pid, signal.SIGKILL)
        except (ProcessLookupError, PermissionError):
            pass                           # already gone — death still lands


@dataclass
class StragglerDetector:
    """Welford running stats over step times; z-score threshold flags
    stragglers (slow steps) for plan re-selection / replacement."""
    z_threshold: float = 3.0
    warmup: int = 5
    n: int = 0
    mean: float = 0.0
    m2: float = 0.0
    flagged: List[int] = field(default_factory=list)
    on_straggler: Optional[Callable[[int, float], None]] = None

    def observe(self, step: int, seconds: float) -> bool:
        if self.n >= self.warmup:
            std = math.sqrt(self.m2 / max(self.n - 1, 1))
            if std > 0 and (seconds - self.mean) / std > self.z_threshold:
                self.flagged.append(step)
                if self.on_straggler:
                    self.on_straggler(step, seconds)
                return True              # straggler: exclude from stats
        self.n += 1
        d = seconds - self.mean
        self.mean += d / self.n
        self.m2 += d * (seconds - self.mean)
        return False
