"""Quickstart: a cross-island polystore query in ~40 lines, end to end
through the adaptive planning loop (see docs/PLANNER_LOOP.md).

This is the paper's own example (§III-C-2):
    ARRAY( multiply( RELATIONAL( select * from A ... ), B ) )
The RELATIONAL scope runs on the columnar engine, the ARRAY scope on the
dense engine, and the middleware inserts the Cast between them.  The second
half restarts the middleware on the same state files — a warm restart serves
production with zero plan enumerations, and the budgeted exploration path
keeps trying the k-best DP's runner-up plans while serving the winner
(``stats["explorations"]``); ``stats["replans"]`` counts online re-plans
from predicted/measured divergence.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import tempfile
import os

import numpy as np
import jax.numpy as jnp

from repro.core import BigDAWG, DenseTensor, Monitor, array, relational
from repro.runtime import QueryServer

state_dir = tempfile.mkdtemp(prefix="bigdawg-quickstart-")
rng = np.random.default_rng(0)


def make_bigdawg():
    """Middleware wired to persistent state files (monitor DB, calibration
    and plan cache ride side by side under state_dir)."""
    bd = BigDAWG(monitor=Monitor(os.path.join(state_dir, "monitor.json")),
                 explore_budget=0.5)       # spend <=50% of serve time trying
    bd.register("A", DenseTensor(jnp.asarray(                  # alternates
        rng.normal(size=(256, 256)).astype(np.float32))), engine="columnar")
    bd.register("B", DenseTensor(jnp.asarray(
        rng.normal(size=(256, 64)).astype(np.float32))), engine="dense_array")
    return bd


def query():
    # the paper's cross-island query (rebuilt fresh each time: signatures
    # make structurally-identical queries share plans and history)
    return array.matmul(relational.select("A", column="value",
                                          lo=-0.5, hi=2.0), "B")


# -- first process: training phase, then persist ----------------------------
bd = make_bigdawg()
report = bd.execute(query(), mode="training")    # first time: explore plans
print(f"training phase: tried {report.plans_tried} plans, "
      f"winner={report.plan_key} in {report.seconds*1e3:.1f} ms")
srv = QueryServer(bd)
srv.persist()                                    # flush monitor/calib/plans

# -- second process (simulated): warm restart, production + exploration -----
srv2 = QueryServer(make_bigdawg())               # reads the persisted state
for _ in range(4):
    report = srv2.submit(query())                # production: cached plan
print(f"production phase: plan={report.plan_key} "
      f"in {report.seconds*1e3:.1f} ms (cast {report.cast_bytes/1e6:.1f} MB)")
print(f"after warm restart: trainings={srv2.stats['trainings']} "
      f"explorations={srv2.stats['explorations']} "
      f"replans={srv2.stats['replans']}")
print("result:", report.result.data.shape, report.result.data.dtype)

# -- concurrent admission: the same traffic from 4 client threads ------------
# submit_many drives the server's request pool; the middleware's
# per-signature locking would train a cold signature exactly once even if
# every thread raced it, and exploration trials run off-path on the host
# pool (stats["seconds"] contains zero exploration time).
out = srv2.serve([query() for _ in range(8)], workers=4)
srv2.bd.drain_explorations()                     # let background trials land
print(f"concurrent serve: {out['rps']:.1f} requests/sec from "
      f"{out['workers']} threads "
      f"(explorations so far: {srv2.stats['explorations']})")
