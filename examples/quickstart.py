"""Quickstart: a cross-island polystore query in ~40 lines, end to end
through the adaptive planning loop (see docs/PLANNER_LOOP.md).

This is the paper's own example (§III-C-2):
    ARRAY( multiply( RELATIONAL( select * from A ... ), B ) )
written in the paper's textual syntax and executed through the
``connect()``/``Session`` front door: the RELATIONAL scope runs on the
columnar engine, the ARRAY scope on the dense engine, and the planner prices
and places the Cast at the island seam.  The second half restarts the
session on the same state files — a warm restart serves production with zero
plan enumerations — and drives concurrent traffic through a bounded-admission
``QueryServer``.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import tempfile
import os

import numpy as np
import jax.numpy as jnp

from repro.core import DenseTensor, connect

state_dir = tempfile.mkdtemp(prefix="bigdawg-quickstart-")
rng = np.random.default_rng(0)

# the paper's cross-island query, in the paper's textual surface: a nested
# island block is a SCOPE, the seam between blocks is a CAST the planner
# places.  (s.parse(QUERY) shows the compiled PolyOp IR; the attribute API
# — s.islands.array.matmul(s.islands.array.scope(...), "B") — builds the
# signature-identical tree.)
QUERY = "ARRAY(matmul(RELATIONAL(select(A, column=value, lo=-0.5, hi=2.0)), B))"


def make_session():
    """Session wired to persistent state files (monitor DB, calibration and
    plan cache ride side by side under state_dir)."""
    s = connect(os.path.join(state_dir, "monitor.json"),
                explore_budget=0.5)        # spend <=50% of serve time trying
    s.register("A", DenseTensor(jnp.asarray(                   # alternates
        rng.normal(size=(256, 256)).astype(np.float32))), engine="columnar")
    s.register("B", DenseTensor(jnp.asarray(
        rng.normal(size=(256, 64)).astype(np.float32))), engine="dense_array")
    return s


# -- first process: training phase, then persist ----------------------------
s = make_session()
res = s.execute(QUERY, mode="training")          # first time: explore plans
print(f"training phase: tried {res.report.plans_tried} plans "
      f"in {res.seconds*1e3:.1f} ms")
print(f"islands: {res.islands}")
print(f"plan:    {res.describe()}")
s.persist()                                      # flush monitor/calib/plans

# -- second process (simulated): warm restart, production + exploration -----
s2 = make_session()                              # reads the persisted state
srv = s2.server(max_pending=64)                  # bounded admission
for _ in range(4):
    res = s2.execute(QUERY)                      # production: cached plan
print(f"production phase: {res.seconds*1e3:.1f} ms "
      f"(cast {res.cast_bytes/1e6:.1f} MB, mode={res.mode})")
print("result:", res.value.data.shape, res.value.data.dtype)

# -- concurrent admission: the same traffic from 4 client threads ------------
# submit_many drives the server's request pool; the middleware's
# per-signature locking would train a cold signature exactly once even if
# every thread raced it, and with max_pending set, overflow beyond the bound
# is shed (stats["shed"]) instead of queued without limit.
out = srv.serve([s2.parse(QUERY) for _ in range(8)], workers=4)
srv.bd.drain_explorations()                      # let background trials land
print(f"concurrent serve: {out['rps']:.1f} requests/sec from "
      f"{out['workers']} threads (shed: {out['shed']}, "
      f"explorations so far: {srv.stats['explorations']})")
