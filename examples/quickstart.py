"""Quickstart: a cross-island polystore query in ~20 lines.

This is the paper's own example (§III-C-2):
    ARRAY( multiply( RELATIONAL( select * from A ... ), B ) )
The RELATIONAL scope runs on the columnar engine, the ARRAY scope on the
dense engine, and the middleware inserts the Cast between them.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import BigDAWG, DenseTensor, array, relational

bd = BigDAWG()
rng = np.random.default_rng(0)
bd.register("A", DenseTensor(jnp.asarray(
    rng.normal(size=(256, 256)).astype(np.float32))), engine="columnar")
bd.register("B", DenseTensor(jnp.asarray(
    rng.normal(size=(256, 64)).astype(np.float32))), engine="dense_array")

# the paper's cross-island query
query = array.matmul(relational.select("A", column="value", lo=-0.5, hi=2.0),
                     "B")

report = bd.execute(query, mode="training")      # first time: explore plans
print(f"training phase: tried {report.plans_tried} plans, "
      f"winner={report.plan_key} in {report.seconds*1e3:.1f} ms")

report = bd.execute(query)                       # now: production phase
print(f"production phase: plan={report.plan_key} "
      f"in {report.seconds*1e3:.1f} ms (cast {report.cast_bytes/1e6:.1f} MB)")
print("result:", report.result.data.shape, report.result.data.dtype)
