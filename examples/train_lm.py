"""End-to-end LM training driver on CPU: a reduced internlm2-family model
through the full production stack — sharded loader, AdamW + cosine schedule,
grad accumulation, async checkpointing, failure injection + automatic
restart, straggler detection.

Defaults train a ~13M-param model for 60 steps (a few minutes on this
container); ``--d-model 768 --layers 12 --steps 300`` gives a ~100M-param
run when you have the budget.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 60] [--fail-at 25]
"""
import argparse
import dataclasses
import shutil

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import get_arch, PlanConfig
from repro.data import TokenStream
from repro.models import api
from repro.optim import AdamW, cosine_schedule
from repro.runtime import FailureInjector, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=2)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a node failure at this step")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_arch("internlm2-1.8b"), name="internlm2-mini",
        num_layers=args.layers, d_model=args.d_model,
        num_heads=max(args.d_model // 64, 2),
        num_kv_heads=max(args.d_model // 128, 1),
        head_dim=64, d_ff=args.d_model * 4, vocab_size=args.vocab)
    plan = PlanConfig(param_dtype="float32", compute_dtype="float32",
                      master_dtype="float32", accum=args.accum,
                      attn_chunk=64, loss_chunk=64, remat="none")
    n = api.count_params(cfg)
    print(f"model: {cfg.name} {n/1e6:.1f}M params; "
          f"{args.batch}x{args.seq} tokens/step, accum={args.accum}")

    opt = AdamW(learning_rate=cosine_schedule(3e-4, 10, args.steps),
                weight_decay=0.01)
    state = api.init_train_state(cfg, plan, jax.random.PRNGKey(0), opt)
    step_fn = jax.jit(api.make_train_step(cfg, plan, opt), donate_argnums=0)

    stream = TokenStream(vocab_size=cfg.vocab_size, batch=args.batch,
                         seq_len=args.seq, seed=42)
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    trainer = Trainer(
        step_fn, lambda s: {"tokens": stream.batch_at(s)},
        CheckpointManager(args.ckpt_dir, keep_last=2), ckpt_every=10,
        injector=FailureInjector({args.fail_at}) if args.fail_at else None)
    trainer.straggler.on_straggler = \
        lambda s, t: print(f"  [straggler] step {s}: {t:.2f}s")

    state, restarts = trainer.run_with_restarts(state, args.steps)
    losses = trainer.losses()
    print(f"restarts: {restarts}")
    print(f"loss: first5={losses[:5].mean():.4f} last5={losses[-5:].mean():.4f}")
    assert losses[-5:].mean() < losses[:5].mean(), "training must reduce loss"
    tps = args.batch * args.seq / np.mean(
        [h["seconds"] for h in trainer.history[5:]])
    print(f"throughput: {tps:,.0f} tokens/s on CPU; "
          f"checkpoints at {args.ckpt_dir}: steps {trainer.ckpt.steps()}")
    print("OK: end-to-end training with fault tolerance")


if __name__ == "__main__":
    main()
