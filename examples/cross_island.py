"""Cross-island queries three ways: attribute API + explicit scope(), the
paper's textual BIGDAWG(ISLAND(...)) syntax, and the |> pipeline sugar — all
compiling to one IR, one signature, one cached plan.

The query: a RELATIONAL join reconstructs a matrix from an edge table A
(i, key, value) and a key->column mapping B (key, j), then an ARRAY matmul
projects it against W.  The island seam between join and matmul is a
first-class `scope` node: the planner prices the columnar->dense cast there
with the calibrated per-pair bandwidths (multi-hop routed, charged per hop)
and the executor moves the bytes through the migrator — the `Result`'s
provenance shows exactly where.

Run: PYTHONPATH=src python examples/cross_island.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (ColumnarTable, DenseTensor, connect, signature,
                        signature_text)

rng = np.random.default_rng(0)
N, K, D = 64, 32, 8
M = rng.normal(size=(N, K)).astype(np.float32)
perm = rng.permutation(K)
W = rng.normal(size=(K, D)).astype(np.float32)

# relational inputs: the matrix as an edge table + the column mapping
ii, kk = np.meshgrid(np.arange(N), np.arange(K), indexing="ij")
A = ColumnarTable({"i": ii.ravel().astype(np.int32),
                   "key": kk.ravel().astype(np.int32),
                   "value": M.ravel()})
B = ColumnarTable({"key": np.arange(K, dtype=np.int32),
                   "j": perm.astype(np.int32)})

s = connect()
s.register("A", A, "columnar").register("B", B, "columnar")
s.register("W", DenseTensor(jnp.asarray(W)), "dense_array")

# -- one query, three surfaces ----------------------------------------------
isl = s.islands
q_api = isl.array.matmul(
    isl.array.scope(isl.relational.join("A", "B",
                                        left_on="key", right_on="key")), "W")
q_nested = s.parse("BIGDAWG(ARRAY(matmul(RELATIONAL("
                   "join(A, B, left_on=key, right_on=key)), W)))")
q_pipe = s.parse("RELATIONAL(join(A, B, left_on=key, right_on=key)) "
                 "|> ARRAY(matmul(_, W))")
sigs = {signature(q, s.catalog) for q in (q_api, q_nested, q_pipe)}
assert len(sigs) == 1, "the three surfaces must share one signature"
print("canonical form:", signature_text(q_api))
print("signature:     ", sigs.pop())

# -- parse -> plan -> execute ------------------------------------------------
res = s.execute(q_pipe, mode="training")
print(f"\nislands:    {res.islands}")
print(f"plan:       {res.describe()}")
print(f"seconds:    {res.seconds*1e3:.2f} ms "
      f"(cast {res.cast_bytes/1e3:.1f} kB across the island seam)")
print(f"per node:   " + ", ".join(f"{p}={t*1e3:.2f}ms" for p, t in
                                  sorted(res.per_node_seconds.items())))

# correctness against the numpy reference
Pm = np.zeros((K, K), np.float32)
Pm[np.arange(K), perm] = 1.0
np.testing.assert_allclose(np.asarray(res.value.data), (M @ Pm) @ W,
                           rtol=1e-4, atol=1e-4)

# the textual twin serves from the same cached plan — no re-enumeration
res2 = s.execute(q_nested)
assert res2.mode == "production" and res2.plan_key == res.plan_key
print(f"\ntextual twin served {res2.mode} from the same plan "
      f"({res2.seconds*1e3:.2f} ms)")
print("OK: one cross-island query, three surfaces, one plan")
