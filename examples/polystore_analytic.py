"""The paper's §IV-B medical analytic, end to end: classify hemodynamic
deterioration from ECG waveforms via Haar signatures + TF-IDF + kNN
(Saeed & Mark), executed as a polystore query.

Trains on 600 synthetic MIMIC-like patients, classifies 64 held-out test
patients under the training-phase-discovered plan, and reports accuracy plus
the plan comparison of paper Fig. 5.  The analytic is issued through the
``connect()`` session front door as a textual island query (see
``repro.core.qlang``).

Run: PYTHONPATH=src python examples/polystore_analytic.py [--patients 600]
"""
import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.core import DenseTensor, connect
from repro.core.engines import _da_bin_hist
from repro.data import ecg_waveforms
from repro.kernels.ref import haar_ref

LEVELS, NBINS, K = 6, 32, 11

# the analytic pipeline as one textual query (the same IR the attribute API
# would build via session.islands.array)
QUERY = (f"ARRAY(knn(tfidf(bin_hist(haar(waves, levels={LEVELS}), "
         f"nbins={NBINS}, levels={LEVELS})), test_hist, k={K}))")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--patients", type=int, default=600)
    ap.add_argument("--test", type=int, default=64)
    ap.add_argument("--samples", type=int, default=16384)
    args = ap.parse_args()

    waves, labels = ecg_waveforms(args.patients + args.test, args.samples)
    train_w, test_w = waves[:args.patients], waves[args.patients:]
    train_y, test_y = labels[:args.patients], labels[args.patients:]

    session = connect(train_plans=36)
    session.register("waves", DenseTensor(jnp.asarray(train_w)),
                     engine="dense_array")

    # precompute each test patient's tf-idf-ready histogram (same features)
    test_hists = _da_bin_hist({"nbins": NBINS, "levels": LEVELS},
                              DenseTensor(haar_ref(jnp.asarray(test_w),
                                                   LEVELS))).data

    correct = 0
    t0 = time.perf_counter()
    plan_key = None
    for i in range(args.test):
        session.register("test_hist", DenseTensor(test_hists[i:i + 1]),
                         engine="dense_array")
        res = session.execute(QUERY)  # training once, production thereafter
        plan_key = res.plan_key
        neighbors = np.asarray(res.value.data)[0]
        pred = int(np.round(train_y[neighbors].mean()))
        correct += int(pred == test_y[i])
    dt = time.perf_counter() - t0

    acc = correct / args.test
    base = max(test_y.mean(), 1 - test_y.mean())
    print(f"plan: {plan_key}")
    print(f"classified {args.test} patients in {dt:.1f}s "
          f"({dt/args.test*1e3:.0f} ms/patient)")
    print(f"accuracy: {acc:.3f} (majority-class baseline {base:.3f})")
    assert acc > base + 0.05, "classifier should beat the baseline"
    print("OK: wavelet-signature kNN separates deteriorating patients")


if __name__ == "__main__":
    main()
