"""Batched LM serving with continuous slot management: prefill into free KV
slots, decode all active slots together, release on completion — the
standard continuous-batching loop, over a reduced internlm2-family model.

Run: PYTHONPATH=src python examples/serve_lm.py [--requests 12] [--slots 4]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, PlanConfig, ShapeConfig
from repro.models import api
from repro.models import transformer as T
from repro.runtime import BatchServer
from repro.runtime.server import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_arch("internlm2-1.8b"), name="internlm2-serve",
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=1024, vocab_size=1024)
    plan = PlanConfig(param_dtype="float32", compute_dtype="float32",
                      attn_chunk=64, remat="none")
    params = api.init_params(cfg, jax.random.PRNGKey(0), plan)
    shape = ShapeConfig("serve", "decode", args.max_len, args.slots)

    prefill1 = jax.jit(lambda p, toks: T.lm_prefill(cfg, plan, p, toks,
                                                    args.max_len))
    decode = jax.jit(api.make_decode_step(cfg, shape, plan))

    server = BatchServer(
        slots=args.slots, max_len=args.max_len,
        prefill_fn=prefill1, decode_fn=decode, params=params,
        init_cache_fn=lambda b, ml: T.init_cache(cfg, b, ml,
                                                 jnp.float32),
        eos_id=None)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        rng.integers(4, 17)).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    server.run(reqs)

    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == args.new_tokens for r in reqs)
    s = server.stats
    tps = s["tokens_out"] / max(s["decode_seconds"], 1e-9)
    print(f"served {len(reqs)} requests on {args.slots} slots: "
          f"{s['prefills']} prefills, {s['decode_steps']} decode steps")
    print(f"decode throughput: {tps:,.0f} tokens/s "
          f"(batched decode over active slots)")
    print("sample output:", reqs[0].out_tokens[:10])
    print("OK: continuous-batching serving loop")


if __name__ == "__main__":
    main()
