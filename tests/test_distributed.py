"""Multi-device correctness tests, run in subprocesses with
--xla_force_host_platform_device_count so the main pytest process keeps its
single-device view (smoke tests must see 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(n, code):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n}")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=900, env=env)
    assert p.returncode == 0, p.stdout + p.stderr
    return p.stdout


def test_cp_decode_matches_naive_on_8_devices():
    out = run_devices(8, """
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_arch, PlanConfig, ShapeConfig
        from repro.models import api
        from repro.models.partition import plan_scope
        from repro.launch.mesh import make_mesh_compat

        cfg = get_arch("internlm2-1.8b").smoke()
        plan = PlanConfig(param_dtype="float32", compute_dtype="float32",
                          attn_chunk=8, remat="none")
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        shape = ShapeConfig("d", "decode", 32, 4)
        params = api.init_params(cfg, jax.random.PRNGKey(0), plan)
        tok = jnp.array([3, 5, 7, 9], jnp.int32)
        pos = jnp.array([9, 17, 4, 30], jnp.int32)

        def run(decode_cp):
            p2 = plan.with_(decode_cp=decode_cp)
            with plan_scope(mesh, p2):
                cache = jax.tree.map(
                    lambda s: jnp.zeros(s.shape, s.dtype),
                    api.example_cache(cfg, shape, p2))
                # fill the cache with deterministic values
                cache = jax.tree.map(
                    lambda c: (jnp.arange(c.size, dtype=jnp.float32)
                               .reshape(c.shape) % 7 - 3) / 10 if
                    jnp.issubdtype(c.dtype, jnp.floating) else c, cache)
                step = jax.jit(api.make_decode_step(cfg, shape, p2))
                nt, nc = step(params, cache, tok, pos)
                return np.asarray(nt), jax.tree.map(np.asarray, nc)

        t0, c0 = run(False)
        t1, c1 = run(True)
        np.testing.assert_array_equal(t0, t1)
        for a, b in zip(jax.tree.leaves(c0), jax.tree.leaves(c1)):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=5e-6)
        print("CP_DECODE_OK")
    """)
    assert "CP_DECODE_OK" in out


def test_sharded_train_step_matches_single_device():
    out = run_devices(8, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_arch, PlanConfig
        from repro.models import api
        from repro.models.partition import plan_scope
        from repro.launch.mesh import make_mesh_compat
        from repro.optim import AdamW

        cfg = get_arch("internlm2-1.8b").smoke()
        plan = PlanConfig(param_dtype="float32", compute_dtype="float32",
                          master_dtype="float32", attn_chunk=8, loss_chunk=8,
                          remat="none")
        opt = AdamW(learning_rate=1e-3)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16),
                                              0, cfg.vocab_size)}
        # single device
        state0 = api.init_train_state(cfg, plan, jax.random.PRNGKey(0), opt)
        s1, m1 = jax.jit(api.make_train_step(cfg, plan, opt))(state0, batch)
        # 8-device mesh (dp=2, tp=4)
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        with plan_scope(mesh, plan):
            state0b = api.init_train_state(cfg, plan, jax.random.PRNGKey(0), opt)
            sspec = api.train_state_specs(cfg, plan,
                                          jax.eval_shape(lambda: state0b))
            sshard = api.to_shardings(mesh, sspec)
            state0b = jax.tree.map(jax.device_put, state0b,
                                   sshard)
            step = jax.jit(api.make_train_step(cfg, plan, opt),
                           in_shardings=(sshard, None),
                           out_shardings=(sshard, None))
            s2, m2 = step(state0b, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-4)
        for a, b in zip(jax.tree.leaves(s1["master"]),
                        jax.tree.leaves(s2["master"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-4)
        print("SHARDED_TRAIN_OK")
    """)
    assert "SHARDED_TRAIN_OK" in out
