"""End-to-end behaviour tests for the paper's system: a full polystore
session — register heterogeneous data, train, production, drift — plus the
paper's flagship analytic pipeline asserting plan-answer agreement."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (BigDAWG, DenseTensor, array, relational,
                        execute_plan)
from repro.core.planner import Plan
from repro.data import mimic_like_dataset


@pytest.fixture(scope="module")
def session():
    ds = mimic_like_dataset(n_patients=64, n_samples=1024)
    bd = BigDAWG(train_plans=36)
    bd.register("waves", ds["waveforms"], engine="dense_array")
    bd.register("patients", ds["patients"], engine="columnar")
    bd.register("notes", ds["notes"], engine="kv_sparse")
    return bd, ds


def _analytic_query():
    coeffs = array.haar("waves", levels=4)
    hist = array.bin_hist(coeffs, nbins=16, levels=4)
    return array.tfidf(hist)


def test_full_polystore_session(session):
    bd, ds = session
    q = _analytic_query()
    rep1 = bd.execute(q)                      # auto -> training
    assert rep1.mode == "training" and rep1.plans_tried > 1
    rep2 = bd.execute(q)                      # auto -> production
    assert rep2.mode == "production"
    assert rep2.plan_key == rep1.plan_key
    assert rep2.result.kind == "dense"        # array island delivers dense
    got = np.asarray(rep2.result.data)
    assert got.shape[0] == 64 and np.all(np.isfinite(got))
    # rows are l2-normalized tf-idf vectors
    norms = np.linalg.norm(got, axis=1)
    np.testing.assert_allclose(norms[norms > 0], 1.0, rtol=1e-4)


def test_plans_agree_on_answers(session):
    """Location transparency: every engine placement gives the same answer."""
    bd, _ = session
    q = _analytic_query()
    dense_only = Plan(((0, "dense_array"), (1, "dense_array"),
                       (2, "dense_array")))
    columnar_only = Plan(((0, "columnar"), (1, "columnar"), (2, "columnar")))
    r_d = execute_plan(q, dense_only, bd.catalog)
    r_c = execute_plan(q, columnar_only, bd.catalog)
    d = np.asarray(r_d.value.data)
    from repro.core import cast as castmod
    c = np.asarray(castmod.cast(r_c.value, "dense").data)
    np.testing.assert_allclose(d, c, rtol=1e-3, atol=1e-4)


def test_cross_island_query_correct(session):
    bd, ds = session
    q = array.matmul(relational.select("waves", column="value", lo=0.0),
                     array.transpose("waves"))
    rep = bd.execute(q, mode="training")
    W = np.asarray(ds["waveforms"].data)
    want = np.where(W >= 0.0, W, 0.0) @ W.T
    np.testing.assert_allclose(np.asarray(rep.result.data), want,
                               rtol=1e-3, atol=1e-2)
