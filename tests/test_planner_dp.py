"""Planner DP + calibrated cost model + plan cache + concurrent executor.

Covers the §III-C planner rebuild: the container DP must agree with
exhaustive enumeration, the calibrated cost model must rank plans in measured
order where the gap is structural, production must serve from the plan cache
without re-enumeration, and concurrent level dispatch must preserve answers.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (BigDAWG, CostModel, DenseTensor, Monitor, array,
                        relational, dp_plans, enumerate_plans,
                        exhaustive_plans, execute_plan, plan_containers,
                        plan_cost, estimate_sizes, topo_levels)
from repro.core.monitor import PlanStats
from repro.core.planner import Plan
from repro.runtime import QueryServer


@pytest.fixture(scope="module")
def cm():
    model = CostModel()
    model.calibrate(n=64)
    return model


def _bd(cm=None, n=32, t=64):
    bd = BigDAWG(cost_model=cm)
    rng = np.random.default_rng(0)
    bd.register("waves", DenseTensor(jnp.asarray(
        rng.normal(size=(n, t)).astype(np.float32))), engine="dense_array")
    return bd


def _analytic():
    s = relational.select("waves", column="value", lo=0.0)
    h = array.haar(s, levels=2)
    b = array.bin_hist(h, nbins=8, levels=2)
    return array.tfidf(b)


def _wide():                                  # 10-node tree, 648-plan space
    def branch():
        return _analytic()
    return array.matmul(branch(), array.transpose(branch()))


# ---------------------------------------------------------------------------
# DP vs exhaustive enumeration
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mk", [
    lambda: array.matmul(relational.select("waves", column="value", lo=-1.0),
                         "waves"),
    _analytic,
    _wide,
])
def test_dp_agrees_with_exhaustive(mk, cm):
    bd = _bd(cm)
    q = mk()
    k = 8
    dp = dp_plans(q, bd.catalog, max_plans=k, cost_model=cm)
    ex = exhaustive_plans(q, bd.catalog, cost_model=cm)
    assert dp[0][1].key == ex[0][1].key                 # same optimum
    np.testing.assert_allclose(dp[0][0], ex[0][0], rtol=1e-9)
    # the whole k-best front matches (costs, up to ties)
    np.testing.assert_allclose([c for c, _ in dp],
                               [c for c, _ in ex[:len(dp)]], rtol=1e-9)


def test_dp_handles_diamond_merge():
    """A node that merges into an early container while depending on a later
    one (select and matmul share candidates; tfidf sits between them) must
    plan via topological order, not container-list order.  Uses the default
    (deterministic) cost model so the assertion is machine-independent."""
    bd = _bd(n=16, t=16)
    model = CostModel()
    a = array.select("waves", lo=0.0)
    q = array.matmul(a, array.tfidf(a))
    dp = dp_plans(q, bd.catalog, max_plans=8, cost_model=model)
    ex = exhaustive_plans(q, bd.catalog, cost_model=model)
    assert dp[0][1].key == ex[0][1].key
    np.testing.assert_allclose(dp[0][0], ex[0][0], rtol=1e-9)
    # after collapsing shared occurrences the DP front is a subset of the
    # exhaustive space; every candidate must exist there at the same cost
    ex_cost = {p.key: c for c, p in ex}
    for cost, plan in dp:
        np.testing.assert_allclose(cost, ex_cost[plan.key], rtol=1e-9)


def test_dp_shared_input_costs_match_plan_cost(cm):
    """Shared subtrees: DP candidates must carry the cost execution will see
    (plan_cost collapses each shared node to one engine, like the executor);
    optimum equality is asserted under the deterministic default model."""
    bd = _bd(cm, n=16, t=16)
    h = array.tfidf("waves")
    q = array.matmul(h, array.scale(h, factor=2.0))
    for cost, plan in dp_plans(q, bd.catalog, max_plans=8, cost_model=cm):
        np.testing.assert_allclose(cost, plan_cost(q, plan, bd.catalog, cm),
                                   rtol=1e-9)
    model = CostModel()
    ex = exhaustive_plans(q, bd.catalog, cost_model=model)
    dp = dp_plans(q, bd.catalog, max_plans=8, cost_model=model)
    assert dp[0][1].key == ex[0][1].key


def test_dp_sees_past_truncated_prefix(cm):
    """The full space, not the first-16 product prefix: the DP optimum on a
    wide DAG must be found even when the space dwarfs any truncation cap."""
    bd = _bd(cm)
    q = _wide()
    space = 1
    for c in plan_containers(q, bd.catalog):
        space *= len(c.candidates)
    assert space > 16 * 4                                # way past the old cap
    dp = dp_plans(q, bd.catalog, max_plans=4, cost_model=cm)
    ex = exhaustive_plans(q, bd.catalog, cost_model=cm)
    assert dp[0][1].key == ex[0][1].key


def test_dp_exact_under_adversarial_rates():
    """Per-engine k-best fronts: even when every cheap subplan ends on one
    engine and the global optimum needs a different child engine to dodge a
    brutal cast, the DP must still find it (global-cut truncation regression)."""
    model = CostModel()
    for op in ("haar", "bin_hist", "tfidf", "select", "matmul", "transpose"):
        model.observe_op("columnar", op, 1e6, 0.001)      # columnar looks fast
        model.observe_op("dense_array", op, 1e6, 0.01)
    model.observe_cast("columnar", "dense", 1e3, 1.0)     # 1e3 B/s cast
    bd = _bd()
    q = _wide()
    for k in (1, 2, 3, 8):
        dp = dp_plans(q, bd.catalog, max_plans=k, cost_model=model)
        ex = exhaustive_plans(q, bd.catalog, cost_model=model)
        assert dp[0][1].key == ex[0][1].key
        np.testing.assert_allclose([c for c, _ in dp],
                                   [c for c, _ in ex[:len(dp)]], rtol=1e-9)


def test_dp_cost_equals_plan_cost(cm):
    """DP internal accounting must match the standalone plan costing."""
    bd = _bd(cm)
    q = _analytic()
    for cost, plan in dp_plans(q, bd.catalog, max_plans=6, cost_model=cm):
        np.testing.assert_allclose(cost, plan_cost(q, plan, bd.catalog, cm),
                                   rtol=1e-9)


def test_enumerate_keeps_hybrid_plans():
    bd = _bd()
    q = array.matmul(relational.select("waves", column="value", lo=-1.0),
                     "waves")
    descs = {p.describe(q) for p in enumerate_plans(q, bd.catalog)}
    assert "select@columnar matmul@dense_array" in descs
    assert "select@columnar matmul@columnar" in descs


def test_estimate_sizes_shape_aware():
    bd = _bd(n=32, t=64)
    q = array.matmul("waves", array.transpose("waves"))
    sizes = estimate_sizes(q, bd.catalog)
    # matmul (32,64) @ (64,32) -> (32,32) floats
    assert sizes[q.uid] == 4.0 * 32 * 32
    assert sizes[q.nodes()[0].uid] == 4.0 * 64 * 32      # transpose


# ---------------------------------------------------------------------------
# calibrated cost ordering vs measured execution
# ---------------------------------------------------------------------------

def test_calibrated_order_matches_measured(cm):
    """Where the structural gap is wide (matmul on MXU layout vs the
    join-aggregate formulation), predicted ordering = measured ordering."""
    bd = _bd(cm, n=48, t=48)
    q = array.matmul("waves", "waves")
    dense = Plan(((0, "dense_array"),))
    col = Plan(((0, "columnar"),))
    pred_d = plan_cost(q, dense, bd.catalog, cm)
    pred_c = plan_cost(q, col, bd.catalog, cm)
    assert pred_d < pred_c

    def measured(p):
        execute_plan(q, p, bd.catalog)                   # warm
        return min(execute_plan(q, p, bd.catalog).seconds for _ in range(3))

    assert measured(dense) < measured(col)


def test_observation_updates_model():
    model = CostModel()
    before = model.op_seconds("dense_array", "matmul", 1e6)
    model.observe_op("dense_array", "matmul", 1e6, 0.5)  # much slower engine
    after = model.op_seconds("dense_array", "matmul", 1e6)
    assert after > before
    model.observe_cast("dense", "coo", 1e6, 0.25)
    assert model.cast_seconds("dense", "coo", 1e6) == pytest.approx(
        0.25, rel=0.1)
    assert model.cast_seconds("dense", "dense", 1e6) == 0.0


def test_cost_model_roundtrip(tmp_path):
    model = CostModel()
    model.observe_op("columnar", "haar", 1e5, 0.01)
    model.observe_cast("dense", "columnar", 1e6, 0.002)
    p = tmp_path / "m.calib.json"
    model.save(str(p))
    m2 = CostModel(str(p))
    assert m2.op_seconds("columnar", "haar", 1e5) == pytest.approx(
        model.op_seconds("columnar", "haar", 1e5))
    assert m2.cast_seconds("dense", "columnar", 1e6) == pytest.approx(
        model.cast_seconds("dense", "columnar", 1e6))


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

def test_plan_cache_hit_skips_enumeration(monkeypatch):
    bd = _bd()
    # disarm online re-planning: wall-clock noise on ~ms queries can exceed
    # the 2x factor by itself and would call the patched dp_plans (the replan
    # policy has its own controlled-value tests in test_adaptive_loop.py)
    bd.replan_factor = float("inf")
    q = _analytic()
    rep1 = bd.execute(q, mode="training")
    assert rep1.sig in bd.plan_cache

    import repro.core.middleware as mw

    def boom(*a, **kw):
        raise AssertionError("production re-enumerated plans")

    monkeypatch.setattr(mw, "dp_plans", boom)
    rep2 = bd.execute(_analytic(), mode="auto")          # rebuilt query
    assert rep2.mode == "production"
    assert rep2.cache_hit
    assert rep2.plan_key == rep1.plan_key


def test_drift_invalidates_plan_cache():
    bd = _bd(n=32, t=32)
    q = array.matmul("waves", "waves")
    rep1 = bd.execute(q, mode="training")
    for stats in bd.monitor.db[rep1.sig].values():
        stats.usage = {"devices": 4096.0, "rss_gb": 999.0, "time": 0.0}
    rep2 = bd.execute(q, mode="production")
    assert rep2.drifted and not rep2.cache_hit           # retrained, recached
    rep3 = bd.execute(q, mode="production")
    assert rep3.cache_hit


def test_query_server_serves_through_cache():
    bd = _bd()
    srv = QueryServer(bd)
    srv.warm([_analytic()])
    for _ in range(3):
        rep = srv.submit(_analytic())
        assert rep.mode == "production"
    assert srv.stats["requests"] == 3
    assert srv.stats["trainings"] == 0       # warm once, never re-train
    # measured re-ranking may legitimately switch the monitor's best plan
    # between submits (one miss per switch, re-cached immediately), but the
    # first post-warm submit always hits
    assert srv.stats["cache_hits"] >= 1


# ---------------------------------------------------------------------------
# concurrent executor
# ---------------------------------------------------------------------------

def test_topo_levels_group_independent_nodes():
    q = _wide()
    lvls = topo_levels(q)
    assert len(lvls) >= 4
    assert len(lvls[0]) == 2                 # the two selects are independent


def test_concurrent_matches_sequential():
    bd = _bd()
    q = _wide()
    plan = enumerate_plans(q, bd.catalog, max_plans=1)[0]
    seq = execute_plan(q, plan, bd.catalog, concurrent=False)
    conc = execute_plan(q, plan, bd.catalog, concurrent=True)
    assert conc.levels >= 4
    np.testing.assert_allclose(np.asarray(seq.value.data),
                               np.asarray(conc.value.data),
                               rtol=1e-5, atol=1e-6)
    assert seq.node_obs and not conc.node_obs            # obs = sequential only


# ---------------------------------------------------------------------------
# monitor satellites: atomic save + cast_bytes running mean
# ---------------------------------------------------------------------------

def test_monitor_save_atomic(tmp_path):
    p = tmp_path / "monitor.json"
    m = Monitor(str(p))
    m.record("sig", "0:dense_array", 0.1, cast_bytes=100.0)
    m.save()
    assert not list(tmp_path.glob("*.tmp"))              # no droppings
    m2 = Monitor(str(p))
    key, stats, _ = m2.best("sig")
    assert key == "0:dense_array" and stats.n == 1


def test_cast_bytes_running_mean():
    st = PlanStats()
    st.record(0.1, {}, cast_bytes=100.0)
    st.record(0.1, {}, cast_bytes=0.0)                   # light rerun
    assert st.cast_bytes == pytest.approx(50.0)          # mean, not overwrite
