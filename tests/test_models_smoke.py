"""Per-architecture smoke tests: reduced config, one forward/train/decode step
on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch, PlanConfig, ShapeConfig
from repro.models import api
from repro.optim import AdamW

SMOKE_PLAN = PlanConfig(param_dtype="float32", compute_dtype="float32",
                        master_dtype="float32", attn_chunk=8, loss_chunk=8,
                        remat="none")
B, S = 2, 16


def smoke_batch(cfg, mode="train"):
    key = jax.random.PRNGKey(0)
    if mode == "decode":
        return {"tokens": jnp.zeros((B,), jnp.int32),
                "pos": jnp.full((B,), 3, jnp.int32)}
    if cfg.family == "vlm":
        Pf = cfg.num_frontend_tokens
        return {"patch_embeds": jax.random.normal(key, (B, Pf, cfg.d_model)),
                "tokens": jax.random.randint(key, (B, S - Pf), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        s_dec = S if mode == "train" else 1
        return {"frames": jax.random.normal(key, (B, S, cfg.d_model)),
                "tokens": jax.random.randint(key, (B, s_dec), 0, cfg.vocab_size)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.fixture(scope="module")
def params_cache():
    return {}


def get_params(cfg, params_cache):
    if cfg.name not in params_cache:
        params_cache[cfg.name] = api.init_params(
            cfg, jax.random.PRNGKey(1), SMOKE_PLAN)
    return params_cache[cfg.name]


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_loss_finite(name, params_cache):
    cfg = get_arch(name).smoke()
    params = get_params(cfg, params_cache)
    loss_fn = api.get_loss_fn(cfg, SMOKE_PLAN)
    loss = jax.jit(loss_fn)(params, smoke_batch(cfg))
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{name}: loss not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step(name, params_cache):
    cfg = get_arch(name).smoke()
    opt = AdamW(learning_rate=1e-3)
    state = api.init_train_state(cfg, SMOKE_PLAN, jax.random.PRNGKey(2), opt)
    step = jax.jit(api.make_train_step(cfg, SMOKE_PLAN, opt))
    batch = smoke_batch(cfg)
    state2, m1 = step(state, batch)
    state3, m2 = step(state2, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]), \
        f"{name}: loss did not go down on repeated batch"
    assert int(state3["step"]) == 2


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill_decode(name, params_cache):
    cfg = get_arch(name).smoke()
    params = get_params(cfg, params_cache)
    shape = ShapeConfig("smoke_decode", "decode", 32, B)
    prefill = api.make_prefill(cfg, shape, SMOKE_PLAN)
    batch = smoke_batch(cfg, mode="prefill")
    logits, cache, pos = jax.jit(prefill)(params, batch)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    decode = api.make_decode_step(cfg, shape, SMOKE_PLAN)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    tok2, cache2 = jax.jit(decode)(params, cache, tok, pos)
    assert tok2.shape == (B,)
    assert tok2.dtype == jnp.int32
    # caches must keep their structure
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_accum_matches_single(name, params_cache):
    """Gradient accumulation must match the single-batch step (property)."""
    cfg = get_arch(name).smoke()
    opt = AdamW(learning_rate=1e-2, clip_norm=0.0)
    key = jax.random.PRNGKey(3)
    state = api.init_train_state(cfg, SMOKE_PLAN, key, opt)
    batch = smoke_batch(cfg)
    s1, m1 = jax.jit(api.make_train_step(cfg, SMOKE_PLAN, opt))(state, batch)
    plan2 = SMOKE_PLAN.with_(accum=2)
    s2, m2 = jax.jit(api.make_train_step(cfg, plan2, opt))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-4)
    l1 = jax.tree.leaves(s1["master"])
    l2 = jax.tree.leaves(s2["master"])
    for a, b in zip(l1, l2):
        # adam's first step ~ sign(g)*lr wherever |g| >> eps; accumulation
        # reorders f32 sums, so allow ~2% of one lr step in absolute terms
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-3, atol=1e-3)


def test_count_params_sane():
    n = api.count_params(get_arch("qwen2-72b"))
    assert 70e9 < n < 82e9, f"qwen2-72b param count {n/1e9:.1f}B out of range"
    n2 = api.count_params(get_arch("grok-1-314b"))
    assert 280e9 < n2 < 340e9, f"grok-1 param count {n2/1e9:.1f}B out of range"
    na = api.count_params(get_arch("grok-1-314b"), active_only=True)
    assert na < n2 * 0.4
