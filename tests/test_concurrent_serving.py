"""Concurrent admission across the serving stack (ISSUE 4).

Covers the tentpole's guarantees under multi-threaded traffic: the
``QueryServer`` admits requests from N threads over mixed cold/warm
signatures with exactly ONE training per signature (per-signature locking),
consistent stats totals, and an uncorrupted plan cache; the ``Monitor``'s
batched record queue loses nothing under a thread hammer; the ``CostModel``
survives concurrent observe/predict; the auto-threading gate is now
predicted-seconds-based with a learned per-host dispatch overhead; and
eager triple-format intermediates stay numpy until a dense consumer needs
the device.
"""
import threading
from collections import Counter

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (BigDAWG, ColumnarTable, CostModel, DenseTensor,
                        Monitor, array, execute_plan, relational)
from repro.core.cast import dense_to_columnar, dense_to_coo
from repro.core.costmodel import _DEFAULT_DISPATCH_OVERHEAD_S
from repro.core.engines import ENGINES
from repro.core.executor import (HOST_TASK_GATE_FACTOR, _task_pred_seconds,
                                 host_pool)
from repro.core.middleware import _plan_from_key
from repro.core.planner import Plan
from repro.runtime import QueryServer


def _bd(tmp_path=None, n=24, t=64, **kw):
    monitor = Monitor(str(tmp_path / "monitor.json")) if tmp_path else None
    bd = BigDAWG(monitor=monitor, train_plans=2, train_repeats=1, **kw)
    rng = np.random.default_rng(0)
    bd.register("waves", DenseTensor(jnp.asarray(
        rng.normal(size=(n, t)).astype(np.float32))), engine="dense_array")
    return bd


# four structurally-distinct query shapes = four distinct signatures
_SHAPES = [
    lambda: array.tfidf(array.haar(
        relational.select("waves", column="value", lo=0.0), levels=2)),
    lambda: array.count(relational.select("waves", column="value", lo=0.5)),
    lambda: array.matmul(array.tfidf("waves"),
                         array.transpose(array.tfidf("waves"))),
    lambda: array.distinct(array.haar("waves", levels=1)),
]


# ---------------------------------------------------------------------------
# (1) the stress test: N threads, mixed cold/warm signatures
# ---------------------------------------------------------------------------

def test_stress_mixed_cold_warm_traffic(tmp_path):
    bd = _bd(tmp_path, explore_budget=0.5)
    bd.replan_factor = float("inf")      # isolate admission from replanning
    srv = QueryServer(bd)
    n_warm = srv.warm([_SHAPES[0](), _SHAPES[1]()])    # 2 warm, 2 cold
    assert n_warm == 2
    warm_sigs = set(bd.plan_cache)

    repeat = 4
    queries = [build() for build in _SHAPES for _ in range(repeat)]
    rng = np.random.default_rng(7)
    order = rng.permutation(len(queries))
    reports = srv.submit_many([queries[i] for i in order], workers=4)

    # every request came back, in submission order
    assert len(reports) == len(queries)
    want_sigs = [bd.monitor and r.sig for r in reports]
    assert all(want_sigs)

    # exactly one training per COLD signature, zero for warm ones
    trainings = Counter(r.sig for r in reports if r.mode == "training")
    all_sigs = {r.sig for r in reports}
    assert len(all_sigs) == len(_SHAPES)
    for sig in all_sigs:
        if sig in warm_sigs:
            assert trainings[sig] == 0
        else:
            assert trainings[sig] == 1
    # stats totals add up
    assert srv.stats["requests"] == len(queries)
    assert srv.stats["trainings"] == sum(trainings.values()) == 2
    assert srv.stats["seconds"] > 0.0
    n_production = sum(1 for r in reports if r.mode == "production")
    assert n_production == len(queries) - 2

    # the plan cache stayed uncorrupted: one entry per signature, every
    # plan/alternate parseable and sized for its query
    bd.drain_explorations()
    n_nodes = {r.sig: len(q.nodes())
               for q, r in zip([queries[i] for i in order], reports)}
    assert set(bd.plan_cache) == all_sigs
    for sig, entry in bd.plan_cache.items():
        assert len(entry.plan.assignment) == n_nodes[sig]
        _plan_from_key(entry.plan.key)               # raises if mangled
        for alt in entry.alternates:
            assert len(alt.assignment) == n_nodes[sig]
    # ... and round-trips through its file
    srv.persist()
    bd2 = _bd(tmp_path)
    assert set(bd2.plan_cache) == all_sigs
    assert {s: e.plan.key for s, e in bd2.plan_cache.items()} == \
        {s: e.plan.key for s, e in bd.plan_cache.items()}
    # monitor settled: nothing pending once everything drained+flushed
    bd.monitor.flush()
    assert bd.monitor.pending_records() == 0


def test_racing_cold_requests_train_once(tmp_path):
    """All threads hit the SAME cold signature at once: per-signature
    locking must collapse the stampede to one training."""
    bd = _bd(tmp_path)
    srv = QueryServer(bd)
    reports = srv.submit_many([_SHAPES[0]() for _ in range(8)], workers=4)
    modes = Counter(r.mode for r in reports)
    assert modes["training"] == 1
    assert modes["production"] == 7
    assert srv.stats["trainings"] == 1
    assert len(bd.plan_cache) == 1


def test_submit_many_preserves_input_order(tmp_path):
    bd = _bd(tmp_path)
    srv = QueryServer(bd)
    qs = [_SHAPES[i % 2]() for i in range(6)]
    want = [len(q.nodes()) for q in qs]
    reports = srv.submit_many(qs, workers=3)
    got = [len(_plan_from_key(r.plan_key).assignment) for r in reports]
    assert got == want


def test_serve_summarizes_throughput(tmp_path):
    bd = _bd(tmp_path)
    srv = QueryServer(bd)
    srv.warm([_SHAPES[1]()])
    out = srv.serve([_SHAPES[1]() for _ in range(4)], workers=2)
    assert len(out["reports"]) == 4
    assert out["rps"] == pytest.approx(4 / out["seconds"], rel=1e-6)
    assert out["workers"] == 2


def test_failing_alternate_is_evicted_from_rotation(monkeypatch):
    """A background trial that raises must not be rescheduled forever: it
    charges no explore_seconds, so only eviction stops the serve path from
    re-spawning a doomed task on every request."""
    import warnings as warnings_mod
    import repro.core.middleware as mw
    bd = _bd(explore_budget=10.0)
    bd.replan_factor = float("inf")
    q = _SHAPES[0]()
    rep = bd.execute(q, mode="training")
    entry = bd.plan_cache[rep.sig]
    assert entry.alternates
    doomed = entry.alternates[entry.next_alt % len(entry.alternates)]
    real = mw.execute_plan

    def flaky(query, plan, *args, **kwargs):
        if plan.key == doomed.key:
            raise RuntimeError("alternate exploded")
        return real(query, plan, *args, **kwargs)

    monkeypatch.setattr(mw, "execute_plan", flaky)
    with warnings_mod.catch_warnings(record=True):
        warnings_mod.simplefilter("always")
        rep2 = bd.execute(q, mode="production")
        assert rep2.explored_key == doomed.key
        bd.drain_explorations()
    # evicted: the doomed alternate left the pool, nothing was credited
    assert doomed.key not in {p.key
                              for p in bd.plan_cache[rep.sig].alternates}
    assert bd.explorations == 0 and bd.explore_seconds == 0.0


# ---------------------------------------------------------------------------
# (2) monitor: batched records survive a thread hammer
# ---------------------------------------------------------------------------

def test_monitor_batched_records_add_up_across_threads():
    m = Monitor(decay=0.0)               # cumulative: n is the ground truth
    threads, per_thread = 8, 50

    def hammer(t):
        for i in range(per_thread):
            m.record("sig", "0:dense_array", 0.01, sizes={0: 64.0})

    ts = [threading.Thread(target=hammer, args=(t,)) for t in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    stats = m.known_plans("sig")["0:dense_array"]    # flushes internally
    assert stats.n == threads * per_thread
    assert m.pending_records() == 0
    assert m.sizes["sig"][0][1] == threads * per_thread


def test_monitor_record_is_deferred_until_flush():
    m = Monitor()
    m.record("sig", "0:dense_array", 0.5)
    assert m.pending_records() == 1
    assert "sig" not in m.db                 # raw dict untouched pre-flush
    key, stats, _ = m.best("sig")            # readers flush implicitly
    assert key == "0:dense_array" and stats.n == 1
    assert m.pending_records() == 0


# ---------------------------------------------------------------------------
# (3) cost model: concurrent observe/predict + learned dispatch overhead
# ---------------------------------------------------------------------------

def test_cost_model_concurrent_observe_and_predict():
    cm = CostModel()
    errors = []

    def obs():
        try:
            for i in range(200):
                cm.observe_op("dense_array", "matmul", 1e5, 1e-3)
                cm.observe_cast("dense", "coo", 1e5, 1e-3)
        except Exception as exc:            # pragma: no cover
            errors.append(exc)

    def pred():
        try:
            for i in range(200):
                assert cm.op_seconds("dense_array", "matmul", 1e5) > 0
                assert cm.cast_seconds("dense", "coo", 1e5) > 0
        except Exception as exc:            # pragma: no cover
            errors.append(exc)

    ts = [threading.Thread(target=f) for f in (obs, obs, pred, pred)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errors
    assert cm.op_rate["dense_array"]["matmul"].n == 400


def test_dispatch_overhead_learned_and_persisted(tmp_path):
    p = tmp_path / "calib.json"
    cm = CostModel(str(p))
    assert cm.dispatch_overhead_s() == _DEFAULT_DISPATCH_OVERHEAD_S
    cm.observe_dispatch(3e-4)
    cm.observe_dispatch(5e-4)
    assert cm.dispatch_overhead_s() == pytest.approx(4e-4)
    cm.save()
    cm2 = CostModel(str(p))
    assert cm2.dispatch_overhead.n == 2
    assert cm2.dispatch_overhead_s() == pytest.approx(4e-4)


def test_auto_gate_measures_dispatch_overhead_on_first_concurrent_run(
        monkeypatch):
    # the gate only runs when the host pool exists; on a 1-core machine the
    # default pool size is 1 and concurrent dispatch stays inline, so pin a
    # multi-worker pool for this test
    monkeypatch.setenv("REPRO_HOST_WORKERS", "4")
    bd = _bd()
    q = array.matmul(array.tfidf(relational.select("waves", column="value",
                                                   lo=0.0)),
                     array.transpose(array.tfidf("waves")))
    plan = Plan(tuple((i, "dense_array") for i in range(len(q.nodes()))))
    execute_plan(q, plan, bd.catalog, concurrent=True,
                 cost_model=bd.cost_model)
    # the gate ran: the model now carries a real measured round trip
    assert bd.cost_model.dispatch_overhead.n >= 1
    assert bd.cost_model.dispatch_overhead_s() > 0.0


def test_task_pred_seconds_scales_with_input_and_casts():
    cm = CostModel()
    bd = _bd(n=64, t=128)
    small = relational.select("waves", column="value", lo=0.0)
    # same op, but the input must first cast dense->columnar: predicted
    # seconds must include the cast onto the columnar data model
    t_dense = _task_pred_seconds(small, "dense_array", bd.catalog, {}, cm)
    t_col = _task_pred_seconds(small, "columnar", bd.catalog, {}, cm)
    assert t_col > t_dense
    # tiny tasks sit below the threading floor; the floor is overhead-based
    floor = HOST_TASK_GATE_FACTOR * cm.dispatch_overhead_s()
    assert floor > 0.0


# ---------------------------------------------------------------------------
# (4) numpy-eager intermediates
# ---------------------------------------------------------------------------

def test_triple_casts_stay_numpy():
    d = DenseTensor(jnp.asarray(np.arange(12, dtype=np.float32)
                                .reshape(3, 4) + 1.0))
    col = dense_to_columnar(d)
    assert all(isinstance(v, np.ndarray) for v in col.columns.values())
    assert isinstance(col.valid, np.ndarray)
    coo = dense_to_coo(d)
    assert isinstance(coo.rows, np.ndarray)
    assert isinstance(coo.vals, np.ndarray)
    assert col.nbytes > 0 and coo.nbytes > 0      # accounting still works


def test_join_output_stays_numpy_and_correct():
    a = ColumnarTable({"i": jnp.asarray([0, 1, 2], jnp.int32),
                       "value": jnp.asarray([1.0, 2.0, 3.0])})
    b = ColumnarTable({"i": jnp.asarray([1, 2, 3], jnp.int32),
                       "value": jnp.asarray([10.0, 20.0, 30.0])})
    j = ENGINES["columnar"].run("join", {"left_on": "i", "right_on": "i"},
                                a, b)
    assert all(isinstance(v, np.ndarray) for v in j.columns.values())
    order = np.argsort(np.asarray(j.columns["l_i"]))
    assert np.asarray(j.columns["l_i"])[order].tolist() == [1, 2]
    np.testing.assert_allclose(np.asarray(j.columns["l_value"])[order],
                               [2.0, 3.0])
    np.testing.assert_allclose(np.asarray(j.columns["r_value"])[order],
                               [10.0, 20.0])


def test_registered_catalog_objects_are_homed_on_device():
    bd = BigDAWG(train_plans=2)
    rng = np.random.default_rng(0)
    # registering a dense object under a columnar home casts it — and the
    # long-lived catalog copy must be device arrays, not the numpy-eager
    # intermediate the cast produced
    bd.register("A", DenseTensor(jnp.asarray(
        rng.normal(size=(8, 8)).astype(np.float32))), engine="columnar")
    obj = bd.catalog["A"].obj
    assert obj.kind == "columnar"
    assert not any(isinstance(v, np.ndarray) for v in obj.columns.values())


def test_numpy_columnar_pipeline_matches_device_pipeline():
    """A full columnar pipeline over a numpy-born table must agree with the
    same pipeline over a device-born table."""
    rng = np.random.default_rng(3)
    raw = rng.normal(size=(8, 16)).astype(np.float32)
    q_np = ColumnarTable({"i": np.repeat(np.arange(8, dtype=np.int32), 16),
                          "j": np.tile(np.arange(16, dtype=np.int32), 8),
                          "value": raw.ravel()})
    q_dev = ColumnarTable({c: jnp.asarray(v) for c, v in q_np.columns.items()})
    eng = ENGINES["columnar"]
    for op, attrs in (("select", {"column": "value", "lo": 0.0}),
                      ("haar", {"levels": 2}),
                      ("count", {}), ("distinct", {"column": "value"})):
        out_np = eng.run(op, attrs, q_np)
        out_dev = eng.run(op, attrs, q_dev)
        if hasattr(out_np, "columns"):
            for c in out_np.columns:
                np.testing.assert_allclose(np.asarray(out_np.columns[c]),
                                           np.asarray(out_dev.columns[c]),
                                           rtol=1e-5)
        else:
            np.testing.assert_allclose(np.asarray(out_np.data),
                                       np.asarray(out_dev.data), rtol=1e-5)


# ---------------------------------------------------------------------------
# (5) concurrent execute_plan sanity (request threads share the host pool)
# ---------------------------------------------------------------------------

def test_execute_plan_from_many_threads_is_consistent():
    bd = _bd()
    q = _SHAPES[0]()
    plan = Plan(tuple((i, "dense_array") for i in range(len(q.nodes()))))
    ref = execute_plan(q, plan, bd.catalog)
    results, errors = [], []

    def run():
        try:
            r = execute_plan(q, plan, bd.catalog, concurrent=True,
                             cost_model=bd.cost_model)
            results.append(r)
        except Exception as exc:            # pragma: no cover
            errors.append(exc)

    ts = [threading.Thread(target=run) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errors and len(results) == 4
    for r in results:
        np.testing.assert_allclose(np.asarray(r.value.data),
                                   np.asarray(ref.value.data),
                                   rtol=1e-5, atol=1e-6)
        assert r.n_casts == ref.n_casts
    host_pool()          # pool survives (smoke: no shutdown mid-flight)
