"""MoE layer properties: chunked dispatch equivalence, capacity semantics,
combine correctness against a dense reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import moe as M


def _setup(seed=0, B=2, S=16):
    cfg = get_arch("deepseek-v2-lite-16b").smoke()
    p = M.init_moe(jax.random.PRNGKey(seed), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (B, S, cfg.d_model), jnp.float32)
    return cfg, p, x


def test_chunked_equals_unchunked():
    # capacity is per-group, so exact equivalence requires no drops
    cfg, p, x = _setup(S=16)
    m = dataclasses.replace(cfg.moe, capacity_factor=100.0)
    cfg = dataclasses.replace(cfg, moe=m)
    y0, a0 = M.moe_apply(p, cfg, x, group_size=0)
    y1, a1 = M.moe_apply(p, cfg, x, group_size=8)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=2e-5,
                               atol=2e-5)


def test_unroll_equals_scan():
    cfg, p, x = _setup(S=16)
    y0, _ = M.moe_apply(p, cfg, x, group_size=8, unroll=False)
    y1, _ = M.moe_apply(p, cfg, x, group_size=8, unroll=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-5,
                               atol=2e-6)


def test_combine_matches_dense_reference():
    """With capacity >= T (no drops), MoE == explicit per-token expert sum."""
    cfg, p, x = _setup(S=8)
    m = dataclasses.replace(cfg.moe, capacity_factor=100.0)  # no drops
    cfg = dataclasses.replace(cfg, moe=m)
    y, _ = M.moe_apply(p, cfg, x)

    # dense reference
    B, S, D = x.shape
    E, K = cfg.moe.num_experts, cfg.moe.top_k
    logits = jnp.einsum("btd,de->bte", x, p["router"])
    probs = jax.nn.softmax(logits, -1)
    topw, topi = jax.lax.top_k(probs, K)
    topw = topw / topw.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for e in range(E):
        h = jnp.einsum("btd,df->btf", x, p["we1"][e])
        g = jnp.einsum("btd,df->btf", x, p["we3"][e])
        ye = jnp.einsum("btf,fd->btd", jax.nn.silu(g) * h, p["we2"][e])
        w = jnp.where(topi == e, topw, 0.0).sum(-1)
        ref = ref + ye * w[..., None]
    if cfg.moe.num_shared_experts:
        from repro.models.layers import mlp_apply
        ref = ref + mlp_apply(p["shared"], x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-3,
                               atol=2e-4)


def test_capacity_drops_overflow():
    """With capacity 1 per expert, most tokens are dropped (output ~ shared
    path only) but nothing crashes and aux stays finite."""
    cfg, p, x = _setup(S=16)
    m = dataclasses.replace(cfg.moe, capacity_factor=1e-9)
    cfg2 = dataclasses.replace(cfg, moe=m)
    y, aux = M.moe_apply(p, cfg2, x)
    assert np.all(np.isfinite(np.asarray(y)))
    assert np.isfinite(float(aux))


def test_decode_path_matches_train_path():
    cfg, p, x = _setup(S=4)
    y_seq, _ = M.moe_apply(p, cfg, x)              # (B,S,D) grouped per seq
    # decode treats the batch as one group; compare against a (B*S)-token
    # "decode" call on the flattened tokens with ample capacity
    m = dataclasses.replace(cfg.moe, capacity_factor=100.0)
    cfg2 = dataclasses.replace(cfg, moe=m)
    y_seq2, _ = M.moe_apply(p, cfg2, x)
    y_dec, _ = M.moe_apply(p, cfg2, x.reshape(-1, x.shape[-1]))
    np.testing.assert_allclose(np.asarray(y_dec),
                               np.asarray(y_seq2).reshape(-1, x.shape[-1]),
                               rtol=2e-3, atol=2e-4)
