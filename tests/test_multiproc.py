"""Multi-process serving battery: the ProcPool master/worker stack, sharded
scatter–gather correctness (property-based), worker-kill fault recovery, and
multi-process persistence contention.

Property tests run ≥200 examples each and execute IN-PROCESS against
``shardplan.run_scatter_gather`` (the sequential reference the pool shares
its ``gather`` with) — spawning a pool per drawn example would test process
startup, not the merge algebra.  The pool itself is exercised by the
module-scoped fixture tests below them, including the same equivalence
checks end-to-end across real worker processes.
"""
import multiprocessing
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from repro.proptest import given, settings, strategies as st

from repro.core import shardplan
from repro.core import tables as T
from repro.core.errors import EngineDown, PlanInfeasible
from repro.core.islands import array, relational, scope
from repro.core.middleware import BigDAWG
from repro.core.monitor import Monitor
from repro.core.planner import (dp_plans, exhaustive_plans,
                                price_scatter_gather)
from repro.core.procpool import ProcPool, _monitor_hammer, worker_channel
from repro.core.tables import COOMatrix, ColumnarTable, DenseTensor
from repro.runtime.fault import WorkerKillInjector
from repro.runtime.server import QueryServer

ENGINE_NAMES = ("dense_array", "columnar", "kv_sparse", "stream")

# bounded shape pools keep the jit cache small across 200+ examples
_NROWS = (5, 8, 12, 16, 24)
_NCOLS = (2, 3, 4)


# ---------------------------------------------------------------------------
# merge primitives (numpy-only master-side algebra)
# ---------------------------------------------------------------------------

def test_shard_bounds_cover_and_spread():
    assert T.shard_bounds(10, 3) == [(0, 4), (4, 7), (7, 10)]
    assert T.shard_bounds(2, 4) == [(0, 1), (1, 2), (2, 2), (2, 2)]
    with pytest.raises(ValueError):
        T.shard_bounds(10, 0)


def test_shard_concat_roundtrip_all_kinds():
    rng = np.random.RandomState(0)
    dense = DenseTensor(rng.rand(11, 3))
    col = ColumnarTable({"key": np.arange(11), "value": rng.rand(11)},
                        valid=(np.arange(11) % 3 != 0))
    coo = COOMatrix(np.array([0, 3, 7, 10]), np.array([1, 0, 2, 1]),
                    np.array([1.0, 2.0, 3.0, 4.0]), (11, 3))
    stream = T.StreamBuffer(rng.rand(11, 4), t0=5)
    for obj in (dense, col, coo, stream):
        parts = T.shard_rows(obj, 3)
        back = T.concat_shards(parts)
        if isinstance(obj, DenseTensor):
            assert np.allclose(np.asarray(back.data), np.asarray(obj.data))
            assert back.valid_count == obj.valid_count
        elif isinstance(obj, ColumnarTable):
            for c in obj.columns:
                assert np.allclose(np.asarray(back.columns[c]),
                                   np.asarray(obj.columns[c]))
            assert np.array_equal(np.asarray(back.valid),
                                  np.asarray(obj.valid))
        elif isinstance(obj, COOMatrix):
            assert back.shape == obj.shape
            assert np.array_equal(np.asarray(back.rows), np.asarray(obj.rows))
            assert np.allclose(np.asarray(back.vals), np.asarray(obj.vals))
        else:
            assert np.allclose(np.asarray(back.data), np.asarray(obj.data))
            assert back.t0 == obj.t0


def test_shard_rows_rejects_padded_dense_and_0d():
    with pytest.raises(ValueError):
        T.shard_rows(DenseTensor(np.ones((6, 2)), valid_count=7), 2)
    with pytest.raises(ValueError):
        T.shard_rows(DenseTensor(np.float64(3.0)), 2)


def test_kmerge_is_a_stable_ordered_merge():
    a = ColumnarTable({"k": np.array([1.0, 3.0, 9.0]),
                       "tag": np.array([10, 11, 12])})
    b = ColumnarTable({"k": np.array([2.0, 3.0, 10.0]),
                       "tag": np.array([20, 21, 22])},
                      valid=np.array([True, True, False]))
    out = T.kmerge_shards([a, b], by="k")
    assert np.allclose(out.columns["k"], [1.0, 2.0, 3.0, 3.0, 9.0])
    # the tied k=3.0 keeps shard order: shard 0's row first (stable)
    assert list(out.columns["tag"]) == [10, 20, 11, 21, 12]


def test_sum_merge_requires_aligned_keys():
    a = ColumnarTable({"key": np.arange(3), "sum": np.ones(3)})
    b = ColumnarTable({"key": np.arange(1, 4), "sum": np.ones(3)})
    with pytest.raises(ValueError):
        T.sum_shards([a, b])


# ---------------------------------------------------------------------------
# scatter–gather pricing
# ---------------------------------------------------------------------------

def _small_catalog_bd():
    rng = np.random.RandomState(7)
    bd = BigDAWG(train_plans=1, train_repeats=1)
    bd.register("A", ColumnarTable({"key": rng.randint(0, 5, 24),
                                    "value": rng.rand(24)}),
                "columnar", shards=2)
    bd.register("M", DenseTensor(rng.rand(24, 3)), "dense_array", shards=2)
    bd.register("W", DenseTensor(rng.rand(3, 4)), "dense_array")
    return bd


def test_price_scatter_gather_shape_and_scaling():
    bd = _small_catalog_bd()
    q = array.matmul("M", "W")
    sg = shardplan.analyze_catalog(q, bd.sharded)
    assert sg is not None
    p1 = price_scatter_gather(q, sg.fragment(0), catalog=bd.catalog,
                              n_shards=2, workers=1)
    p4 = price_scatter_gather(q, sg.fragment(0), catalog=bd.catalog,
                              n_shards=2, workers=4)
    assert p1.unsharded_s > 0 and p1.fragment_s > 0
    # more workers -> fewer sequential rounds -> never slower
    assert p4.sharded_s <= p1.sharded_s
    assert p1.worthwhile == (p1.sharded_s < p1.unsharded_s)


# ---------------------------------------------------------------------------
# shardability analysis (conservative fallbacks)
# ---------------------------------------------------------------------------

def test_analyze_rejects_non_decomposable_shapes():
    bd = _small_catalog_bd()
    infos = bd.sharded
    # global ops are not row-decomposable
    assert shardplan.analyze_catalog(relational.distinct("A"), infos) is None
    # sharded table on a replicated slot (join RIGHT side)
    q = relational.join("A2", "A", left_on="key", right_on="key")
    assert shardplan.analyze_catalog(q, infos) is None
    # island boundary inside the sharded lineage
    q = array.count(scope("array", relational.select(
        "A", column="value", lo=0.0)))
    assert shardplan.analyze_catalog(q, infos) is None
    # aggregate below the root
    q = relational.sort(relational.sort("A", by="value"), by="key")
    assert shardplan.analyze_catalog(q, infos) is None
    # no sharded leaves at all
    assert shardplan.analyze_catalog(array.count("W"), infos) is None


def test_analyze_accepts_the_decomposable_families():
    bd = _small_catalog_bd()
    infos = bd.sharded
    cases = [
        (array.matmul("M", "W"), "concat", True),
        (array.count("M"), "sum", False),
        (relational.sort("A", by="value"), "kmerge", False),
        (relational.groupby_sum("A", key="key", value="value",
                                num_groups=5), "sum", False),
    ]
    for q, merge, wrapped in cases:
        sg = shardplan.analyze_catalog(q, infos)
        assert sg is not None and sg.merge == merge
        assert sg.wrap_scope == wrapped
        frag = sg.fragment(0)
        names = {r.name for r in frag.refs()}
        assert any(n.endswith("#0") for n in names)


# ---------------------------------------------------------------------------
# PROPERTY 1: sharded scatter–gather == unsharded execution
# ---------------------------------------------------------------------------

_FAMILIES = ("matmul", "count", "scale", "add", "sort", "groupby",
             "join", "select_sort", "project")


def _assert_containers_equal(a, b):
    assert type(a) is type(b)
    if isinstance(a, DenseTensor):
        assert np.asarray(a.data).shape == np.asarray(b.data).shape
        assert np.allclose(np.asarray(a.data), np.asarray(b.data))
        assert a.valid_count == b.valid_count
    elif isinstance(a, ColumnarTable):
        assert set(a.columns) == set(b.columns)
        av, bv = np.asarray(a.valid), np.asarray(b.valid)
        assert np.array_equal(av, bv)
        for c in a.columns:
            assert np.allclose(np.asarray(a.columns[c])[av],
                               np.asarray(b.columns[c])[bv])
    else:
        raise AssertionError(f"unexpected container {type(a).__name__}")


def _run_scatter_case(family, n, k, shards, seed):
    rng = np.random.RandomState(seed)
    bd = BigDAWG(train_plans=1, train_repeats=1)
    if family in ("matmul", "count", "scale", "add"):
        M = DenseTensor(rng.rand(n, k))
        bd.register("M", M, "dense_array", shards=shards)
        if family == "matmul":
            bd.register("W", DenseTensor(rng.rand(k, 3)), "dense_array")
            q = array.matmul("M", "W")
        elif family == "count":
            q = array.count("M")
        elif family == "scale":
            q = array.scale("M", factor=2.5)
        else:
            bd.register("M2", DenseTensor(rng.rand(n, k)), "dense_array",
                        shards=shards)
            q = array.add("M", "M2")
    else:
        A = ColumnarTable({"key": rng.randint(0, 4, n).astype(np.int32),
                           "value": rng.rand(n)})
        bd.register("A", A, "columnar", shards=shards)
        if family == "sort":
            q = relational.sort("A", by="value")
        elif family == "groupby":
            q = relational.groupby_sum("A", key="key", value="value",
                                       num_groups=4)
        elif family == "join":
            B = ColumnarTable({"key": np.arange(4, dtype=np.int32),
                               "w": rng.rand(4)})
            bd.register("B", B, "columnar")
            q = relational.join("A", "B", left_on="key", right_on="key")
        elif family == "select_sort":
            q = relational.sort(
                relational.select("A", column="value", lo=0.3), by="value")
        else:
            q = relational.project("A", columns=["value"])

    sg = shardplan.analyze_catalog(q, bd.sharded)
    assert sg is not None and sg.n_shards == shards
    full = bd.execute(q, mode="training").result
    merged = shardplan.run_scatter_gather(
        sg, lambda i, frag: bd.execute(frag, mode="training").result)
    if family == "count":
        assert int(np.asarray(merged.data)) == int(np.asarray(full.data))
    elif family == "groupby":
        assert np.array_equal(np.asarray(merged.columns["key"]),
                              np.asarray(full.columns["key"]))
        assert np.allclose(np.asarray(merged.columns["sum"]),
                           np.asarray(full.columns["sum"]))
    else:
        _assert_containers_equal(T.host_copy(full), T.host_copy(merged))


@settings(max_examples=200, deadline=None)
@given(st.sampled_from(_FAMILIES), st.sampled_from(_NROWS),
       st.sampled_from(_NCOLS), st.integers(1, 4),
       st.integers(0, 2 ** 31 - 1))
def test_scatter_gather_equals_unsharded(family, n, k, shards, seed):
    _run_scatter_case(family, n, k, shards, seed)


# ---------------------------------------------------------------------------
# PROPERTY 2: masked k=1 DP == exhaustive enumeration (shard placements too)
# ---------------------------------------------------------------------------

def _mask_pool():
    """Every proper subset of the engine set (the full set is trivially
    infeasible everywhere and tests nothing)."""
    masks = []
    for bits in range(2 ** len(ENGINE_NAMES) - 1):
        masks.append(frozenset(e for i, e in enumerate(ENGINE_NAMES)
                               if bits & (1 << i)))
    return masks


_MASKS = _mask_pool()


def _query_pool(bd):
    """Queries over the sharded catalog, including shard FRAGMENTS — the
    placement-constrained form the pool plans per worker."""
    qs = [
        array.matmul("M", "W"),
        array.count("M"),
        relational.sort("A", by="value"),
        relational.groupby_sum("A", key="key", value="value", num_groups=5),
        relational.select("A", column="value", lo=0.2),
        array.count(scope("array",
                          relational.select("A", column="value", lo=0.0))),
    ]
    for q in (array.matmul("M", "W"), relational.sort("A", by="value")):
        sg = shardplan.analyze_catalog(q, bd.sharded)
        assert sg is not None
        qs.extend(sg.fragment(i) for i in range(sg.n_shards))
    return qs


_DP_BD = _small_catalog_bd()
_DP_QUERIES = _query_pool(_DP_BD)


@settings(max_examples=200, deadline=None)
@given(st.integers(0, len(_DP_QUERIES) - 1),
       st.integers(0, len(_MASKS) - 1))
def test_masked_k1_dp_matches_exhaustive(qi, mi):
    q, mask = _DP_QUERIES[qi], _MASKS[mi]
    try:
        dp = dp_plans(q, _DP_BD.catalog, max_plans=1, mask=mask)
    except PlanInfeasible:
        dp = None
    try:
        ex = exhaustive_plans(q, _DP_BD.catalog, mask=mask)
    except PlanInfeasible:
        ex = None
    assert (dp is None) == (ex is None)
    if dp is not None:
        assert dp[0][0] == pytest.approx(ex[0][0], rel=1e-9, abs=1e-12)
        for _, plan in [dp[0]]:
            for _pos, eng in plan.assignment:
                assert eng not in mask


# ---------------------------------------------------------------------------
# monitor / plan-cache shared persistence (in-process protocol checks)
# ---------------------------------------------------------------------------

def test_monitor_merge_save_preserves_other_writers(tmp_path):
    path = str(tmp_path / "monitor.json")
    usage = {"cpu": 0.1, "mem_frac": 0.1}
    m1 = Monitor(path, shared=True)
    m1.record("sig-one", "0:columnar", 0.01, usage=usage)
    m1.save()
    m2 = Monitor(path, shared=True)
    m2.record("sig-two", "0:dense_array", 0.02, usage=usage)
    m2.save()                    # must carry sig-one through
    # m1 polls: adopts m2's signature (non-local) without losing its own
    assert m1.reload_if_changed() is True
    assert "sig-two" in m1.db and "sig-one" in m1.db
    m1.record("sig-one", "0:columnar", 0.03, usage=usage)
    m1.save()                    # must carry sig-two through
    fresh = Monitor(path)
    assert set(fresh.db) == {"sig-one", "sig-two"}
    assert fresh.db["sig-one"]["0:columnar"].n == 2


def test_plan_cache_merge_save_preserves_other_writers(tmp_path):
    state = str(tmp_path / "monitor.json")
    rng = np.random.RandomState(3)
    A = ColumnarTable({"key": rng.randint(0, 3, 12), "value": rng.rand(12)})
    M = DenseTensor(rng.rand(12, 2))
    W = DenseTensor(rng.rand(2, 2))

    bd1 = BigDAWG(monitor=Monitor(state, shared=True), train_plans=1,
                  train_repeats=1)
    bd1.register("A", A, "columnar")
    bd1.execute(relational.sort("A", by="value"), mode="training")
    bd1.monitor.save()
    bd1.save_plan_cache()

    bd2 = BigDAWG(monitor=Monitor(state, shared=True), train_plans=1,
                  train_repeats=1)
    bd2.register("M", M, "dense_array")
    bd2.register("W", W, "dense_array")
    bd2.execute(array.matmul("M", "W"), mode="training")
    bd2.monitor.save()
    bd2.save_plan_cache()        # bd1's signature must survive

    bd3 = BigDAWG(monitor=Monitor(state), train_plans=1, train_repeats=1)
    assert len(bd3.plan_cache) == 2
    assert all(cp.restored for cp in bd3.plan_cache.values())
    # bd1 adopts bd2's entry on poll without losing its own
    assert bd1.reload_shared() is True
    assert len(bd1.plan_cache) == 2


def test_multiprocess_persistence_contention(tmp_path):
    """N real processes hammer one monitor DB through atomic merge-saves and
    versioned reloads: every private signature survives, the contended one
    resolves last-writer-wins, and the final file parses clean (no torn
    reads, no malformed entries)."""
    path = str(tmp_path / "contended.json")
    ctx = multiprocessing.get_context("spawn")
    n_procs, rounds = 3, 6
    procs = [ctx.Process(target=_monitor_hammer,
                         args=(path, f"private-{i}", "shared-sig", rounds, i))
             for i in range(n_procs)]
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=120)
        assert p.exitcode == 0
    final = Monitor(path)        # auto-loads; a torn file would raise here
    for i in range(n_procs):
        sig = f"private-{i}"
        assert sig in final.db, f"dropped private signature {sig}"
        stats = final.db[sig][f"0:plan{i}"]
        # per-signature last-writer-wins: a sibling's save that read the
        # file just before this process's final round may carry a stale
        # copy of this section, so n can trail rounds — but never exceed
        # it, never vanish, and never mix in another writer's plan keys
        assert 1 <= stats.n <= rounds
        assert set(final.db[sig]) == {f"0:plan{i}"}
    assert "shared-sig" in final.db
    winners = set(final.db["shared-sig"])
    assert winners and winners <= {f"0:writer{i}" for i in range(n_procs)}


# ---------------------------------------------------------------------------
# the pool itself (module-scoped: spawn cost paid once)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def pool_state(tmp_path_factory):
    return str(tmp_path_factory.mktemp("mpstate") / "monitor.json")


@pytest.fixture(scope="module")
def pool_data():
    rng = np.random.RandomState(11)
    return {
        "A": ColumnarTable({"key": rng.randint(0, 5, 40).astype(np.int32),
                            "value": rng.rand(40)}),
        "M": DenseTensor(rng.rand(40, 3)),
        "W": DenseTensor(rng.rand(3, 4)),
    }


def _register_all(target, data):
    target.register("A", data["A"], "columnar", shards=2)
    target.register("M", data["M"], "dense_array", shards=2)
    target.register("W", data["W"], "dense_array")


@pytest.fixture(scope="module")
def pool(pool_state, pool_data):
    p = ProcPool(2, state_path=pool_state, train_plans=2,
                 scatter="always", request_timeout_s=120.0)
    _register_all(p, pool_data)
    yield p
    p.close()


@pytest.fixture(scope="module")
def oracle(pool_data):
    bd = BigDAWG(train_plans=2)
    _register_all(bd, pool_data)
    return bd


_POOL_QUERIES = [
    ("count", lambda: array.count("M")),
    ("matmul", lambda: array.matmul("M", "W")),
    ("sort", lambda: relational.sort("A", by="value")),
    ("groupby", lambda: relational.groupby_sum("A", key="key",
                                               value="value", num_groups=5)),
]


def test_pool_scatter_matches_oracle(pool, oracle):
    for name, build in _POOL_QUERIES:
        q = build()
        rep = pool.execute(q, mode="training")
        ref = oracle.execute(q, mode="training")
        assert rep.shards == 2, name
        got, want = T.host_copy(rep.result), T.host_copy(ref.result)
        if isinstance(want, DenseTensor):
            assert np.allclose(np.asarray(got.data),
                               np.asarray(want.data)), name
        else:
            for c in want.columns:
                assert np.allclose(np.asarray(got.columns[c]),
                                   np.asarray(want.columns[c])), (name, c)
    assert pool.scatter_serves >= len(_POOL_QUERIES)


def test_pool_serves_warm_after_training(pool):
    rep = pool.execute(array.matmul("M", "W"))
    assert rep.mode == "production"
    assert rep.shards == 2
    assert rep.cache_hit


def test_pool_persist_and_warm_restart(pool, pool_state, pool_data):
    pool.persist()
    restarted = ProcPool(1, state_path=pool_state, train_plans=2,
                         scatter="always")
    try:
        _register_all(restarted, pool_data)
        rep = restarted.execute(array.matmul("M", "W"))
        assert rep.mode == "production"    # warm from the shared files
        assert rep.shards == 2
    finally:
        restarted.close()


def test_queryserver_over_pool_admission(pool):
    srv = QueryServer(pool)
    reports = srv.submit_many([array.matmul("M", "W") for _ in range(6)],
                              workers=3)
    assert len(reports) == 6
    assert srv.stats["requests"] == 6
    assert srv.stats["shed"] == 0
    assert all(r.shards == 2 for r in reports)


def test_unsharded_query_round_robins(pool):
    # a query with no sharded leaves takes the ordinary single-worker path
    rep = pool.execute(array.count("W"), mode="training")
    assert rep.shards == 0
    assert int(np.asarray(rep.result.data)) == 12


# ---------------------------------------------------------------------------
# worker-kill fault battery
# ---------------------------------------------------------------------------

def test_worker_kill_respawn_retry_and_clean_error(pool_data):
    """SIGKILL a worker mid-request: the master must detect the death via
    the breaker channel, respawn with the registration log replayed, and
    either retry transparently (retries>=1) or surface a clean EngineDown
    (retries=0) — zero hung requests, zero lost requests."""
    inj = WorkerKillInjector(kill_on_dispatch=2)
    p = ProcPool(2, train_plans=2, retries=1, kill_injector=inj,
                 request_timeout_s=120.0)
    try:
        p.register("M", pool_data["M"], "dense_array")
        p.register("W", pool_data["W"], "dense_array")
        q = array.matmul("M", "W")
        ref = p.execute(q, mode="training")        # dispatch 1: survives
        rep = p.execute(q, mode="training")        # dispatch 2: kill lands
        assert inj.kills == 1
        assert p.respawns >= 1
        assert p.breaker_trips >= 1                # death hit the breaker
        assert np.allclose(np.asarray(rep.result.data),
                           np.asarray(ref.result.data))
        # the respawned worker keeps serving (registration replay worked)
        for _ in range(2):
            again = p.execute(q)
            assert np.allclose(np.asarray(again.result.data),
                               np.asarray(ref.result.data))
        assert all(pid is not None for pid in p.ping())
    finally:
        p.close()

    inj0 = WorkerKillInjector(kill_on_dispatch=1)
    p0 = ProcPool(1, train_plans=2, retries=0, kill_injector=inj0,
                  request_timeout_s=120.0)
    try:
        p0.register("M", pool_data["M"], "dense_array")
        p0.register("W", pool_data["W"], "dense_array")
        with pytest.raises(EngineDown) as exc:
            p0.execute(q, mode="training")
        assert worker_channel(0) in str(exc.value)
        assert p0.respawns == 1
        rep = p0.execute(q, mode="training")       # next request serves fine
        assert np.asarray(rep.result.data).shape == (40, 4)
    finally:
        p0.close()


# ---------------------------------------------------------------------------
# API surface: connect(processes=) / QueryServer(processes=)
# ---------------------------------------------------------------------------

def test_connect_with_processes_session(tmp_path, pool_data):
    from repro.core.api import connect
    state = str(tmp_path / "session.json")
    with connect(state, processes=2, train_plans=2,
                 scatter="always") as s:
        s.register("A", pool_data["A"], "columnar", shards=2)
        s.register("M", pool_data["M"], "dense_array", shards=2)
        s.register("W", pool_data["W"], "dense_array")
        res = s.execute(array.matmul("M", "W"), mode="training")
        assert res.value.data.shape == (40, 4)
        assert res.report.shards == 2
        assert res.provenance == ()        # fragment plans: no per-node map
        res2 = s.execute(relational.sort("A", by="value"), mode="training")
        assert np.all(np.diff(np.asarray(
            res2.value.columns["value"])) >= 0)
        s.persist()
    # context-manager exit closed the pool
    with pytest.raises(RuntimeError):
        s.bigdawg.execute(array.count("M"))


def test_queryserver_processes_kwarg(pool_data):
    bd = BigDAWG(train_plans=2)
    bd.register("M", pool_data["M"], "dense_array")
    bd.register("W", pool_data["W"], "dense_array")
    srv = QueryServer(bd, processes=2)
    try:
        assert isinstance(srv.bd, ProcPool)        # lifted via from_bigdawg
        q = array.matmul("M", "W")
        srv.warm([q])
        rep = srv.submit(q)
        assert np.asarray(rep.result.data).shape == (40, 4)
        assert srv.stats["requests"] == 1
    finally:
        srv.close()
