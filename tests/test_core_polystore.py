"""Core polystore middleware tests: islands, shims, casts, signatures,
planner, monitor phases, executor correctness — incl. hypothesis properties."""
import numpy as np
import jax.numpy as jnp
import pytest

# property tests prefer real hypothesis (in requirements.txt; CI installs
# it); a bare environment falls back to the vendored shim with the same
# decorator surface — the properties RUN either way, never skip
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from repro.proptest import given, settings, strategies as st

from repro.core import (BigDAWG, COOMatrix, ColumnarTable, DenseTensor,
                        ENGINES, Monitor, array, relational, text,
                        enumerate_plans, execute_plan, signature,
                        signature_text, degenerate)
from repro.core import cast as castmod
from repro.core.shims import validate, shim_table
from repro.core.monitor import usage_drift


# ---------------------------------------------------------------------------
# shims / islands
# ---------------------------------------------------------------------------

def test_every_island_op_has_a_shim():
    validate()
    tbl = shim_table()
    assert ("array", "matmul", "dense_array") in tbl
    assert ("relational", "count", "columnar") in tbl


def test_degenerate_island_full_engine_power():
    isl = degenerate("kv_sparse")
    assert set(isl.ops) == set(ENGINES["kv_sparse"].ops)
    for op, engines in isl.ops.items():
        assert engines == ("kv_sparse",)


# ---------------------------------------------------------------------------
# casts (hypothesis round-trips)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(2, 10), st.integers(2, 10), st.integers(0, 2 ** 31 - 1))
def test_cast_dense_columnar_roundtrip(n, t, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(n, t)).astype(np.float32)
    d = DenseTensor(jnp.asarray(a))
    back = castmod.cast(castmod.cast(d, "columnar"), "dense")
    np.testing.assert_allclose(np.asarray(back.data), a, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 12), st.integers(2, 12), st.integers(0, 2 ** 31 - 1))
def test_cast_dense_coo_roundtrip(n, t, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(n, t)).astype(np.float32)
    a[rng.random((n, t)) < 0.5] = 0.0          # sparse-ish
    d = DenseTensor(jnp.asarray(a))
    back = castmod.cast(castmod.cast(d, "coo"), "dense")
    np.testing.assert_allclose(np.asarray(back.data), a, rtol=1e-6)


def test_two_hop_cast_through_dense():
    m = COOMatrix(jnp.asarray([0, 1]), jnp.asarray([1, 0]),
                  jnp.asarray([2.0, 3.0]), (2, 2))
    t = castmod.cast(m, "columnar")     # direct
    s = castmod.cast(castmod.cast(m, "dense"), "columnar")
    assert t.kind == s.kind == "columnar"


# ---------------------------------------------------------------------------
# engines agree on logical answers (the polystore invariant)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(4, 40), st.integers(0, 2 ** 31 - 1))
def test_count_agrees_across_engines(n, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(1, 5, size=(n,)).astype(np.float32)   # no zeros
    d = DenseTensor(jnp.asarray(a))
    col = castmod.cast(d, "columnar")
    c_dense = int(ENGINES["dense_array"].run("count", {}, d).data)
    c_col = int(ENGINES["columnar"].run("count", {}, col).data)
    assert c_dense == c_col == n


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 60), st.integers(2, 8), st.integers(0, 2 ** 31 - 1))
def test_distinct_agrees_across_engines(n, k, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(1, k + 1, size=(n,)).astype(np.float32)
    d = DenseTensor(jnp.asarray(a))
    col = castmod.cast(d, "columnar")
    want = len(np.unique(a))
    assert int(ENGINES["dense_array"].run("distinct", {}, d).data) == want
    assert int(ENGINES["columnar"].run("distinct", {}, col).data) == want


def test_matmul_agrees_dense_vs_columnar():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(8, 6)).astype(np.float32)
    b = rng.normal(size=(6, 5)).astype(np.float32)
    da, db = DenseTensor(jnp.asarray(a)), DenseTensor(jnp.asarray(b))
    out_d = ENGINES["dense_array"].run("matmul", {}, da, db)
    ca, cb = castmod.cast(da, "columnar"), castmod.cast(db, "columnar")
    out_c = ENGINES["columnar"].run("matmul", {}, ca, cb)
    dense_c = castmod.cast(out_c, "dense")
    np.testing.assert_allclose(np.asarray(dense_c.data), a @ b, rtol=1e-4,
                               atol=1e-4)


def test_tfidf_agrees_dense_vs_kv():
    rng = np.random.default_rng(1)
    tf = (rng.random((6, 10)) < 0.4) * rng.integers(1, 4, (6, 10))
    tf = tf.astype(np.float32)
    d = DenseTensor(jnp.asarray(tf))
    coo = castmod.cast(d, "coo")
    out_d = np.asarray(ENGINES["dense_array"].run("tfidf", {}, d).data)
    out_kv = np.asarray(castmod.cast(
        ENGINES["kv_sparse"].run("tfidf", {}, coo), "dense").data)
    np.testing.assert_allclose(out_d, out_kv, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# signatures
# ---------------------------------------------------------------------------

def test_signature_stable_across_rebuilds():
    q1 = array.matmul(relational.select("A", column="value", lo=0.5), "B")
    q2 = array.matmul(relational.select("A", column="value", lo=0.5), "B")
    assert signature(q1) == signature(q2)


def test_signature_bins_constants():
    # nearly identical constants share a signature (paper: constants binned)
    a = array.scale(array.matmul("A", "B"), factor=1000.0)
    b = array.scale(array.matmul("A", "B"), factor=1040.0)
    c = array.scale(array.matmul("A", "B"), factor=2000.0)
    assert signature(a) == signature(b)
    assert signature(a) != signature(c)


def test_signature_sensitive_to_structure_and_objects():
    q1 = array.matmul("A", "B")
    q2 = array.matmul("B", "A")
    q3 = array.count("A")
    assert len({signature(q1), signature(q2), signature(q3)}) == 3


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------

def _small_bd():
    bd = BigDAWG()
    rng = np.random.default_rng(0)
    bd.register("A", DenseTensor(jnp.asarray(
        rng.normal(size=(16, 16)).astype(np.float32))), engine="dense_array")
    bd.register("B", DenseTensor(jnp.asarray(
        rng.normal(size=(16, 8)).astype(np.float32))), engine="dense_array")
    return bd


def test_planner_enumerates_hybrid_plans():
    bd = _small_bd()
    q = array.matmul(relational.select("A", column="value", lo=-1.0), "B")
    plans = enumerate_plans(q, bd.catalog)
    descs = {p.describe(q) for p in plans}
    assert "select@columnar matmul@dense_array" in descs
    assert "select@columnar matmul@columnar" in descs


def test_plan_keys_apply_to_rebuilt_queries():
    bd = _small_bd()
    mk = lambda: array.matmul(relational.select("A", column="value", lo=-1.0), "B")
    plans = enumerate_plans(mk(), bd.catalog)
    # a plan enumerated from one instance must execute a fresh instance
    res = execute_plan(mk(), plans[0], bd.catalog)
    assert res.value.data.shape == (16, 8)


# ---------------------------------------------------------------------------
# monitor: training/production phases + drift
# ---------------------------------------------------------------------------

def test_training_then_production(tmp_path):
    bd = _small_bd()
    q = array.matmul(relational.select("A", column="value", lo=-0.5), "B")
    rep1 = bd.execute(q, mode="training")
    assert rep1.mode == "training" and rep1.plans_tried >= 2
    rep2 = bd.execute(q, mode="auto")
    assert rep2.mode == "production"
    assert rep2.plan_key == rep1.plan_key
    # persistence round-trip
    p = tmp_path / "monitor.json"
    bd.monitor.save(str(p))
    m2 = Monitor(str(p))
    key, stats, _ = m2.best(rep1.sig)
    assert key == rep1.plan_key and stats.n >= 1


def test_production_falls_back_to_training_on_unknown_signature():
    bd = _small_bd()
    q = array.count("A")
    rep = bd.execute(q, mode="production")
    assert rep.mode == "training"          # signature miss -> train (paper)


def test_drift_triggers_retraining():
    bd = _small_bd()
    q = array.matmul("A", "B")
    rep1 = bd.execute(q, mode="training")
    # corrupt the recorded usage to look like a very different system
    for stats in bd.monitor.db[rep1.sig].values():
        stats.usage = {"devices": 4096.0, "rss_gb": 10 * stats.usage.get(
            "rss_gb", 1.0) + 100.0, "time": 0.0}
    rep2 = bd.execute(q, mode="production")
    assert rep2.drifted
    assert bd.monitor.background_queue     # losers queued for re-exploration


def test_background_queue_execution():
    bd = _small_bd()
    q = array.matmul("A", "B")
    rep = bd.execute(q, mode="training")
    for stats in bd.monitor.db[rep.sig].values():
        stats.usage = {"devices": 4096.0, "rss_gb": 999.0, "time": 0.0}
    bd.execute(q, mode="production")
    n = bd.run_background_queue({rep.sig: q})
    assert n >= 1


def test_usage_drift_metric():
    assert usage_drift({"devices": 1, "rss_gb": 1}, {"devices": 1, "rss_gb": 1}) == 0
    assert usage_drift({"devices": 1, "rss_gb": 1}, {"devices": 2, "rss_gb": 1}) >= 0.5


# ---------------------------------------------------------------------------
# executor correctness vs direct jnp
# ---------------------------------------------------------------------------

def test_executor_matches_numpy_reference():
    bd = _small_bd()
    q = array.matmul(relational.select("A", column="value", lo=-0.25, hi=0.75),
                     "B")
    rep = bd.execute(q, mode="training")
    A = np.asarray(bd.catalog["A"].obj.data)
    B = np.asarray(bd.catalog["B"].obj.data)
    sel = np.where((A >= -0.25) & (A <= 0.75), A, 0.0)
    np.testing.assert_allclose(np.asarray(rep.result.data), sel @ B,
                               rtol=1e-4, atol=1e-4)


def test_executor_counts_cast_bytes():
    bd = _small_bd()
    q = array.matmul(relational.select("A", column="value", lo=-1.0), "B")
    plans = enumerate_plans(q, bd.catalog)
    hybrid = next(p for p in plans
                  if p.describe(q) == "select@columnar matmul@dense_array")
    res = execute_plan(q, hybrid, bd.catalog)
    assert res.cast_bytes > 0 and res.n_casts >= 2
