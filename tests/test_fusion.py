"""Plan-level kernel fusion (core/fuseplan.py): the differential property
battery ISSUE 8 demands.

The contract under test: fusing a cached plan's same-engine chains into
single jitted callables must be *unobservable* except in speed — identical
values, shapes, valid counts and island roll-ups across every fusable op
family, chain length and input data model; segmentation must never cross an
engine or island (scope) boundary; a fused segment that fails to
trace/compile falls back to node-by-node execution (sticky per signature)
without changing results; and the monitor/drift loop keeps working on
pro-rata attributed timings.
"""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from repro.proptest import given, settings, strategies as st

from repro.core import fuseplan
from repro.core.executor import execute_plan
from repro.core.fuseplan import (FUSABLE_ENGINES, FUSABLE_OPS, fuse_plan,
                                 query_fingerprint)
from repro.core.islands import array, relational, scope
from repro.core.middleware import BigDAWG
from repro.core.ops import SCOPE_OP, PolyOp, Ref
from repro.core.planner import Plan
from repro.core.tables import DenseTensor
from repro.runtime.fault import FusionFaultInjector
from repro.runtime.server import QueryServer

N, T = 8, 16          # base shape; transpose flips it to (16, 8)


@pytest.fixture(autouse=True)
def _fresh_fusion_registry():
    """The compiled-callable cache is process-wide and the broken-key marks
    are sticky by design — isolate every test from its neighbors."""
    fuseplan.reset_cache()
    yield
    fuseplan.reset_cache()


def _middleware(**kw):
    rng = np.random.default_rng(7)
    bd = BigDAWG(train_plans=2, train_repeats=1, **kw)

    def dense(shape):
        return DenseTensor(jnp.asarray(
            rng.normal(size=shape).astype(np.float32)))

    bd.register("Xd", dense((N, T)), "dense_array")
    bd.register("Xc", dense((N, T)), "columnar")
    bd.register("Xs", dense((N, T)), "kv_sparse")
    bd.register("W16", dense((16, 16)), "dense_array")
    bd.register("W8", dense((8, 8)), "dense_array")
    bd.register("B816", dense((8, 16)), "dense_array")
    bd.register("B168", dense((16, 8)), "dense_array")
    bd.register("Q16", dense((4, 16)), "dense_array")
    bd.register("Q8", dense((4, 8)), "dense_array")
    return bd


# ---------------------------------------------------------------------------
# the 200-example differential property: fused == unfused
# ---------------------------------------------------------------------------

@st.composite
def chain_specs(draw):
    """One random fusable chain: the input's home data model plus 1-5 ops,
    each drawn from whatever is shape-legal at that point.  Attr values are
    binned to small sets so the 200 examples revisit compiled segment
    signatures instead of paying 400 fresh traces."""
    n_ops = draw(st.integers(min_value=1, max_value=5))
    src = draw(st.sampled_from(["Xd", "Xc", "Xs"]))
    shape = (N, T)
    ops = []
    for i in range(n_ops):
        choices = ["select", "scale", "tfidf", "add", "matmul", "transpose",
                   "haar"]          # both shapes keep cols % 4 == 0
        if i == n_ops - 1:
            choices.append("knn")   # int indices: terminal only
            choices.append("count")  # 0-d scalar: terminal only
        op = draw(st.sampled_from(choices))
        if op == "select":
            ops.append(("select",
                        {"lo": draw(st.sampled_from([-0.5, 0.0, 0.5]))}))
        elif op == "scale":
            ops.append(("scale",
                        {"factor": draw(st.sampled_from([0.5, 2.0]))}))
        elif op == "tfidf":
            ops.append(("tfidf", {}))
        elif op == "haar":
            ops.append(("haar",
                        {"levels": draw(st.sampled_from([1, 2]))}))
        elif op == "transpose":
            ops.append(("transpose", {}))
            shape = (shape[1], shape[0])
        elif op == "add":
            ops.append(("add",
                        {"other": "B816" if shape == (N, T) else "B168"}))
        elif op == "matmul":
            ops.append(("matmul",
                        {"other": "W16" if shape[1] == 16 else "W8"}))
        elif op == "knn":
            ops.append(("knn",
                        {"other": "Q16" if shape[1] == 16 else "Q8",
                         "k": 3}))
            break
        elif op == "count":
            ops.append(("count", {}))
            break
    return src, tuple(ops)


def _build_query(src, ops):
    node = Ref(src)
    for op, a in ops:
        if "other" in a:
            attrs = {k: v for k, v in a.items() if k != "other"}
            node = array._build(op, node, Ref(a["other"]), **attrs)
        else:
            node = array._build(op, node, **a)
    return node


_BD = None


def _shared_bd():
    global _BD
    if _BD is None:
        _BD = _middleware()
    return _BD


@settings(max_examples=200, deadline=None)
@given(chain_specs())
def test_fused_equals_unfused(spec):
    src, ops = spec
    bd = _shared_bd()
    query = _build_query(src, ops)
    nodes = query.nodes()
    plan = Plan(tuple((i, "dense_array") for i in range(len(nodes))))
    fused = fuse_plan(query, plan, bd.catalog, cost_model=bd.cost_model)
    base = execute_plan(query, plan, bd.catalog, concurrent=True)
    got = execute_plan(query, plan, bd.catalog, concurrent=True, fused=fused)
    assert got.fusion_fallbacks == 0, fuseplan.broken_keys()
    if len(ops) >= fuseplan.MIN_SEGMENT_NODES:
        assert got.fused_segments, (src, ops)     # the chain really fused
    else:
        assert not got.fused_segments             # 1-node chains never do
    assert base.value.data.shape == got.value.data.shape
    np.testing.assert_allclose(np.asarray(base.value.data, np.float32),
                               np.asarray(got.value.data, np.float32),
                               rtol=1e-5, atol=1e-5)
    assert base.value.valid_count == got.value.valid_count
    # pro-rata attribution: every fused member got a share of the segment
    for seg in got.fused_segments:
        for pos in seg:
            assert got.per_node_seconds[nodes[pos].uid] >= 0.0


def test_fused_count_reads_threaded_valid_count():
    """``count`` fuses by consuming the valid-count value threaded through
    the trace: a padded external's metadata count enters as a traced
    scalar, and an upstream select's mask sum replaces it — both must match
    the eager engine exactly, with count mid-chain as well as at the
    root."""
    bd = _middleware()
    rng = np.random.default_rng(11)
    padded = DenseTensor(jnp.asarray(rng.normal(size=(N, T))
                                     .astype(np.float32)), valid_count=29)
    bd.register("Xpad", padded, "dense_array")
    queries = [
        array.count(array.select(Ref("Xd"), lo=0.0, hi=0.7)),
        array.count(array.scale(Ref("Xpad"), factor=2.0)),
        array.scale(array.count(array.select(Ref("Xd"), lo=-0.3)),
                    factor=0.5),
        array.count(array.select(array.matmul(Ref("Xd"), Ref("W16")),
                                 lo=0.0)),
    ]
    for query in queries:
        nodes = query.nodes()
        plan = Plan(tuple((i, "dense_array") for i in range(len(nodes))))
        fused = fuse_plan(query, plan, bd.catalog, cost_model=bd.cost_model)
        assert any("count" in s.ops for s in fused.segments)
        base = execute_plan(query, plan, bd.catalog, concurrent=True)
        got = execute_plan(query, plan, bd.catalog, concurrent=True,
                           fused=fused)
        assert got.fusion_fallbacks == 0, fuseplan.broken_keys()
        assert got.fused_segments
        np.testing.assert_array_equal(np.asarray(base.value.data),
                                      np.asarray(got.value.data))
        assert base.value.valid_count == got.value.valid_count
    # the metadata really flowed: a padded count is 29, not N*T
    q = array.count(array.transpose(array.transpose(Ref("Xpad"))))
    plan = Plan(((0, "dense_array"), (1, "dense_array"),
                 (2, "dense_array")))
    base = execute_plan(q, plan, bd.catalog, concurrent=True)
    # NB the eager transpose drops padding metadata (engine outputs are
    # full) — fused must mirror that, not "fix" it
    got = execute_plan(q, plan, bd.catalog, concurrent=True,
                       fused=fuse_plan(q, plan, bd.catalog))
    assert int(np.asarray(got.value.data)) == \
        int(np.asarray(base.value.data))


# ---------------------------------------------------------------------------
# segmentation never crosses an engine or island (scope) boundary
# ---------------------------------------------------------------------------

def _boundary_query():
    """A cross-island shape with an explicit SCOPE seam in the middle and
    fusable ops on both sides of it."""
    left = relational.select(Ref("Xc"), lo=0.0)
    mid = scope(array, relational.matmul(left, Ref("W16")))
    return array.scale(array.haar(mid, levels=2), factor=2.0)


@settings(max_examples=100, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_segments_never_cross_engine_or_scope_boundaries(seed):
    bd = _shared_bd()
    query = _boundary_query()
    nodes = query.nodes()
    rng = np.random.default_rng(seed)
    assignment = []
    for pos, node in enumerate(nodes):
        if node.op == SCOPE_OP:
            assignment.append((pos, "dense_array"))   # array-model boundary
        else:
            assignment.append(
                (pos, str(rng.choice(["dense_array", "columnar"]))))
    plan = Plan(tuple(assignment))
    amap = dict(assignment)
    fused = fuse_plan(query, plan, bd.catalog)
    pos_of = {n.uid: p for p, n in enumerate(nodes)}
    seen = set()
    for seg in fused.segments:
        assert len(seg.positions) >= fuseplan.MIN_SEGMENT_NODES
        assert seg.engine in FUSABLE_ENGINES
        for pos in seg.positions:
            assert pos not in seen            # segments are disjoint
            seen.add(pos)
            node = nodes[pos]
            assert node.op != SCOPE_OP        # island seams stay explicit
            assert node.op in FUSABLE_OPS
            assert amap[pos] == seg.engine    # one engine per segment
        # connectivity: every non-root member's consumer is IN the segment,
        # so a chain interrupted by a scope node (or a foreign-engine node)
        # can never contribute both of its sides to one segment
        member = set(seg.positions)
        for pos in seg.positions[:-1]:
            consumer = next(p for p, n in enumerate(nodes)
                            if any(isinstance(i, PolyOp)
                                   and pos_of[i.uid] == pos
                                   for i in n.inputs))
            assert consumer in member


def test_shared_subtree_is_never_fused():
    bd = _shared_bd()
    shared = array.haar(Ref("Xd"), levels=2)
    query = array.add(shared, shared)          # one uid, two positions
    plan = Plan(tuple((i, "dense_array")
                      for i in range(len(query.nodes()))))
    assert fuse_plan(query, plan, bd.catalog).segments == ()


def test_fingerprint_distinguishes_binned_constants():
    q1 = array.scale(array.haar(Ref("Xd"), levels=2), factor=2.0)
    q2 = array.scale(array.haar(Ref("Xd"), levels=2), factor=0.5)
    assert query_fingerprint(q1) != query_fingerprint(q2)


# ---------------------------------------------------------------------------
# middleware/session surface: fuse knob, Result/stats reporting
# ---------------------------------------------------------------------------

def _pipeline_query():
    """A 4-op chain of dense_array-ONLY ops: every plan the DP (or a replan)
    can produce is the all-dense one, so these middleware-level tests are
    deterministic even when the first jit-cold fused serve triggers the
    online re-planner.  Mixed-candidate ops (select/haar/tfidf) get their
    fused-vs-unfused coverage from the 200-example property above."""
    x = array.transpose(array.transpose(Ref("Xd")))
    return array.scale(array.add(x, Ref("B816")), factor=2.0)


def test_fuse_knob_end_to_end():
    bd_on = _middleware(fuse=True)
    bd_off = _middleware(fuse=False)
    q = _pipeline_query()
    t_on = bd_on.execute(q, mode="training")
    t_off = bd_off.execute(q, mode="training")
    assert t_on.fused_segments == ()           # training always unfused
    p_on = bd_on.execute(q, mode="production")
    p_off = bd_off.execute(q, mode="production")
    assert p_on.fused_segments and not p_off.fused_segments
    np.testing.assert_allclose(np.asarray(p_on.result.data),
                               np.asarray(p_off.result.data),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(t_on.result.data),
                               np.asarray(p_on.result.data),
                               rtol=1e-5, atol=1e-5)
    assert bd_on.fused_serves == 1 and bd_on.fusion_segments >= 1
    assert bd_off.fused_serves == 0


def test_session_result_surfaces_fusion_and_islands():
    from repro.core.api import Session
    q = relational.select(Ref("Xc"), column="value", lo=0.0)
    # the fused tail uses dense_array-ONLY ops (scale/transpose), so the DP
    # cannot plan it apart — the segment is guaranteed whatever it learns
    q = array.scale(array.transpose(array.transpose(scope(array, q))),
                    factor=0.5)
    res = {}
    for fuse in (True, False):
        s = Session(_middleware(fuse=fuse))
        s.execute(q)                           # training
        res[fuse] = s.execute(q)               # production
    assert res[True].fused_segments and not res[False].fused_segments
    assert res[True].islands == res[False].islands
    np.testing.assert_allclose(np.asarray(res[True].value.data),
                               np.asarray(res[False].value.data),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# fallback fault injection: serve completes unfused, sticky, counted
# ---------------------------------------------------------------------------

def test_fusion_fallback_is_sticky_and_counted():
    inj = FusionFaultInjector()
    bd = _middleware(fusion_injector=inj)
    srv = QueryServer(bd)
    q = _pipeline_query()
    srv.submit(q)                              # training
    r1 = srv.submit(q)                         # warm fused serve
    assert r1.fused_segments and r1.fusion_fallbacks == 0
    inj.arm(1)                                 # next fused call "fails to
    r2 = srv.submit(q)                         # compile" mid-serve
    assert r2.fusion_fallbacks == 1
    assert r2.fused_segments == ()
    np.testing.assert_allclose(np.asarray(r2.result.data),
                               np.asarray(r1.result.data),
                               rtol=1e-5, atol=1e-5)
    assert len(inj.fired) == 1
    assert fuseplan.is_broken(inj.fired[0])
    r3 = srv.submit(q)                         # sticky: no retry, no new
    assert r3.fusion_fallbacks == 0            # fallback transition
    assert r3.fused_segments == ()
    np.testing.assert_allclose(np.asarray(r3.result.data),
                               np.asarray(r1.result.data),
                               rtol=1e-5, atol=1e-5)
    assert len(inj.fired) == 1                 # fused path never re-entered
    assert srv.stats["fusion_fallbacks"] == 1
    assert srv.stats["fused_serves"] == 1


# ---------------------------------------------------------------------------
# monitor attribution: fused serves keep the adaptive loop honest
# ---------------------------------------------------------------------------

def test_fused_serves_do_not_pollute_op_rates_and_drift_still_replans():
    bd = _middleware()
    q = _pipeline_query()
    rep_t = bd.execute(q, mode="training")
    sig = rep_t.sig
    # op-rate snapshot: production serves (fused or not) must never feed the
    # calibrated throughputs — only sequential training runs do
    probe = [("dense_array", op, 4096.0)
             for op in ("transpose", "add", "scale")]
    before = [bd.cost_model.op_seconds(*p) for p in probe]
    n_pos = len(q.nodes())
    for _ in range(3):
        rep = bd.execute(q, mode="production")
        assert rep.fused_segments
        # pro-rata attribution covers EVERY position, like an unfused serve
        assert set(rep.per_node_seconds) == set(range(n_pos))
        assert all(v >= 0.0 for v in rep.per_node_seconds.values())
    after = [bd.cost_model.op_seconds(*p) for p in probe]
    assert before == after
    # drift re-planning still fires on divergence measured from fused serves
    entry = bd.plan_cache[sig]
    entry.predicted_s = max(entry.predicted_s, 1e-4) * 1e3
    entry.restored = False
    rep = bd.execute(q, mode="production")
    assert rep.replanned
    assert bd.replans >= 1


def test_jit_cold_fused_serve_is_a_warmup_not_a_measurement():
    """The FIRST fused serve of a segment signature pays trace+compile: its
    wall time must stay out of the plan's measured mean and must never trip
    the divergence re-planner (which would silently dethrone the incumbent
    plan — observed as a resilience-test failure: failing the incumbent's
    engines no longer degraded the next serve)."""
    bd = _middleware()
    q = _pipeline_query()
    rep_t = bd.execute(q, mode="training")
    n_before = bd.monitor.known_plans(rep_t.sig)[rep_t.plan_key].n
    cold = bd.execute(q, mode="production")    # jit-cold fused serve
    assert cold.fused_segments and not cold.replanned
    assert bd.replans == 0
    assert bd.monitor.known_plans(rep_t.sig)[rep_t.plan_key].n == n_before
    warm = bd.execute(q, mode="production")    # warm serves DO measure
    assert warm.fused_segments
    assert bd.monitor.known_plans(rep_t.sig)[rep_t.plan_key].n == n_before + 1


def test_fused_serve_feeds_health_per_engine():
    from repro.core.health import EngineHealth
    health = EngineHealth()
    bd = _middleware(health=health)
    q = _pipeline_query()
    bd.execute(q, mode="training")
    rep = bd.execute(q, mode="production")
    assert rep.fused_segments and rep.status == "ok"
    # the straggler channel consumed per-engine seconds from the fused serve
    det = health._stragglers.get("dense_array")
    assert det is not None and det.n > 0
