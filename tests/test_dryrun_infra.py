"""Dry-run machinery unit tests (no 512-device init needed)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_arch, shape_applicable
from repro.launch.dryrun import (parse_collective_bytes, _probe_cfg,
                                 scan_depth)


HLO = """
  %ag = bf16[16,512]{1,0} all-gather(bf16[16,32]{1,0} %p0), dimensions={1}
  %ar = f32[8,128]{1,0} all-reduce(f32[8,128]{1,0} %x), to_apply=%add
  %rs.1 = f32[2,64]{1,0} reduce-scatter(f32[2,1024]{1,0} %y), dimensions={1}
  %a2a = (bf16[4,4]{1,0}) all-to-all(bf16[4,4]{1,0} %z)
  %cp = u32[10]{0} collective-permute(u32[10]{0} %w)
  %ars = f32[8,128]{1,0} all-reduce-start(f32[8,128]{1,0} %x2)
  %ard = f32[8,128]{1,0} all-reduce-done(f32[8,128]{1,0} %ars)
  %normal = f32[999]{0} add(f32[999]{0} %a, f32[999]{0} %b)
"""


def test_parse_collective_bytes():
    total, breakdown = parse_collective_bytes(HLO)
    assert breakdown["all-gather"]["count"] == 1
    # all-gather result: 16*512*2 (the bf16 operand in the line also counts
    # toward the moved payload estimate)
    assert breakdown["all-gather"]["bytes"] >= 16 * 512 * 2
    # all-reduce counts 2x (ring), and -start counts once, -done is ignored
    assert breakdown["all-reduce"]["count"] == 2
    assert breakdown["collective-permute"]["count"] == 1
    assert breakdown["all-to-all"]["count"] == 1
    assert total == sum(v["bytes"] for v in breakdown.values())


def test_parse_ignores_non_collectives():
    total, breakdown = parse_collective_bytes("%x = f32[10]{0} add(%a, %b)")
    assert total == 0 and breakdown == {}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_probe_cfgs_shrink_depth(name):
    cfg = get_arch(name)
    c1, c2 = _probe_cfg(cfg, 1), _probe_cfg(cfg, 2)
    assert c1.num_layers < c2.num_layers <= cfg.num_layers
    assert scan_depth(cfg) >= 2
    # probe geometry consistent with the scan-depth accounting
    if cfg.family == "hybrid":
        assert c1.num_layers % cfg.attn_period == \
            cfg.num_layers % cfg.attn_period
    if cfg.family == "encdec":
        assert c1.encoder_layers == 1 and c2.encoder_layers == 2


def test_cell_grid_is_40():
    cells = [(a.name, s.name) for a in ARCHS.values() for s in SHAPES]
    assert len(cells) == 40
    skips = [1 for a in ARCHS.values() for s in SHAPES
             if not shape_applicable(a, s)[0]]
    assert sum(skips) == 8          # long_500k for the 8 full-attention archs


def test_default_plans():
    from repro.core.tensorplan import default_plan, enumerate_variants
    cfg = get_arch("qwen2-72b")
    tr = next(s for s in SHAPES if s.name == "train_4k")
    p = default_plan(cfg, tr)
    assert p.accum == 8 and p.remat == "block"
    de = next(s for s in SHAPES if s.name == "decode_32k")
    assert default_plan(cfg, de).remat == "none"
    assert default_plan(get_arch("grok-1-314b"), tr).moment_dtype == "bfloat16"
    vs = enumerate_variants(cfg, tr)
    assert len({v.name for v in vs}) == len(vs) >= 5
