"""Multi-island query API: first-class scope boundaries in the IR, the
``connect()``/``Session`` front door, the textual ``BIGDAWG(ISLAND(...))``
syntax, bounded admission, and degenerate islands through the full
train -> cache -> serve path."""
import threading
import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (BigDAWG, ColumnarTable, DenseTensor, Monitor, Report,
                        Result, SCOPE_OP, array, bigdawg, connect, degenerate,
                        enumerate_plans, execute_plan, island_kind,
                        relational, scope, scope_candidates, signature,
                        signature_text, stream, text)
from repro.core.planner import dp_plans, estimate_casts, exhaustive_plans
from repro.core.qlang import QueryParseError
from repro.runtime.server import QueryServer, Shed


# ---------------------------------------------------------------------------
# fixtures: a join-able relational catalog + a dense weight matrix
# ---------------------------------------------------------------------------

def _cross_island_session(state_path=None, **kwargs):
    """A session where RELATIONAL(join(A, B)) reconstructs a permuted matrix
    and ARRAY(matmul(_, W)) projects it — the canonical cross-island query."""
    rng = np.random.default_rng(0)
    M = rng.normal(size=(8, 6)).astype(np.float32)
    perm = np.array([2, 0, 5, 1, 4, 3])
    W = rng.normal(size=(6, 4)).astype(np.float32)
    ii, kk = np.meshgrid(np.arange(8), np.arange(6), indexing="ij")
    A = ColumnarTable({"i": ii.ravel().astype(np.int32),
                       "key": kk.ravel().astype(np.int32),
                       "value": M.ravel()})
    B = ColumnarTable({"key": np.arange(6, dtype=np.int32),
                       "j": perm.astype(np.int32)})
    s = connect(state_path, **kwargs)
    s.register("A", A, "columnar").register("B", B, "columnar")
    s.register("W", DenseTensor(jnp.asarray(W)), "dense_array")
    Pm = np.zeros((6, 6), np.float32)
    Pm[np.arange(6), perm] = 1.0
    return s, (M @ Pm) @ W


TEXT_Q = ("RELATIONAL(join(A, B, left_on=key, right_on=key)) "
          "|> ARRAY(matmul(_, W))")


def _handbuilt(s):
    isl = s.islands
    return isl.array.matmul(
        isl.array.scope(isl.relational.join("A", "B", left_on="key",
                                            right_on="key")), "W")


# ---------------------------------------------------------------------------
# scope nodes in the IR
# ---------------------------------------------------------------------------

def test_scope_builds_boundary_node():
    q = scope("array", relational.select("A", column="value", lo=0.0))
    assert q.op == SCOPE_OP and q.island == "array"
    assert q.inputs[0].island == "relational"
    # Island.scope and the free function agree
    q2 = array.scope(relational.select("A", column="value", lo=0.0))
    assert signature(q) == signature(q2)


def test_scope_rejects_unknown_island():
    with pytest.raises(ValueError, match="available"):
        scope("warehouse", relational.count("A"))


def test_scope_candidates_are_model_native():
    assert scope_candidates("array") == ("dense_array",)
    assert scope_candidates("relational") == ("columnar",)
    assert scope_candidates("text") == ("kv_sparse",)
    assert scope_candidates("stream") == ("stream",)
    assert scope_candidates("degenerate:kv_sparse") == ("kv_sparse",)
    assert island_kind("degenerate:columnar") == "columnar"


def test_scope_changes_signature():
    plain = array.count(relational.select("A", column="value", lo=0.0))
    scoped = array.count(scope("array",
                               relational.select("A", column="value",
                                                 lo=0.0)))
    assert signature(plain) != signature(scoped)
    assert ".scope[](" in signature_text(scoped)
    # stable across rebuilds (plan cache / monitor keying)
    again = array.count(scope("array",
                              relational.select("A", column="value",
                                                lo=0.0)))
    assert signature(scoped) == signature(again)


# ---------------------------------------------------------------------------
# planner: the boundary cast is planned and charged
# ---------------------------------------------------------------------------

def test_planner_places_boundary_on_island_model():
    s, _ = _cross_island_session()
    q = _handbuilt(s)
    ranked = dp_plans(q, s.catalog, max_plans=8)
    descs = {p.describe(q) for _, p in ranked}
    # the boundary node always lands on the array island's model-native
    # engine; the relational fragment always stays columnar
    for _, p in ranked:
        d = p.describe(q)
        assert "scope@dense_array" in d and "join@columnar" in d
    assert "join@columnar scope@dense_array matmul@dense_array" in descs


def test_boundary_cast_is_charged():
    s, _ = _cross_island_session()
    q = _handbuilt(s)
    best = enumerate_plans(q, s.catalog)[0]
    assert estimate_casts(q, best, s.catalog) > 0.0


def test_dp_matches_exhaustive_on_scoped_query():
    s, _ = _cross_island_session()
    q = _handbuilt(s)
    dp = dp_plans(q, s.catalog, max_plans=16)
    ex = exhaustive_plans(q, s.catalog)
    assert dp[0][1].key == ex[0][1].key
    assert dp[0][0] == pytest.approx(ex[0][0])


def test_identity_scope_merges_for_free():
    # scoping a relational subtree INTO relational adds no cast candidates:
    # the boundary merges with its child's container
    q_plain = relational.count(relational.select("A", column="value", lo=0.0))
    q_scoped = relational.count(
        scope("relational", relational.select("A", column="value", lo=0.0)))
    plans_p = enumerate_plans(q_plain)
    plans_s = enumerate_plans(q_scoped)
    assert {p.describe(q_plain) for p in plans_p} == \
        {p.describe(q_scoped).replace(" scope@columnar", "")
         for p in plans_s}


# ---------------------------------------------------------------------------
# executor: boundary executes as a migration, result matches the reference
# ---------------------------------------------------------------------------

def test_cross_island_executes_correctly_both_modes():
    s, ref = _cross_island_session()
    q = _handbuilt(s)
    plan = enumerate_plans(q, s.catalog)[0]
    seq = execute_plan(q, plan, s.catalog)
    con = execute_plan(q, plan, s.catalog, concurrent=True)
    np.testing.assert_allclose(np.asarray(seq.value.data), ref,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(con.value.data), ref,
                               rtol=1e-4, atol=1e-4)
    # the boundary moved real bytes through the migrator
    assert seq.cast_bytes > 0 and seq.n_casts >= 1


def test_scope_never_feeds_op_observations():
    s, _ = _cross_island_session()
    q = _handbuilt(s)
    plan = enumerate_plans(q, s.catalog)[0]
    res = execute_plan(q, plan, s.catalog)
    assert all(op != SCOPE_OP for _, op, _, _ in res.node_obs)
    assert any(op == "join" for _, op, _, _ in res.node_obs)


# ---------------------------------------------------------------------------
# qlang: the paper's textual surface round-trips parse -> plan -> execute
# ---------------------------------------------------------------------------

def test_textual_equals_handbuilt_signature():
    s, _ = _cross_island_session()
    assert signature(bigdawg(TEXT_Q), s.catalog) == \
        signature(_handbuilt(s), s.catalog)


def test_paper_nested_syntax():
    s, _ = _cross_island_session()
    nested = bigdawg("BIGDAWG(ARRAY(matmul(RELATIONAL("
                     "join(A, B, left_on=key, right_on=key)), W)))")
    assert signature(nested, s.catalog) == \
        signature(bigdawg(TEXT_Q), s.catalog)


def test_textual_literals_and_strings():
    q = bigdawg("RELATIONAL(select(A, column='value', lo=-0.5, hi=2))")
    node = q  # select (no boundary: relational block over a relational op)
    assert node.op == "select"
    assert node.attrs == {"column": "value", "lo": -0.5, "hi": 2}
    # bare-word kwarg == quoted string
    q2 = bigdawg("RELATIONAL(select(A, column=value, lo=-0.5, hi=2))")
    assert signature(q) == signature(q2)


def test_textual_bare_ref_block_is_a_cast():
    q = bigdawg("ARRAY(A)")
    assert q.op == SCOPE_OP and q.island == "array"


def test_textual_degenerate_island():
    q = bigdawg("DEGENERATE:kv_sparse(tfidf(T))")
    assert q.island == "degenerate:kv_sparse"


def test_parse_errors_carry_vocabulary():
    with pytest.raises(QueryParseError, match="available islands"):
        bigdawg("WAREHOUSE(count(A))")
    # unknown operator surfaces the island's op list (satellite: the error
    # path must teach the vocabulary)
    with pytest.raises(AttributeError, match="tfidf"):
        bigdawg("TEXT(frobnicate(A))")
    with pytest.raises(QueryParseError, match="placeholder"):
        bigdawg("ARRAY(count(_))")
    with pytest.raises(QueryParseError, match="never consumed"):
        bigdawg("RELATIONAL(count(A)) |> ARRAY(count(W))")
    with pytest.raises(QueryParseError, match="trailing"):
        bigdawg("ARRAY(count(A)) whoops")
    with pytest.raises(QueryParseError, match="ISLAND"):
        bigdawg("count(A)")
    with pytest.raises(QueryParseError, match="keyword"):
        bigdawg("ARRAY(scale(A, 2.0))")


def test_island_error_lists_ops_attribute_api():
    with pytest.raises(AttributeError, match="window_agg"):
        stream.frobnicate("S")
    with pytest.raises(AttributeError, match="available operators"):
        text.matmul  # noqa: B018 — text island has spmm, not matmul
    with pytest.raises(ValueError, match="available operators"):
        relational._build("no_such_op", "A")


# ---------------------------------------------------------------------------
# Session front door
# ---------------------------------------------------------------------------

def test_session_execute_returns_structured_result():
    s, ref = _cross_island_session()
    res = s.execute(TEXT_Q, mode="training")
    assert isinstance(res, Result)
    np.testing.assert_allclose(np.asarray(res.value.data), ref,
                               rtol=1e-4, atol=1e-4)
    # provenance names BOTH islands, per node and in the island roll-up
    assert res.islands == ("relational", "array")
    assert res.provenance[0].startswith("relational.join@")
    assert f"array.{SCOPE_OP}@dense_array" in res.provenance
    assert any(p.startswith("array.matmul@") for p in res.provenance)
    assert " -> " in res.describe()
    # per-node timings cover every post-order position
    assert set(res.per_node_seconds) == {0, 1, 2}
    assert all(t >= 0.0 for t in res.per_node_seconds.values())
    assert res.cast_bytes > 0 and res.mode == "training"
    assert res.report is not None and res.report.sig == res.sig


def test_session_text_and_handbuilt_share_plan_cache():
    s, _ = _cross_island_session()
    r1 = s.execute(TEXT_Q, mode="training")
    r2 = s.execute(_handbuilt(s))          # auto -> production, same sig
    assert r2.mode == "production"
    assert r2.sig == r1.sig and r2.plan_key == r1.plan_key


def test_session_warm_restart(tmp_path):
    path = str(tmp_path / "monitor.json")
    s, ref = _cross_island_session(path)
    s.execute(TEXT_Q, mode="training")
    s.persist()
    s2, _ = _cross_island_session(path)
    res = s2.execute(TEXT_Q)
    assert res.mode == "production"        # zero plan enumerations
    np.testing.assert_allclose(np.asarray(res.value.data), ref,
                               rtol=1e-4, atol=1e-4)


def test_connect_rejects_conflicting_args():
    bd = BigDAWG()
    with pytest.raises(ValueError, match="existing instance"):
        connect("x.json", bigdawg=bd)
    assert connect(bigdawg=bd).bigdawg is bd


def test_session_server_wraps_queryserver():
    s, _ = _cross_island_session()
    srv = s.server(max_pending=3)
    assert isinstance(srv, QueryServer)
    assert srv.bd is s.bigdawg and srv.max_pending == 3
    rep = srv.submit(s.parse(TEXT_Q))
    assert isinstance(rep, Report)
    assert srv.stats["requests"] == 1


def test_islands_namespace_degenerate():
    s, _ = _cross_island_session()
    isl = s.islands.degenerate("dense_array")
    assert isl.name == "degenerate:dense_array"
    with pytest.raises(ValueError, match="engines"):
        s.islands.degenerate("oracle")


# ---------------------------------------------------------------------------
# bounded admission (QueryServer(max_pending=N))
# ---------------------------------------------------------------------------

class _SlowBD:
    """Stand-in middleware whose execute blocks long enough that a bounded
    server must shed the rest of the batch."""

    def __init__(self, delay=0.25):
        self.delay = delay
        self.calls = 0
        self._lock = threading.Lock()

    def execute(self, query, mode="auto"):
        with self._lock:
            self.calls += 1
        time.sleep(self.delay)
        return Report(result=None, plan_key="0:dense_array",
                      mode="production", seconds=self.delay,
                      cast_bytes=0.0, sig="s", cache_hit=True)


def test_max_pending_sheds_overflow():
    bd = _SlowBD()
    srv = QueryServer(bd, max_pending=1)
    out = srv.submit_many(["q"] * 5, workers=4)
    assert len(out) == 5
    assert isinstance(out[0], Report)          # first request always admitted
    shed = [r for r in out if isinstance(r, Shed)]
    assert len(shed) == 4 and srv.stats["shed"] == 4
    assert all(r.query == "q" and r.reason == "max_pending" for r in shed)
    assert bd.calls == 1 and srv.stats["requests"] == 1
    # capacity is released once in-flight work drains: a later batch admits
    out2 = srv.submit_many(["q2"] * 2, workers=2)
    assert isinstance(out2[0], Report)


def test_max_pending_unbounded_by_default():
    srv = QueryServer(_SlowBD(delay=0.0))
    out = srv.submit_many(["q"] * 6, workers=3)
    assert all(isinstance(r, Report) for r in out)
    assert srv.stats["shed"] == 0


def test_serve_summary_counts_shed():
    srv = QueryServer(_SlowBD(), max_pending=1)
    summary = srv.serve(["q"] * 4, workers=4)
    assert summary["shed"] == 3
    # rps counts served requests only
    assert summary["rps"] == pytest.approx(1 / summary["seconds"], rel=0.2)


def test_max_pending_validation():
    with pytest.raises(ValueError, match="max_pending"):
        QueryServer(_SlowBD(), max_pending=0)


def test_sequential_batch_occupies_the_shared_bound():
    # a workers<=1 batch must reserve in-flight slots too, or a concurrent
    # batch on another thread could jointly exceed max_pending
    release, started = threading.Event(), threading.Event()

    class _BlockingBD:
        def execute(self, query, mode="auto"):
            started.set()
            release.wait(5)
            return Report(result=None, plan_key="0:dense_array",
                          mode="production", seconds=0.0, cast_bytes=0.0,
                          sig="s", cache_hit=True)

    srv = QueryServer(_BlockingBD(), max_pending=1)
    t = threading.Thread(target=lambda: srv.submit_many(["q"], workers=1))
    t.start()
    try:
        assert started.wait(5)
        out = srv.submit_many(["q2"] * 3, workers=2)
        assert all(isinstance(r, Shed) for r in out)
        assert srv.stats["shed"] == 3
    finally:
        release.set()
        t.join()


# ---------------------------------------------------------------------------
# degenerate islands through the full train -> cache -> serve path
# ---------------------------------------------------------------------------

def _degenerate_session(state_path=None):
    rng = np.random.default_rng(1)
    M = rng.normal(size=(12, 6)).astype(np.float32)
    W = rng.normal(size=(6, 5)).astype(np.float32)
    s = connect(state_path)
    s.register("M", DenseTensor(jnp.asarray(M)), "dense_array")
    s.register("Wd", DenseTensor(jnp.asarray(W)), "dense_array")
    return s, M @ W


def test_degenerate_train_then_production():
    s, ref = _degenerate_session()
    isl = s.islands.degenerate("dense_array")
    q = isl.matmul(isl.select("M", lo=-10.0, hi=10.0), "Wd")
    r1 = s.execute(q, mode="training")
    np.testing.assert_allclose(np.asarray(r1.value.data), ref,
                               rtol=1e-4, atol=1e-4)
    # every node pinned to the one engine, by construction
    assert all(p.endswith("@dense_array") for p in r1.provenance)
    assert r1.islands == ("degenerate:dense_array",)
    r2 = s.execute(q)
    assert r2.mode == "production" and r2.report.cache_hit


def test_degenerate_served_warm_through_queryserver(tmp_path):
    path = str(tmp_path / "monitor.json")
    s, ref = _degenerate_session(path)
    isl = s.islands.degenerate("dense_array")
    mk = lambda: isl.matmul(isl.select("M", lo=-10.0, hi=10.0), "Wd")
    srv = s.server()
    srv.warm([mk()])
    srv.persist()
    # fresh process on the same state: production from the persisted cache
    s2, _ = _degenerate_session(path)
    srv2 = s2.server()
    reports = srv2.submit_many([mk() for _ in range(4)], workers=2)
    assert all(r.mode == "production" for r in reports)
    assert srv2.stats["trainings"] == 0 and srv2.stats["requests"] == 4
    np.testing.assert_allclose(np.asarray(reports[-1].result.data), ref,
                               rtol=1e-4, atol=1e-4)


def test_degenerate_scoped_into_array_island():
    # a degenerate fragment consumed by a standard island crosses a boundary
    # like any other island pair
    s, ref = _degenerate_session()
    q = bigdawg("ARRAY(count(DEGENERATE:dense_array(matmul(M, Wd))))")
    r = s.execute(q, mode="training")
    assert "degenerate:dense_array" in r.islands and "array" in r.islands
    assert int(r.value.data) == ref.size
