"""End-to-end observability battery: request span trees (core.tracing),
the process-wide metrics registry (runtime.telemetry), cross-process span
propagation over the procpool pipe RPC, the per-worker-count dispatch
calibration table, and the server's metrics-backed stats view.

The cross-island fixtures mirror test_multi_island_api's canonical query
(RELATIONAL join |> ARRAY matmul) so every span kind shows up: plan,
cache_hit, ivm_patch, engine_op, cast — and over a pool, queue_wait /
worker_dispatch / a worker-rooted request re-attached under the master's
tree."""
import multiprocessing
import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (BigDAWG, ColumnarTable, DenseTensor, Span, Trace,
                        Tracer, connect)
from repro.core import tracing
from repro.core.costmodel import CostModel
from repro.core.executor import DISPATCH_PROBE_WORKERS, _dispatch_overhead
from repro.core.islands import array
from repro.core.procpool import ProcPool
from repro.runtime.fault import WorkerKillInjector
from repro.runtime.server import QueryServer
from repro.runtime.telemetry import (HIST_BOUNDS, Histogram, Metrics,
                                     _metrics_hammer, default_metrics_path,
                                     load_merged)

TEXT_Q = ("RELATIONAL(join(A, B, left_on=key, right_on=key)) "
          "|> ARRAY(matmul(_, W))")


def _cross_island_session(state_path=None, **kwargs):
    rng = np.random.default_rng(0)
    M = rng.normal(size=(8, 6)).astype(np.float32)
    perm = np.array([2, 0, 5, 1, 4, 3])
    W = rng.normal(size=(6, 4)).astype(np.float32)
    ii, kk = np.meshgrid(np.arange(8), np.arange(6), indexing="ij")
    A = ColumnarTable({"i": ii.ravel().astype(np.int32),
                       "key": kk.ravel().astype(np.int32),
                       "value": M.ravel()})
    B = ColumnarTable({"key": np.arange(6, dtype=np.int32),
                       "j": perm.astype(np.int32)})
    s = connect(state_path, **kwargs)
    s.register("A", A, "columnar").register("B", B, "columnar")
    s.register("W", DenseTensor(jnp.asarray(W)), "dense_array")
    return s


def _assert_connected(trace):
    """Every span except the single master root reaches the root via
    parent links — no orphans, one tree."""
    sids = {sp["sid"] for sp in trace.spans}
    roots = [sp for sp in trace.spans if sp["parent"] is None]
    assert len(roots) == 1
    orphans = [sp for sp in trace.spans
               if sp["parent"] is not None and sp["parent"] not in sids]
    assert orphans == []


# ---------------------------------------------------------------------------
# span primitives
# ---------------------------------------------------------------------------

def test_span_tree_basics():
    tr = Tracer(enabled=True)
    t = tr.start()
    with t.root("request", sig="s") as root:
        with root.child("plan") as p:
            p.annotate(plan_key="k")
        root.event("cache_hit", plan_key="k")
        sid = root.static_child("fused_segment", 0.5, engine="dense_array")
        t.static("engine_op", sid, 0.25, op="matmul")
    tree = t.tree()
    assert len(tree) == 1 and tree[0]["name"] == "request"
    names = [c["name"] for c in tree[0]["children"]]
    assert names == ["plan", "cache_hit", "fused_segment"]
    seg = tree[0]["children"][2]
    assert seg["children"][0]["name"] == "engine_op"
    assert seg["children"][0]["seconds"] == 0.25
    assert t.find("cache_hit")[0]["seconds"] == 0.0
    # ids embed the pid -> unique across processes
    assert all(sp["sid"].startswith("%x-" % os.getpid()) for sp in t.spans)
    # adopt extends; portable round-trips
    t2 = Trace(trace_id=t.trace_id)
    t2.adopt(tracing.portable(t))
    assert len(t2) == len(t)


def test_span_end_idempotent_and_exception_safe():
    t = Tracer(True).start()
    root = t.root("request")
    with pytest.raises(RuntimeError):
        with root:
            raise RuntimeError("boom")
    root.end()                      # second end is a no-op
    assert len(t) == 1


def test_disabled_tracer_allocates_nothing():
    tr = Tracer(enabled=False)
    assert tr.start() is None and not tr
    # a propagated upstream context forces a trace even when disabled —
    # the worker half of cross-process propagation
    forced = tr.start(("tid-1", "parent-9"))
    assert forced is not None and forced.trace_id == "tid-1"
    root = forced.root("request")
    root.end()
    assert forced.spans[0]["parent"] == "parent-9"


# ---------------------------------------------------------------------------
# warm in-process serve: span-tree shape
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_session():
    s = _cross_island_session(trace=True, explore_budget=0.0)
    s.execute(TEXT_Q, mode="training")
    yield s


def test_warm_cross_island_span_tree(traced_session):
    res = traced_session.execute(TEXT_Q)
    assert res.report.mode == "production"
    t = res.trace
    assert t is not None
    _assert_connected(t)
    tree = t.tree()
    assert tree[0]["name"] == "request"
    child_names = {c["name"] for c in tree[0]["children"]}
    assert {"plan", "cache_hit", "engine_op"} <= child_names
    # engine_op spans ARE the executor's per-node measurements
    eng_sum = sum(sp["seconds"] for sp in t.find("engine_op"))
    per_node = sum(res.report.per_node_seconds.values())
    assert eng_sum == pytest.approx(per_node, rel=1e-6)
    # the cross-island plan casts columnar -> dense at the scope boundary
    casts = t.find("cast")
    assert casts and casts[0]["attrs"]["src"] == "columnar"
    # the request root's wall time covers the report's measured serve
    root = tree[0]
    assert root["seconds"] >= res.seconds * 0.99
    assert t.to_json().startswith("{")


def test_training_trace_nests_engine_ops_under_train(traced_session):
    res = traced_session.execute(
        "RELATIONAL(join(A, B, left_on=key, right_on=key)) "
        "|> ARRAY(count(_))", mode="training")
    t = res.trace
    _assert_connected(t)
    train = t.find("train")
    assert len(train) == 1 and train[0]["attrs"]["plans"] >= 1
    tsid = train[0]["sid"]
    assert all(sp["parent"] == tsid for sp in t.find("engine_op"))


def test_trace_off_by_default_and_zero_alloc(monkeypatch):
    s = _cross_island_session()          # no trace= knob
    s.execute(TEXT_Q, mode="training")

    def _no_alloc(*a, **k):
        raise AssertionError("Trace allocated on the disabled path")
    monkeypatch.setattr(tracing.Trace, "__init__", _no_alloc)
    monkeypatch.setattr(tracing.Span, "__init__", _no_alloc)
    res = s.execute(TEXT_Q)              # warm serve: no Trace/Span built
    assert res.trace is None
    assert res.report.mode == "production"


# ---------------------------------------------------------------------------
# histogram quantiles
# ---------------------------------------------------------------------------

def test_histogram_quantiles_track_numpy_percentiles():
    rng = np.random.default_rng(42)
    samples = np.exp(rng.normal(loc=-6.0, scale=1.5, size=4000))
    h = Histogram()
    for v in samples:
        h.observe(float(v))
    ratio = 10.0 ** (1.0 / 8.0)          # one bucket of log-spaced error
    for q in (0.50, 0.95, 0.99):
        exact = float(np.percentile(samples, 100 * q))
        est = h.quantile(q)
        assert exact / ratio <= est <= exact * ratio, (q, est, exact)
    assert h.count == 4000
    assert h.sum == pytest.approx(float(samples.sum()), rel=1e-6)
    assert h.min == pytest.approx(float(samples.min()))
    assert h.max == pytest.approx(float(samples.max()))


def test_histogram_merge_equals_single_stream():
    rng = np.random.default_rng(7)
    samples = rng.uniform(1e-4, 1e-1, size=900)
    whole = Histogram()
    parts = [Histogram() for _ in range(3)]
    for i, v in enumerate(samples):
        whole.observe(float(v))
        parts[i % 3].observe(float(v))
    merged = Histogram.from_blob(parts[0].to_blob())     # blob round-trip
    merged.merge(parts[1])
    merged.merge(parts[2])
    assert merged.counts == whole.counts
    for q in (0.5, 0.95, 0.99):
        assert merged.quantile(q) == whole.quantile(q)
    assert Histogram().quantile(0.99) == 0.0             # empty -> 0
    assert len(HIST_BOUNDS) == 61


# ---------------------------------------------------------------------------
# metrics registry + merge-on-save
# ---------------------------------------------------------------------------

def test_metrics_registry_roundtrip(tmp_path):
    path = str(tmp_path / "m.metrics.json")
    m = Metrics(path)
    m.counter("a")
    m.counter("a", 2.0)
    m.set_counter("b", 7.0)
    m.gauge("g", 0.25)
    m.observe("lat", 0.01)
    assert m.value("a") == 3.0 and m.value("g") == 0.25
    assert m.value("missing", -1.0) == -1.0
    m.save()
    snap = load_merged(path)
    assert snap["counters"]["a"] == 3.0 and snap["counters"]["b"] == 7.0
    assert snap["gauges"]["g"] == 0.25
    assert snap["histograms"]["lat"]["count"] == 1
    assert default_metrics_path("state/monitor.json") \
        == "state/monitor.metrics.json"


def test_metrics_merge_on_save_three_process_hammer(tmp_path):
    """Three spawned processes hammer one metrics file, saving after every
    round.  Merge-on-save keeps sections exact: each private counter lands
    at rounds, the shared counter at writers*rounds, and the merged
    histogram saw every observation — no torn files, no lost increments."""
    path = str(tmp_path / "contended.metrics.json")
    ctx = multiprocessing.get_context("spawn")
    n_procs, rounds = 3, 6
    procs = [ctx.Process(target=_metrics_hammer,
                         args=(path, f"private-{i}", "shared", rounds, i))
             for i in range(n_procs)]
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=120)
        assert p.exitcode == 0
    snap = load_merged(path)
    for i in range(n_procs):
        assert snap["counters"][f"private-{i}"] == rounds
    assert snap["counters"]["shared"] == n_procs * rounds
    assert snap["histograms"]["hammer.latency"]["count"] == n_procs * rounds
    assert snap["gauges"]["hammer.last_round"] == rounds - 1


def test_metrics_snapshot_merges_other_writers(tmp_path):
    path = str(tmp_path / "m.metrics.json")
    a, b = Metrics(path, shared=True), Metrics(path, shared=True)
    a.counter("hits", 2.0)
    b.counter("hits", 5.0)
    a.save()
    b.save()
    # local reads stay per-writer; merged folds the other section in
    assert a.value("hits") == 2.0
    assert a.snapshot()["counters"]["hits"] == 2.0
    assert a.snapshot(merged=True)["counters"]["hits"] == 7.0
    assert b.snapshot(merged=True)["counters"]["hits"] == 7.0


# ---------------------------------------------------------------------------
# serving stack: stats view + session metrics
# ---------------------------------------------------------------------------

def test_queryserver_stats_is_metrics_backed_mapping():
    s = _cross_island_session()
    srv = QueryServer(s.bigdawg)
    assert srv.metrics is s.bigdawg.metrics      # one registry, one lock
    q = s.parse(TEXT_Q)
    srv.submit(q)
    srv.submit(q)
    assert srv.stats["requests"] == 2
    assert srv.stats["trainings"] == 1
    assert srv.stats["cache_hits"] >= 1
    assert isinstance(srv.stats["seconds"], float)
    d = dict(srv.stats)                          # Mapping protocol
    assert d["requests"] == 2 and "breaker_trips" in d
    assert srv.stats() == d                      # callable snapshot
    assert len(srv.stats) == len(d)
    with pytest.raises(KeyError):
        srv.stats["nope"]
    hist = srv.metrics.histogram("server.latency")
    assert hist is not None and hist.count == 2


def test_session_metrics_snapshot():
    s = _cross_island_session()
    s.execute(TEXT_Q, mode="training")
    s.execute(TEXT_Q)
    snap = s.metrics()
    assert snap["counters"]["bd.serve_seconds"] > 0.0
    assert snap["histograms"]["bd.serve_latency"]["count"] >= 1
    assert snap["histograms"]["bd.serve_latency"]["p50"] > 0.0


# ---------------------------------------------------------------------------
# dispatch-overhead calibration table (per worker count)
# ---------------------------------------------------------------------------

def test_dispatch_table_interpolates_and_persists(tmp_path):
    cm = CostModel()
    cm.observe_dispatch(1e-4, workers=1)
    cm.observe_dispatch(3e-4, workers=4)
    assert cm.dispatch_overhead_s(1) == pytest.approx(1e-4)
    assert cm.dispatch_overhead_s(4) == pytest.approx(3e-4)
    # linear interpolation between bracketing probes
    assert cm.dispatch_overhead_s(2) == pytest.approx(1e-4 + (3e-4 - 1e-4) / 3)
    # flat extrapolation outside the probed range
    assert cm.dispatch_overhead_s(8) == pytest.approx(3e-4)
    assert cm.dispatch_overhead_s(0) == pytest.approx(1e-4)
    path = str(tmp_path / "calibration.json")
    cm.save(path)
    cm2 = CostModel()
    cm2.load(path)
    assert set(cm2.dispatch_table) == {1, 4}
    assert cm2.dispatch_overhead_s(2) == pytest.approx(cm.dispatch_overhead_s(2))
    # legacy single-point mean still feeds old readers
    assert cm2.dispatch_overhead.n == 2


def test_dispatch_probe_measures_each_worker_count():
    cm = CostModel()
    got = _dispatch_overhead(cm, workers=2)
    assert got > 0.0
    assert set(cm.dispatch_table) >= set(DISPATCH_PROBE_WORKERS)
    for w in DISPATCH_PROBE_WORKERS:
        assert cm.dispatch_table[w].mean > 0.0
    # the probe ran once; later calls reuse the calibrated table
    assert _dispatch_overhead(cm, workers=2) == pytest.approx(got)


# ---------------------------------------------------------------------------
# cross-process propagation over the procpool
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_pool(tmp_path_factory):
    rng = np.random.RandomState(11)
    state = str(tmp_path_factory.mktemp("obsstate") / "monitor.json")
    p = ProcPool(2, state_path=state, train_plans=2, trace=True,
                 request_timeout_s=120.0)
    p.register("M", DenseTensor(rng.rand(40, 3)), "dense_array", shards=2)
    p.register("W", DenseTensor(rng.rand(3, 4)), "dense_array")
    yield p
    p.close()


def test_pool_trace_spans_one_connected_tree(traced_pool):
    q = array.matmul("M", "W")
    traced_pool.execute(q, mode="training")
    rep = traced_pool.execute(q)
    assert rep.mode == "production"
    t = rep.trace
    assert t is not None
    _assert_connected(t)
    # spans from two processes share one trace id and link up: master root
    # (queue_wait/worker_dispatch) + the worker's re-attached request
    pids = {sp["sid"].split("-")[0] for sp in t.spans}
    assert len(pids) >= 2
    master_pid = "%x" % os.getpid()
    assert master_pid in pids
    wroots = [sp for sp in t.spans
              if sp["name"] == "request" and sp["parent"] is not None]
    assert len(wroots) >= 1
    assert all(not sp["sid"].startswith(master_pid + "-") for sp in wroots)
    assert t.find("worker_dispatch") and t.find("queue_wait")
    assert t.find("engine_op")
    # per-span seconds are consistent with the Report's measured wall time:
    # the worker's request span covers the serve (the hard invariant), and
    # doesn't wildly exceed it — the span also wraps middleware bookkeeping
    # (signature hashing, cache lookup, monitor reads) outside the
    # executor-timed rep.seconds, which on a loaded 1-CPU host can cost
    # tens of ms, so the upper bound is a loose sanity check only
    wall = max(sp["seconds"] for sp in wroots)
    assert wall >= rep.seconds * 0.99
    assert wall - rep.seconds <= max(0.10 * wall, 0.5)


def test_pool_trace_survives_worker_kill_and_respawn():
    """A worker killed mid-dispatch respawns and the retried request still
    comes back with one connected trace: the respawn shows up as an event
    under the master root, and the surviving worker's spans re-attach."""
    rng = np.random.RandomState(3)
    inj = WorkerKillInjector(kill_on_dispatch=2)
    p = ProcPool(2, train_plans=2, retries=1, kill_injector=inj,
                 trace=True, request_timeout_s=120.0)
    try:
        p.register("M", DenseTensor(rng.rand(40, 3)), "dense_array")
        p.register("W", DenseTensor(rng.rand(3, 4)), "dense_array")
        q = array.matmul("M", "W")
        p.execute(q, mode="training")              # dispatch 1: survives
        rep = p.execute(q, mode="training")        # dispatch 2: kill lands
        assert inj.kills == 1 and p.respawns >= 1
        t = rep.trace
        assert t is not None
        _assert_connected(t)
        assert len(t.find("respawn")) >= 1
        assert t.find("engine_op")                 # retried serve's spans
        assert len(t.find("request")) == 2         # master root + worker
        # respawns surfaced through the metrics registry too
        assert p.metrics.value("pool.respawns") >= 1
    finally:
        p.close()


def test_pool_scatter_trace_collects_all_shards():
    rng = np.random.RandomState(5)
    p = ProcPool(2, train_plans=2, scatter="always", trace=True,
                 request_timeout_s=120.0)
    try:
        p.register("M", DenseTensor(rng.rand(40, 3)), "dense_array",
                   shards=2)
        q = array.count("M")
        p.execute(q, mode="training")
        rep = p.execute(q)
        assert rep.shards == 2
        t = rep.trace
        _assert_connected(t)
        wroots = [sp for sp in t.spans
                  if sp["name"] == "request" and sp["parent"] is not None]
        assert len(wroots) == rep.shards           # one subtree per shard
        assert len(t.find("gather_fold")) >= rep.shards - 1
        assert p.metrics.value("pool.scatter_serves") >= 1
    finally:
        p.close()


def test_pool_metrics_persist_merges_workers(traced_pool):
    q = array.matmul("M", "W")
    traced_pool.execute(q)
    traced_pool.persist()
    path = default_metrics_path(traced_pool.state_path)
    snap = load_merged(path)
    assert snap["counters"]["pool.dispatches"] >= 1
    assert snap["counters"]["bd.serve_seconds"] > 0.0
