"""Fault tolerance / checkpoint / data-pipeline tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt import CheckpointManager
from repro.configs import get_arch, PlanConfig
from repro.data import TokenStream, ShardedLoader
from repro.models import api
from repro.optim import AdamW, int8_ef_compress, int8_ef_init, cosine_schedule
from repro.runtime import (FailureInjector, SimulatedFailure, StragglerDetector,
                           Trainer)

PLAN = PlanConfig(param_dtype="float32", compute_dtype="float32",
                  master_dtype="float32", attn_chunk=8, loss_chunk=8,
                  remat="none")


def _tiny_setup(tmp_path, fail_at=()):
    cfg = get_arch("internlm2-1.8b").smoke()
    opt = AdamW(learning_rate=1e-3)
    state = api.init_train_state(cfg, PLAN, jax.random.PRNGKey(0), opt)
    step = jax.jit(api.make_train_step(cfg, PLAN, opt))
    stream = TokenStream(vocab_size=cfg.vocab_size, batch=2, seq_len=16, seed=7)
    batch_fn = lambda s: {"tokens": stream.batch_at(s)}
    trainer = Trainer(step, batch_fn, CheckpointManager(str(tmp_path), 3),
                      ckpt_every=5,
                      injector=FailureInjector(set(fail_at)) if fail_at else None)
    return cfg, state, trainer


def test_token_stream_deterministic():
    s1 = TokenStream(vocab_size=100, batch=4, seq_len=8, seed=3)
    s2 = TokenStream(vocab_size=100, batch=4, seq_len=8, seed=3)
    np.testing.assert_array_equal(s1.batch_at(17), s2.batch_at(17))
    assert not np.array_equal(s1.batch_at(17), s1.batch_at(18))


def test_loader_prefetch_order():
    stream = TokenStream(vocab_size=50, batch=2, seq_len=4, seed=1)
    loader = ShardedLoader(lambda s: {"tokens": stream.batch_at(s)})
    b0 = next(loader)
    b1 = next(loader)
    np.testing.assert_array_equal(np.asarray(b0["tokens"]),
                                  np.asarray(stream.batch_at(0)))
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(stream.batch_at(1)))
    loader.close()


def test_checkpoint_roundtrip(tmp_path):
    cfg, state, trainer = _tiny_setup(tmp_path)
    mgr = trainer.ckpt
    mgr.save(3, state, blocking=True)
    like = jax.eval_shape(lambda: state)
    restored, step = mgr.restore(like)
    assert step == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_last(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    state = {"x": jnp.arange(4)}
    for s in (1, 2, 3, 4):
        mgr.save(s, state, blocking=True)
    assert mgr.steps() == [3, 4]


def test_restart_reproduces_uninterrupted_run(tmp_path):
    """The headline fault-tolerance property: a run with injected failures
    ends with the SAME final loss trajectory as an uninterrupted run."""
    cfg, state0, t_clean = _tiny_setup(tmp_path / "clean")
    final_clean = t_clean.run(state0, 12)
    cfg, state0b, t_faulty = _tiny_setup(tmp_path / "faulty",
                                         fail_at=(7, 11))
    final_faulty, restarts = t_faulty.run_with_restarts(state0b, 12)
    assert restarts == 2
    clean = {h["step"]: h["loss"] for h in t_clean.history}
    faulty = {h["step"]: h["loss"] for h in t_faulty.history}
    assert set(clean) == set(faulty)
    for s in clean:
        np.testing.assert_allclose(clean[s], faulty[s], rtol=1e-4, atol=1e-5)
    for a, b in zip(jax.tree.leaves(final_clean), jax.tree.leaves(final_faulty)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-4,
                                   atol=1e-5)


def test_elastic_restore_reshards(tmp_path):
    """A checkpoint written unsharded restores under explicit shardings."""
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, state, blocking=True)
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((1,), ("data",))
    sh = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", None))}
    restored, _ = mgr.restore(jax.eval_shape(lambda: state), shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["w"].sharding == sh["w"]


def test_straggler_detector_flags_outliers():
    det = StragglerDetector(z_threshold=3.0, warmup=5)
    flagged = []
    det.on_straggler = lambda s, t: flagged.append(s)
    for i in range(20):
        det.observe(i, 0.1 + 0.001 * (i % 3))
    det.observe(20, 5.0)
    assert flagged == [20]
    # outlier excluded from stats: next normal step is not flagged
    assert not det.observe(21, 0.1)


def test_int8_ef_compression_converges():
    """Error feedback keeps SGD converging on a quadratic."""
    w = jnp.asarray([2.0, -3.0, 1.5])
    target = jnp.asarray([0.5, 0.5, 0.5])
    ef = int8_ef_init({"w": w})
    lr = 0.1
    for _ in range(200):
        g = {"w": 2 * (w - target)}
        gq, ef = int8_ef_compress(g, ef)
        w = w - lr * gq["w"]
    np.testing.assert_allclose(np.asarray(w), np.asarray(target), atol=1e-2)


def test_cosine_schedule_shape():
    f = cosine_schedule(1e-3, warmup_steps=10, total_steps=100)
    assert float(f(jnp.asarray(0))) < 1e-4
    np.testing.assert_allclose(float(f(jnp.asarray(10))), 1e-3, rtol=1e-2)
    assert float(f(jnp.asarray(100))) < 2e-4
