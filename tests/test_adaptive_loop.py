"""Adaptive planning loop (ISSUE 2): measured-size feedback, online
re-planning on predicted/measured divergence, multi-hop cast routing, and
warm plan-cache persistence.

Covers the four tentpole behaviors end to end: a data-dependent select gets
its real size from the monitor (beating the shape rule), >2x divergence
triggers exactly one re-plan, a persisted plan cache round-trips into a
fresh ``BigDAWG`` that serves production with zero plan enumerations, and
the migrator routes coo->dense->columnar when the direct pair is calibrated
slow.
"""
import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (BigDAWG, CachedPlan, CostModel, DenseTensor, Monitor,
                        array, relational, estimate_sizes, execute_plan)
from repro.core import cast as castmod
from repro.core.costmodel import observed_nbytes
from repro.core.ioutil import atomic_json_dump, load_json
from repro.core.middleware import _plan_from_key, default_plan_cache_path
from repro.core.migrator import Migrator
from repro.core.planner import Plan


def _bd(tmp_path=None, n=32, t=64, lo_frac=0.5, **kw):
    monitor = Monitor(str(tmp_path / "monitor.json")) if tmp_path else None
    bd = BigDAWG(monitor=monitor, train_plans=4, **kw)
    rng = np.random.default_rng(0)
    bd.register("waves", DenseTensor(jnp.asarray(
        rng.normal(size=(n, t)).astype(np.float32))), engine="dense_array")
    return bd


def _selective():
    # select keeps ~30% of a standard normal: genuinely data-dependent size
    return array.tfidf(array.haar(
        relational.select("waves", column="value", lo=0.5), levels=2))


# ---------------------------------------------------------------------------
# (1) measured-size feedback
# ---------------------------------------------------------------------------

def test_measured_size_overrides_shape_rule():
    bd = _bd()
    q = _selective()
    static = estimate_sizes(q, bd.catalog)
    sel = q.nodes()[0]                       # post-order: select is first
    # the shape rule can only say "output ~ input"
    assert static[sel.uid] == 4.0 * 32 * 64

    rep = bd.execute(q, mode="training")
    measured = bd.monitor.measured_sizes(rep.sig)
    assert 0 in measured
    # ~30% of a standard normal is >= 0.5: the measured size must be far
    # below the shape rule's input-sized guess
    assert measured[0] < 0.6 * static[sel.uid]

    fb = estimate_sizes(q, bd.catalog, measured=measured)
    assert fb[sel.uid] == pytest.approx(measured[0])
    assert fb[sel.uid] < static[sel.uid]


def test_executor_reports_size_obs_in_both_modes():
    bd = _bd()
    q = _selective()
    plan = Plan(tuple((i, "dense_array") for i in range(len(q.nodes()))))
    seq = execute_plan(q, plan, bd.catalog)
    conc = execute_plan(q, plan, bd.catalog, concurrent=True)
    assert set(seq.size_obs) == set(conc.size_obs) == {0, 1, 2}
    for pos in seq.size_obs:
        assert seq.size_obs[pos] == pytest.approx(conc.size_obs[pos])


def test_observed_nbytes_is_valid_aware():
    d = DenseTensor(jnp.ones((4, 4)), valid_count=3)
    assert observed_nbytes(d) == 12.0                      # 3 live cells
    col = castmod.cast(DenseTensor(jnp.ones((4, 4))), "columnar")
    assert observed_nbytes(col) == 4.0 * 16
    from repro.core.engines import ENGINES
    masked = ENGINES["columnar"].run("select", {"column": "value", "lo": 2.0},
                                     col)
    assert observed_nbytes(masked) == 0.0                  # nothing matches


def test_monitor_sizes_persist_and_legacy_format_loads(tmp_path):
    p = tmp_path / "monitor.json"
    m = Monitor(str(p))
    m.record("sig", "0:dense_array", 0.1, sizes={0: 100.0, 1: 200.0})
    m.record("sig", "0:dense_array", 0.1, sizes={0: 300.0})
    m.save()
    m2 = Monitor(str(p))
    assert m2.measured_sizes("sig") == {0: 200.0, 1: 200.0}   # running mean
    # a format-1 file (bare {sig: {plan_key: stats}}) still loads
    legacy = tmp_path / "legacy.json"
    atomic_json_dump(str(legacy), {"sig": {"0:dense_array": {
        "mean_seconds": 0.5, "n": 2, "last_seconds": 0.4,
        "cast_bytes": 0.0, "usage": {}, "extra": {}}}})
    m3 = Monitor(str(legacy))
    assert m3.best("sig")[0] == "0:dense_array"
    assert m3.measured_sizes("sig") == {}


# ---------------------------------------------------------------------------
# (2) online re-planning on divergence
# ---------------------------------------------------------------------------

def test_divergence_triggers_exactly_one_replan():
    """One divergence event -> one cheap-DP re-plan, and the replacement
    baseline is measurement-anchored so the same measured cost does not
    re-trigger (controlled measured values: wall-clock noise on ~ms queries
    can exceed the factor by itself and must not drive this assertion)."""
    bd = _bd()
    q = _selective()
    rep = bd.execute(q, mode="training")
    entry = bd.plan_cache[rep.sig]
    entry.pinned = entry.restored = False
    base = bd.replans
    mean = bd.monitor.known_plans(rep.sig)[entry.plan.key].mean_seconds
    entry.predicted_s = mean * 10.0          # make the baseline lie by 10x
    assert bd._maybe_replan(q, rep.sig, mean, entry)
    assert bd.replans == base + 1
    # the replacement entry's baseline is self-consistent: a measured cost
    # matching it must not re-plan (no cascade)
    new_entry = bd.plan_cache[rep.sig]
    new_entry.pinned = False
    assert not bd._maybe_replan(q, rep.sig, new_entry.predicted_s, new_entry)
    assert bd.replans == base + 1


def test_no_replan_within_factor():
    bd = _bd()
    q = _selective()
    rep = bd.execute(q, mode="training")
    entry = bd.plan_cache[rep.sig]
    entry.pinned = entry.restored = False
    # 1.5x off is inside the 2x factor: no re-plan in either direction
    assert not bd._maybe_replan(q, rep.sig, entry.predicted_s * 1.5, entry)
    assert not bd._maybe_replan(q, rep.sig, entry.predicted_s / 1.5, entry)
    assert bd.replans == 0


def test_replanned_entry_is_served_and_recorded():
    bd = _bd()
    q = _selective()
    rep = bd.execute(q, mode="training")
    # force a divergence AND a cost model under which a different plan wins,
    # so the re-plan produces a genuinely new cache entry
    entry = bd.plan_cache[rep.sig]
    entry.pinned = entry.restored = False
    entry.predicted_s *= 50.0
    for op in ("select", "haar", "tfidf"):
        bd.cost_model.observe_op("columnar", op, 1e6, 1e-4)
        bd.cost_model.observe_op("dense_array", op, 1e6, 10.0)
    rep2 = bd.execute(q, mode="production")
    assert rep2.replanned
    new_key = bd.plan_cache[rep.sig].plan.key
    assert bd.plan_cache[rep.sig].pinned
    rep3 = bd.execute(q, mode="production")
    assert rep3.plan_key == new_key          # pinned serve of the new plan
    assert rep3.cache_hit
    assert new_key in bd.monitor.known_plans(rep.sig)


# ---------------------------------------------------------------------------
# (3) multi-hop cast routing
# ---------------------------------------------------------------------------

def _routing_model():
    cm = CostModel()
    cm.observe_cast("coo", "columnar", 1e3, 1.0)       # 1e3 B/s: awful direct
    cm.observe_cast("coo", "dense", 1e6, 0.001)        # 1e9 B/s
    cm.observe_cast("dense", "columnar", 1e6, 0.001)   # 1e9 B/s
    return cm


def test_multi_hop_route_beats_slow_direct_pair():
    cm = _routing_model()
    seconds, path = cm.cast_route("coo", "columnar", 1e6)
    assert path == ["coo", "dense", "columnar"]
    direct = cm._edge_seconds("coo", "columnar", 1e6)
    assert seconds < direct / 100.0
    assert cm.cast_seconds("coo", "columnar", 1e6) == pytest.approx(seconds)


def test_unobserved_multi_hop_never_beats_measured_direct():
    cm = CostModel()
    cm.observe_cast("dense", "coo", 1e6, 0.25)         # slow but MEASURED
    # default-bandwidth detours exist on paper; they must not win
    assert cm.cast_seconds("dense", "coo", 1e6) == pytest.approx(0.25,
                                                                 rel=0.1)


def test_migrator_executes_routed_multi_hop():
    cm = _routing_model()
    rng = np.random.default_rng(0)
    dense = DenseTensor(jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32)))
    coo = castmod.cast(dense, "coo")
    mig = Migrator(cost_model=cm)
    out = mig.to_engine(coo, "columnar")
    assert out.kind == "columnar"
    hops = [(s, d) for s, d, _, _ in mig.events]
    assert hops == [("coo", "dense"), ("dense", "columnar")]
    np.testing.assert_allclose(
        np.asarray(castmod.cast(out, "dense").data),
        np.asarray(dense.data), rtol=1e-6)
    # without a model the migrator still takes the registered direct pair
    mig2 = Migrator()
    mig2.to_engine(coo, "columnar")
    assert [(s, d) for s, d, _, _ in mig2.events] == [("coo", "columnar")]


def test_unregistered_pair_still_routes_through_dense():
    cm = CostModel()
    s, path = cm.cast_route("columnar", "stream", 1e4)
    assert path[0] == "columnar" and path[-1] == "stream"
    assert all(p in castmod._CASTS for p in zip(path, path[1:]))


# ---------------------------------------------------------------------------
# (4) plan-cache persistence + warm restart
# ---------------------------------------------------------------------------

def test_plan_cache_roundtrips_and_fresh_bigdawg_serves_warm(tmp_path,
                                                             monkeypatch):
    bd = _bd(tmp_path)
    q = _selective()
    rep = bd.execute(q, mode="training")
    assert (tmp_path / "monitor.plans.json").exists()
    assert default_plan_cache_path(str(tmp_path / "monitor.json")) == \
        str(tmp_path / "monitor.plans.json")
    bd.execute(q, mode="production")         # at least one production serve
    # align the entry with the monitor's current best (online re-planning may
    # legitimately have pinned a different plan mid-flight) and persist —
    # the explicit flush QueryServer.persist() performs
    key, stats, _ = bd.monitor.best(rep.sig)
    bd.plan_cache[rep.sig] = CachedPlan(_plan_from_key(key),
                                        stats.mean_seconds)
    bd.monitor.save()
    bd.save_plan_cache()

    # fresh middleware on the same dir: must serve production from the
    # persisted cache with ZERO plan enumerations
    bd2 = _bd(tmp_path)
    assert rep.sig in bd2.plan_cache
    assert bd2.plan_cache[rep.sig].plan.key == key
    assert bd2.plan_cache[rep.sig].restored

    import repro.core.middleware as mw

    def boom(*a, **kw):
        raise AssertionError("fresh process enumerated plans")

    monkeypatch.setattr(mw, "dp_plans", boom)
    rep2 = bd2.execute(q, mode="production")
    assert rep2.mode == "production"
    assert rep2.cache_hit and not rep2.replanned
    assert rep2.plan_key == key


def test_malformed_persisted_entries_are_skipped_with_warning(tmp_path):
    path = tmp_path / "monitor.plans.json"
    atomic_json_dump(str(path), {"format": 1, "entries": {
        "goodsig": {"plan": "0:dense_array|1:dense_array", "predicted_s": 0.1},
        "badsig1": {"plan": "0:dense_array|garbage"},
        "badsig2": {"plan": "0:no_such_engine"},
        "badsig3": "not-an-object",
        "badsig4": {"predicted_s": 0.5},                  # missing plan key
    }})
    bd = BigDAWG(monitor=Monitor(str(tmp_path / "monitor.json")))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        bd.load_plan_cache(str(path))
    assert list(bd.plan_cache) == ["goodsig"]
    assert bd.plan_cache["goodsig"].restored
    assert len(w) == 4


def test_plan_from_key_rejects_malformed():
    assert _plan_from_key("0:dense_array|1:columnar").key == \
        "0:dense_array|1:columnar"
    for bad in ("", "garbage", "0:dense_array|x:y:z", "a:dense_array",
                "0:not_an_engine", "1:dense_array",         # gap at 0
                "0:dense_array|0:columnar"):                # duplicate pos
        with pytest.raises(ValueError):
            _plan_from_key(bad)


def test_unparseable_plan_cache_file_starts_cold(tmp_path):
    mon = tmp_path / "monitor.json"
    bad = tmp_path / "monitor.plans.json"
    bad.write_text('{"entries": {"sig1": {"plan": "0:dense')   # truncated
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        bd = BigDAWG(monitor=Monitor(str(mon)))
    assert bd.plan_cache == {}
    assert any("unreadable" in str(x.message) for x in w)


def test_wrong_length_plan_falls_back_to_training(tmp_path):
    bd = _bd()
    q = _selective()
    rep = bd.execute(q, mode="training")
    # history and cache claim a 1-position plan for this 3-node query
    stats = bd.monitor.db[rep.sig].pop(rep.plan_key)
    bd.monitor.db[rep.sig] = {"0:dense_array": stats}
    bd.plan_cache[rep.sig] = CachedPlan(_plan_from_key("0:dense_array"),
                                        stats.mean_seconds)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        rep2 = bd.execute(q, mode="production")
    assert rep2.mode == "training"           # retrained, did not crash
    assert any("positions" in str(x.message) for x in w)


def test_background_queue_skips_corrupted_plan_keys():
    bd = _bd()
    q = _selective()
    rep = bd.execute(q, mode="training")
    good = bd.monitor.best(rep.sig)[0]
    bd.monitor.queue_background(rep.sig, "not:a|plan")       # corrupted
    bd.monitor.queue_background(rep.sig, "0:dense_array")    # wrong length
    bd.monitor.queue_background(rep.sig, good)               # fine
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        done = bd.run_background_queue({rep.sig: q})
    assert done == 1                         # drained past both bad entries
    assert len(w) == 2


def test_corrupted_monitor_best_falls_back_to_training(tmp_path):
    bd = _bd()
    q = _selective()
    rep = bd.execute(q, mode="training")
    # corrupt the whole history for this sig: production must retrain, not die
    bd.monitor.db[rep.sig] = {"totally:broken:key":
                              bd.monitor.db[rep.sig][rep.plan_key]}
    bd.plan_cache.pop(rep.sig)
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        rep2 = bd.execute(q, mode="production")
    assert rep2.mode == "training"


def test_restored_entry_without_baseline_adopts_measurement():
    """A persisted entry missing predicted_s (loads as 0.0) must still
    re-sync on first serve — a zero baseline must not leave the replan loop
    permanently dead for that signature."""
    bd = _bd()
    q = _selective()
    rep = bd.execute(q, mode="training")
    entry = bd.plan_cache[rep.sig]
    entry.predicted_s, entry.restored, entry.pinned = 0.0, True, False
    assert not bd._maybe_replan(q, rep.sig, 0.005, entry)
    assert not entry.restored
    assert entry.predicted_s == pytest.approx(0.005)     # baseline adopted


def test_restored_entry_resyncs_instead_of_replanning(tmp_path):
    bd = _bd(tmp_path)
    q = _selective()
    rep = bd.execute(q, mode="training")
    # persist an entry aligned with the monitor's best plan whose baseline
    # will look 10x off to the next process (a "runtime changed" restart)
    key, stats, _ = bd.monitor.best(rep.sig)
    bd.plan_cache[rep.sig] = CachedPlan(_plan_from_key(key),
                                        stats.mean_seconds / 10.0)
    bd.save_plan_cache()
    bd2 = _bd(tmp_path)
    entry = bd2.plan_cache[rep.sig]
    assert entry.restored
    rep2 = bd2.execute(q, mode="production")
    assert not rep2.replanned and bd2.replans == 0
    assert not bd2.plan_cache[rep.sig].restored
    # prediction re-synced to this process's measured history
    want = bd2.monitor.known_plans(rep.sig)[rep2.plan_key].mean_seconds
    assert bd2.plan_cache[rep.sig].predicted_s == pytest.approx(want)
