"""Pallas kernel validation: interpret=True vs pure-jnp oracle, swept over
shapes and dtypes (assignment requirement: per-kernel allclose against ref)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.haar import haar_pallas
from repro.kernels.knn import knn_pallas, knn_scores_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssd_scan import ssd_intra_pallas

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,t,levels", [(4, 16, 2), (8, 64, 3), (130, 256, 4),
                                        (3, 32, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_haar(n, t, levels, dtype):
    x = jax.random.normal(KEY, (n, t), dtype)
    got = haar_pallas(x, levels, block_rows=8, interpret=True)
    want = ref.haar_ref(x, levels)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_haar_energy_preserved():
    """Orthonormal transform property: ||coeffs|| == ||signal||."""
    x = jax.random.normal(KEY, (16, 128), jnp.float32)
    y = haar_pallas(x, 4, interpret=True)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=1),
                               np.linalg.norm(np.asarray(x), axis=1),
                               rtol=1e-5)


@pytest.mark.parametrize("n,v,b", [(64, 128, 4), (256, 512, 8), (100, 48, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_knn_scores(n, v, b, dtype):
    train = jax.random.normal(KEY, (n, v), dtype)
    test = jax.random.normal(jax.random.PRNGKey(1), (b, v), dtype)
    got = knn_scores_pallas(train, test, interpret=True)
    want = ref.knn_scores_ref(train, test)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=0.3 if dtype == jnp.bfloat16 else 1e-3)


def test_knn_topk_indices():
    train = jax.random.normal(KEY, (128, 64), jnp.float32)
    test = jax.random.normal(jax.random.PRNGKey(2), (2, 64), jnp.float32)
    idx, _ = knn_pallas(train, test, 5, interpret=True)
    idx_ref, _ = ref.knn_ref(train, test, 5)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_ref))


@pytest.mark.parametrize("bh,s,d", [(2, 128, 64), (1, 256, 128), (4, 64, 32)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(bh, s, d, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (bh, s, d), dtype)
    k = jax.random.normal(ks[1], (bh, s, d), dtype)
    v = jax.random.normal(ks[2], (bh, s, d), dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=64,
                                 block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("b,q,h,p,g,n", [(2, 32, 4, 16, 1, 8),
                                         (1, 64, 8, 32, 2, 16),
                                         (2, 128, 6, 64, 1, 64)])
def test_ssd_intra(b, q, h, p, g, n):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, q, h, p), jnp.float32)
    da = -jax.nn.softplus(jax.random.normal(ks[1], (b, q, h)))  # negative decay
    B = jax.random.normal(ks[2], (b, q, g, n), jnp.float32)
    C = jax.random.normal(ks[3], (b, q, g, n), jnp.float32)
    y, st, cd = ssd_intra_pallas(x, da, B, C, block_h=4, interpret=True)
    y2, st2, cd2 = ref.ssd_intra_ref(x, da, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(cd), np.asarray(cd2), rtol=1e-5,
                               atol=1e-6)


# ---------------------------------------------------------------------------
# randomized-shape/dtype differential parity: every Pallas kernel vs its
# kernels/ref.py oracle (plan-level fusion routes warm serves through these
# kernels, so the fused path is only as trustworthy as this battery).
# Shapes are drawn from a seeded RNG — deterministic, but not hand-picked —
# and each kernel's own block-size adaptation must absorb whatever is drawn.
# ---------------------------------------------------------------------------

_RAND_SEEDS = list(range(6))


def _rand_dtype(rng):
    return jnp.bfloat16 if rng.integers(0, 2) else jnp.float32


@pytest.mark.parametrize("seed", _RAND_SEEDS)
def test_haar_random_shapes(seed):
    rng = np.random.default_rng(seed)
    levels = int(rng.integers(1, 5))
    n = int(rng.integers(1, 200))
    t = int(rng.integers(1, 17)) * (1 << levels)   # T % 2^levels == 0
    dtype = _rand_dtype(rng)
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, t), dtype)
    got = haar_pallas(x, levels, block_rows=int(rng.integers(1, 129)),
                      interpret=True)
    want = ref.haar_ref(x, levels)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("seed", _RAND_SEEDS)
def test_knn_scores_random_shapes(seed):
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(2, 300))
    v = int(rng.integers(2, 200))
    b = int(rng.integers(1, 12))
    dtype = _rand_dtype(rng)
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    train = jax.random.normal(ks[0], (n, v), dtype)
    test = jax.random.normal(ks[1], (b, v), dtype)
    got = knn_scores_pallas(train, test, interpret=True)
    want = ref.knn_scores_ref(train, test)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=0.3 if dtype == jnp.bfloat16 else 1e-3)


@pytest.mark.parametrize("seed", _RAND_SEEDS)
def test_knn_topk_random_shapes(seed):
    # float32 only: bfloat16 score ties reorder the top-k indices, which is
    # an ordering artifact, not a kernel defect
    rng = np.random.default_rng(200 + seed)
    n = int(rng.integers(8, 300))
    v = int(rng.integers(2, 200))
    b = int(rng.integers(1, 8))
    k = int(rng.integers(1, min(n, 8)))
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    train = jax.random.normal(ks[0], (n, v), jnp.float32)
    test = jax.random.normal(ks[1], (b, v), jnp.float32)
    idx, score = knn_pallas(train, test, k, interpret=True)
    idx_ref, score_ref = ref.knn_ref(train, test, k)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_ref))
    np.testing.assert_allclose(np.asarray(score), np.asarray(score_ref),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("seed", _RAND_SEEDS)
def test_flash_attention_random_shapes(seed):
    rng = np.random.default_rng(300 + seed)
    bh = int(rng.integers(1, 5))
    s = int(rng.integers(1, 40)) * 8
    d = int(rng.integers(4, 80))
    causal = bool(rng.integers(0, 2))
    dtype = _rand_dtype(rng)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (bh, s, d), dtype)
    k = jax.random.normal(ks[1], (bh, s, d), dtype)
    v = jax.random.normal(ks[2], (bh, s, d), dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=64,
                                 block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("seed", _RAND_SEEDS)
def test_ssd_intra_random_shapes(seed):
    rng = np.random.default_rng(400 + seed)
    b = int(rng.integers(1, 4))
    q = int(rng.integers(1, 12)) * 8
    h = int(rng.integers(1, 10))
    p = int(rng.integers(2, 40))
    g = int(rng.integers(1, 3))
    while h % g:                              # heads group evenly
        g = 1
    n = int(rng.integers(2, 40))
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (b, q, h, p), jnp.float32)
    da = -jax.nn.softplus(jax.random.normal(ks[1], (b, q, h)))
    B = jax.random.normal(ks[2], (b, q, g, n), jnp.float32)
    C = jax.random.normal(ks[3], (b, q, g, n), jnp.float32)
    y, st, cd = ssd_intra_pallas(x, da, B, C, block_h=4, interpret=True)
    y2, st2, cd2 = ref.ssd_intra_ref(x, da, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(cd), np.asarray(cd2), rtol=1e-5,
                               atol=1e-6)


def test_ssd_intra_matches_full_ssd():
    """One-chunk SSD == the model's chunked SSD with chunk == seq."""
    from repro.models.ssm import ssd_chunked
    b, s, h, p, n = 2, 64, 4, 16, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
    B = jax.random.normal(ks[3], (b, s, 1, n))
    C = jax.random.normal(ks[4], (b, s, 1, n))
    y_full, _ = ssd_chunked(x, dt, A, B, C, chunk=s)
    da = dt * A
    y_k, _, _ = ssd_intra_pallas(x * dt[..., None], da, B, C, block_h=4,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_full, np.float32),
                               rtol=2e-3, atol=2e-3)
