"""Host-parallel executor + k-best alternate exploration (ISSUE 3).

Covers the PR's tentpole behaviors: the thread-pooled concurrent mode must
be a drop-in for sequential execution (same values, worker exceptions
propagate, ``host_workers=1`` falls back inline), the k-best DP's runner-ups
must ride the plan cache as ``CachedPlan.alternates`` and be executed by the
budgeted exploration path (measurements recorded, winner re-selected when an
alternate proves faster), multi-hop casts must be sized per hop from the
intermediate format, measured SHAPES must feed downstream estimates, and
monitor history must decay so workload shifts show up in the means.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (BigDAWG, CostModel, DenseTensor, Monitor, array,
                        estimate_sizes_shapes, execute_plan, relational)
from repro.core.engines import ENGINES, Engine
from repro.core.middleware import CachedPlan
from repro.core.monitor import PlanStats, _ema_alpha
from repro.core.planner import Plan
from repro.runtime import QueryServer


def _bd(tmp_path=None, n=32, t=64, **kw):
    monitor = Monitor(str(tmp_path / "monitor.json")) if tmp_path else None
    bd = BigDAWG(monitor=monitor, train_plans=4, **kw)
    rng = np.random.default_rng(0)
    bd.register("waves", DenseTensor(jnp.asarray(
        rng.normal(size=(n, t)).astype(np.float32))), engine="dense_array")
    return bd


def _wide():
    """10-node tree whose first topological level holds two independent
    selects — the shape the host pool must overlap."""
    def branch():
        s = relational.select("waves", column="value", lo=0.0)
        h = array.haar(s, levels=2)
        return array.tfidf(array.bin_hist(h, nbins=8, levels=2))
    return array.matmul(branch(), array.transpose(branch()))


def _all_dense(q):
    return Plan(tuple((i, "dense_array") for i in range(len(q.nodes()))))


# ---------------------------------------------------------------------------
# (1) thread-pooled concurrent executor
# ---------------------------------------------------------------------------

def test_threaded_concurrent_matches_sequential():
    bd = _bd()
    q = _wide()
    plan = _all_dense(q)
    seq = execute_plan(q, plan, bd.catalog)
    thr = execute_plan(q, plan, bd.catalog, concurrent=True, host_workers=4)
    assert thr.levels >= 4
    np.testing.assert_allclose(np.asarray(seq.value.data),
                               np.asarray(thr.value.data),
                               rtol=1e-5, atol=1e-6)
    # identical migration accounting: the shared Migrator's locked counters
    # must not lose updates across workers
    assert thr.n_casts == seq.n_casts
    assert thr.cast_bytes == pytest.approx(seq.cast_bytes)
    # size/shape feedback is mode-independent
    assert thr.size_obs == pytest.approx(seq.size_obs)
    assert thr.shape_obs == seq.shape_obs
    assert seq.node_obs and not thr.node_obs     # cost-model obs: seq only


def test_single_thread_fallback_matches():
    bd = _bd()
    q = _wide()
    plan = _all_dense(q)
    inline = execute_plan(q, plan, bd.catalog, concurrent=True,
                          host_workers=1)
    thr = execute_plan(q, plan, bd.catalog, concurrent=True, host_workers=4)
    assert inline.levels == thr.levels
    np.testing.assert_allclose(np.asarray(inline.value.data),
                               np.asarray(thr.value.data), rtol=1e-6)


def test_worker_exception_propagates(monkeypatch):
    bd = _bd()
    q = _wide()
    plan = _all_dense(q)

    class Boom(RuntimeError):
        pass

    def exploding(attrs, *inputs):
        raise Boom("engine op failed in a worker")

    broken = Engine("dense_array", "dense",
                    dict(ENGINES["dense_array"].ops, select=exploding))
    monkeypatch.setitem(ENGINES, "dense_array", broken)
    with pytest.raises(Boom):
        execute_plan(q, plan, bd.catalog, concurrent=True, host_workers=4)


def test_per_node_seconds_recorded_in_concurrent_mode():
    bd = _bd()
    q = _wide()
    res = execute_plan(q, _all_dense(q), bd.catalog, concurrent=True,
                       host_workers=4)
    assert len(res.per_node_seconds) == len({n.uid for n in q.nodes()})
    assert all(v >= 0.0 for v in res.per_node_seconds.values())


# ---------------------------------------------------------------------------
# (2) per-hop cast sizing on multi-hop routes
# ---------------------------------------------------------------------------

def test_multi_hop_route_sizes_each_hop_from_intermediate_format():
    cm = CostModel()
    cm.observe_cast("coo", "columnar", 1e3, 1.0)     # awful direct pair
    cm.observe_cast("coo", "dense", 1e6, 0.001)      # 1e9 B/s
    cm.observe_cast("dense", "columnar", 1e6, 0.001)  # 1e9 B/s
    # a very sparse payload: 1e4 logical bytes of triples, but densified it
    # is a (1000, 1000) float32 plane = 4e6 bytes
    kind_nbytes = {"coo": 3e4, "dense": 4e6, "columnar": 3e4}
    flat, path = cm.cast_route("coo", "columnar", 3e4)
    sized, path2 = cm.cast_route("coo", "columnar", 3e4, kind_nbytes)
    assert path == path2 == ["coo", "dense", "columnar"]
    # the flat estimate charges the dense->columnar hop for 3e4 bytes; the
    # per-hop estimate charges the densified 4e6 — visibly more expensive
    assert sized > flat
    assert sized == pytest.approx(
        cm._edge_seconds("coo", "dense", 3e4)
        + cm._edge_seconds("dense", "columnar", 4e6))


def test_migrator_routes_with_densification_cost():
    """A sparse COO whose densified plane is huge must now prefer the direct
    coo->columnar pair over a detour through dense, even when the detour's
    per-byte bandwidths look slightly better."""
    cm = CostModel()
    cm.observe_cast("coo", "columnar", 1e6, 0.01)     # 1e8 B/s direct
    cm.observe_cast("coo", "dense", 1e6, 0.004)       # 2.5e8 B/s
    cm.observe_cast("dense", "columnar", 1e6, 0.004)  # 2.5e8 B/s
    # payload: sparse triples in a (4000, 4000) plane -> densify = 64e6 bytes
    kind_nbytes = {"coo": 3.6e6, "dense": 64e6, "columnar": 3.6e6}
    _, path = cm.cast_route("coo", "columnar", 3.6e6, kind_nbytes)
    assert path == ["coo", "columnar"]
    # without per-hop sizing the detour would have (wrongly) won
    _, flat_path = cm.cast_route("coo", "columnar", 3.6e6)
    assert flat_path == ["coo", "dense", "columnar"]


# ---------------------------------------------------------------------------
# (3) measured-shape feedback
# ---------------------------------------------------------------------------

def test_executor_reports_shape_obs():
    bd = _bd(n=32, t=64)
    q = array.haar(relational.select("waves", column="value", lo=0.5),
                   levels=2)
    res = execute_plan(q, _all_dense(q), bd.catalog)
    # both nodes run dense: every position carries a dense shape
    assert res.shape_obs[0] == (32, 64)
    assert res.shape_obs[1] == (32, 64)


def test_measured_shapes_feed_downstream_matmul_estimate():
    q = array.matmul(array.tfidf("unknown_a"), array.tfidf("unknown_b"))
    # without catalog entries the shape rules know nothing: matmul output
    # falls back to max-input bytes
    static_sizes, static_shapes = estimate_sizes_shapes(q, None)
    assert static_shapes[q.uid] is None
    # measured shapes for the two tfidf outputs (post-order 0, 1): now the
    # matmul rule can predict its true (128, 16) output
    measured_shapes = {0: (128, 64), 1: (64, 16)}
    sizes, shapes = estimate_sizes_shapes(q, None,
                                          measured_shapes=measured_shapes)
    assert shapes[q.uid] == (128, 16)
    assert sizes[q.uid] == 4.0 * 128 * 16


def test_monitor_persists_shapes(tmp_path):
    p = tmp_path / "monitor.json"
    m = Monitor(str(p))
    m.record("sig", "0:dense_array", 0.1, sizes={0: 64.0},
             shapes={0: (4, 4)})
    m.save()
    m2 = Monitor(str(p))
    assert m2.measured_shapes("sig") == {0: (4, 4)}
    # newest shape replaces (no averaging of discrete geometry)
    m2.record("sig", "0:dense_array", 0.1, shapes={0: (8, 2)})
    assert m2.measured_shapes("sig") == {0: (8, 2)}


def test_trained_signature_stores_shapes():
    bd = _bd()
    q = _wide()
    rep = bd.execute(q, mode="training")
    shapes = bd.monitor.measured_shapes(rep.sig)
    assert shapes            # dense placements report real shapes
    assert all(isinstance(s, tuple) for s in shapes.values())


# ---------------------------------------------------------------------------
# (4) monitor history decay
# ---------------------------------------------------------------------------

def test_ema_alpha_warmup_then_floor():
    assert _ema_alpha(0, 0.2) == 1.0                 # first sample: adopt
    assert _ema_alpha(1, 0.2) == 0.5                 # cumulative mean ...
    assert _ema_alpha(4, 0.2) == pytest.approx(0.2)  # ... until 1/decay
    assert _ema_alpha(100, 0.2) == pytest.approx(0.2)   # then EMA floor
    assert _ema_alpha(100, 0.0) == pytest.approx(1 / 101)   # decay off


def test_decay_tracks_workload_shift_cumulative_does_not():
    fresh, stale = PlanStats(), PlanStats()
    for _ in range(50):
        fresh.record(1.0, {}, decay=0.2)
        stale.record(1.0, {}, decay=0.0)             # pure cumulative
    for _ in range(5):                               # 10x regression
        fresh.record(10.0, {}, decay=0.2)
        stale.record(10.0, {}, decay=0.0)
    # decayed mean has moved most of the way to the new regime; the
    # cumulative mean is still diluted by the 50 stale samples
    assert fresh.mean_seconds > 6.0
    assert stale.mean_seconds < 2.0


def test_monitor_size_means_decay():
    m = Monitor(decay=0.5)
    m.record("sig", "0:dense_array", 0.1, sizes={0: 100.0})
    for _ in range(4):
        m.record("sig", "0:dense_array", 0.1, sizes={0: 1000.0})
    # with a 0.5 floor the mean reaches ~944 after four shifted samples; a
    # cumulative mean would sit at 820
    assert m.measured_sizes("sig")[0] > 900.0


# ---------------------------------------------------------------------------
# (5) k-best alternates + budgeted exploration
# ---------------------------------------------------------------------------

def test_training_caches_dp_runner_ups_as_alternates():
    bd = _bd()
    q = _wide()
    rep = bd.execute(q, mode="training")
    entry = bd.plan_cache[rep.sig]
    assert entry.alternates                          # runner-ups survived
    assert len(entry.alternates) <= BigDAWG.MAX_ALTERNATES
    keys = {p.key for p in entry.alternates}
    assert entry.plan.key not in keys                # winner is not its own
    n = len(q.nodes())                               # alternate
    assert all(len(p.assignment) == n for p in entry.alternates)


def test_alternates_roundtrip_through_plan_cache_file(tmp_path):
    bd = _bd(tmp_path)
    q = _wide()
    rep = bd.execute(q, mode="training")
    want = [p.key for p in bd.plan_cache[rep.sig].alternates]
    assert want
    bd.save_plan_cache()
    bd2 = _bd(tmp_path)
    entry = bd2.plan_cache[rep.sig]
    assert entry.restored
    assert [p.key for p in entry.alternates] == want


def test_no_exploration_when_budget_zero():
    bd = _bd()                                       # default budget: 0.0
    q = _wide()
    bd.execute(q, mode="training")
    rep = bd.execute(q, mode="production")
    assert not rep.explored
    assert bd.explorations == 0


def test_exploration_executes_true_alternate_within_budget():
    bd = _bd(explore_budget=10.0)     # generous: explore on every serve
    bd.replan_factor = float("inf")   # isolate exploration from replanning
    q = _wide()
    rep = bd.execute(q, mode="training")
    entry = bd.plan_cache[rep.sig]
    alt_keys = [p.key for p in entry.alternates]
    before = set(bd.monitor.known_plans(rep.sig))
    incumbent = entry.plan.key
    rep2 = bd.execute(q, mode="production")
    # exploration is scheduled off-path: the serve reports WHICH alternate
    # went to the background, and draining waits for its measurement
    assert rep2.explored and rep2.explored_key in alt_keys
    bd.drain_explorations()
    assert bd.explorations == 1
    # the alternate's measurement landed in the monitor (n grew or plan is
    # newly known) and exploration time is accounted
    stats = bd.monitor.known_plans(rep.sig)[rep2.explored_key]
    assert stats.n >= 1
    assert bd.explore_seconds > 0.0
    # the next serve explores again — from the current entry's pool, which
    # may legitimately include the old incumbent if timing noise promoted
    # the explored alternate in between — and never re-runs the served plan
    rep3 = bd.execute(q, mode="production")
    assert rep3.explored
    assert rep3.explored_key in set(alt_keys) | {incumbent}
    assert rep3.explored_key != rep3.plan_key
    bd.drain_explorations()
    assert before <= set(bd.monitor.known_plans(rep.sig))


def test_exploration_runs_off_the_request_path():
    """The serve's own timing must not contain the alternate's execution:
    the trial runs as a background host-pool task the serve only schedules."""
    bd = _bd(explore_budget=10.0)
    bd.replan_factor = float("inf")
    q = _wide()
    bd.execute(q, mode="training")
    rep = bd.execute(q, mode="production")
    assert rep.explored                      # scheduled ...
    # ... but not yet necessarily measured; serve_seconds already counts the
    # serve, while explore_seconds is only credited when the task completes
    waited = bd.drain_explorations()
    assert waited >= 1
    assert bd.explorations >= 1
    assert bd.explore_seconds > 0.0
    assert bd.serve_seconds > 0.0


def test_exploration_respects_budget_exhaustion():
    bd = _bd(explore_budget=1e-9)     # one exploration allowed at most
    bd.replan_factor = float("inf")
    q = _wide()
    bd.execute(q, mode="training")
    bd.execute(q, mode="production")                 # may explore once
    bd.drain_explorations()
    first = bd.explorations
    for _ in range(3):
        bd.execute(q, mode="production")
        bd.drain_explorations()
    # with a vanishing budget, explore_seconds > budget x serve_seconds
    # after the first trial: no further exploration
    assert bd.explorations <= max(first, 1)


def test_winning_alternate_is_promoted_on_next_serve():
    bd = _bd(explore_budget=10.0)
    bd.replan_factor = float("inf")
    q = _wide()
    rep = bd.execute(q, mode="training")
    entry = bd.plan_cache[rep.sig]
    alt = entry.alternates[0]
    incumbent = entry.plan.key
    # the alternate's measured history suddenly dominates the incumbent's
    stats = bd.monitor.db[rep.sig].setdefault(alt.key, PlanStats())
    stats.mean_seconds, stats.n = 1e-9, 5
    rep2 = bd.execute(q, mode="production")
    assert rep2.plan_key == alt.key                  # promoted
    assert not rep2.cache_hit                        # entry was rebuilt
    promoted = bd.plan_cache[rep.sig]
    assert promoted.plan.key == alt.key
    # the dethroned incumbent joined the alternate pool: exploration keeps
    # challenging it, so a wrong promotion can be reversed
    assert incumbent in {p.key for p in promoted.alternates}
    bd.drain_explorations()                          # no background leak


def test_query_server_counts_explorations(tmp_path):
    bd = _bd(tmp_path, explore_budget=10.0)
    bd.replan_factor = float("inf")
    srv = QueryServer(bd)
    srv.warm([_wide()])
    srv.persist()
    for _ in range(2):
        srv.submit(_wide())
        bd.drain_explorations()      # the server counts scheduled trials;
    # completions catch up at the drain
    assert srv.stats["explorations"] == bd.explorations >= 1
    # warm restart: the restored cache still carries the alternates, so a
    # fresh server keeps exploring without retraining
    bd2 = BigDAWG(monitor=Monitor(str(tmp_path / "monitor.json")),
                  train_plans=4, explore_budget=10.0)
    bd2.replan_factor = float("inf")
    rng = np.random.default_rng(0)
    bd2.register("waves", DenseTensor(jnp.asarray(
        rng.normal(size=(32, 64)).astype(np.float32))), engine="dense_array")
    srv2 = QueryServer(bd2)
    rep = srv2.submit(_wide())
    assert rep.mode == "production"
    srv2.submit(_wide())
    assert srv2.stats["trainings"] == 0
    assert srv2.stats["explorations"] >= 1
